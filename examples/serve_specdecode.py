"""Serving driver: continuous batching (HTS slot scheduler) + speculative
decoding with KV rollback — the paper's speculation/TM mechanism on a server.

    PYTHONPATH=src python examples/serve_specdecode.py
"""
import time

import dataclasses
import numpy as np
import jax

from repro.core.sched import serving, specdecode
from repro.models import registry


def main():
    model = registry.build_smoke("qwen2-1.5b")
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    # ---- continuous batching vs naive static batching ----
    reqs = [(rng.integers(0, model.cfg.vocab, 4).tolist(),
             int(rng.integers(4, 16))) for _ in range(16)]
    for policy in ("naive", "ooo"):
        srv = serving.Server(model, params, n_slots=4, max_len=64,
                             policy=policy)
        for i, (p, m) in enumerate(reqs):
            srv.submit(serving.Request(i, list(p), m))
        t0 = time.perf_counter()
        stats = srv.run()
        dt = time.perf_counter() - t0
        print(f"{policy:>5}: {stats.completed} reqs in {stats.steps} engine "
              f"steps, slot utilization {stats.utilization(4):.2f} "
              f"({dt:.1f}s wall)")

    # ---- speculative decoding (draft = truncated self) ----
    t_params = params
    d_params = dict(params)
    d_params["layers"] = jax.tree.map(lambda x: x[:1], params["layers"])
    draft = registry.build(dataclasses.replace(model.cfg, n_layers=1))
    prompt = np.asarray([[11, 7, 5, 3]])
    want = specdecode.greedy_decode(model, t_params, prompt, 16, 64)
    got, stats = specdecode.speculative_decode(
        model, t_params, draft, d_params, prompt, 16, k=4, max_len=64)
    assert (got == want).all(), "speculation must not change the output"
    print(f"spec-decode (1-layer random draft): {stats.proposed} drafted, "
          f"acceptance {stats.acceptance:.0%}, {stats.chunks} verify chunks "
          f"for 16 tokens — output bit-identical to greedy")
    # upper bound: a perfect draft (== target) accepts everything
    got2, stats2 = specdecode.speculative_decode(
        model, t_params, model, t_params, prompt, 16, k=4, max_len=64)
    assert (got2 == want).all()
    print(f"spec-decode (perfect draft):        acceptance "
          f"{stats2.acceptance:.0%}, {stats2.chunks} verify chunks for 16 "
          f"tokens (vs 16 sequential target steps)")


if __name__ == "__main__":
    main()
