"""Multi-tenant sharing demo: N generated apps on one HTS.

Generates a seeded scenario (4 tenants, mixed kernels/loops/branches),
differentially validates it (golden oracle ≡ compiled JAX machine with
event-skip on and off, three scheduler cost models), then prints the
metrics the paper's single global makespan hides: per-app schedule slices,
per-app makespan, and fairness vs each tenant's solo run.

    PYTHONPATH=src python examples/multi_tenant.py [seed]
"""
import sys

from repro.core import hts
from repro.core.hts import workloads


def main(seed: int = 4) -> None:
    sc = workloads.generate_scenario(seed, n_tenants=4)
    print(f"scenario {sc.name}: {sc.n_tenants} tenants, "
          f"{len(sc.merged.program.build())} merged instructions")

    report = hts.compare(sc.merged)         # raises MismatchError on any drift
    print("differential check: golden ≡ machine (event-skip on/off) for",
          ", ".join(report.schedulers))

    shared = hts.run(sc.merged, n_fu=2)
    print(f"\nshared run: {shared.cycles} cycles, "
          f"utilization {shared.utilization:.1%}")
    for pid, rows in shared.by_pid().items():
        print(f"  pid {pid}: {len(rows)} tasks, "
              f"makespan {shared.app_makespan(pid)}")

    solos = workloads.solo_results(sc, n_fu=2)
    fair = shared.fairness(solos)
    serial = sum(r.cycles for r in solos.values())
    print(f"\nserial (sum of solos): {serial} cycles → "
          f"sharing gain {serial / shared.cycles:.2f}×")
    print(fair.table())


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 4)
