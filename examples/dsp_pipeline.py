"""End-to-end DSP pipeline: the HTS schedule *actually executes* the Pallas
TPU kernels.

The audio-compression program (paper Algorithm 1) is built with the Program
Builder, scheduled by the cycle-accurate HTS machine via ``hts.run``, and
then each scheduled task runs its real accelerator kernel (kernels/dsp_*.py)
over a batch of audio frames, in issue order.  This is the full loop:
builder → ISA → OoO schedule → Function accelerators.

    PYTHONPATH=src python examples/dsp_pipeline.py --bands 4
"""
import argparse

import jax.numpy as jnp
import numpy as np

from repro.core import hts
from repro.core.hts import programs
from repro.kernels import ops


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bands", type=int, default=4)
    ap.add_argument("--frames", type=int, default=64)
    args = ap.parse_args()

    bench = programs.audio_compression(args.bands, time_domain=False)
    r = hts.run(bench, scheduler="hts_spec", n_fu=2)
    print(f"scheduled {r.n_tasks} tasks in {r.cycles} cycles "
          f"(aborted speculative: {r.spec_aborted}, "
          f"utilization {r.utilization:.1%})")

    # execute the schedule: every completed task runs its Pallas kernel
    table = ops.dsp_dispatch_table()
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((args.frames, 256), np.float32))
    issued = sorted((t for t in r.schedule if not t.aborted),
                    key=lambda t: t.issue)
    for t in issued:
        x = table[t.func_name](x)
        # renormalize between stages: raw filter chains amplify unboundedly
        x = x / jnp.maximum(jnp.max(jnp.abs(x)), 1e-6)
        print(f"  t={t.issue:>7}: task {t.uid:>3} {t.func_name:<13} -> "
              f"out[0,:3]={np.asarray(x[0, :3]).round(3)}")
    print("pipeline output stats: mean=%.4f std=%.4f"
          % (float(x.mean()), float(x.std())))
    assert np.isfinite(np.asarray(x)).all()


if __name__ == "__main__":
    main()
