"""End-to-end DSP pipeline: the HTS schedule *actually executes* the Pallas
TPU kernels.

The audio-compression program (paper Algorithm 1) is assembled, scheduled by
the cycle-accurate HTS machine, and then each scheduled task runs its real
accelerator kernel (kernels/dsp_*.py) over a batch of audio frames, in issue
order.  This is the full loop: ISA → OoO schedule → Function accelerators.

    PYTHONPATH=src python examples/dsp_pipeline.py --bands 4
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np                                        # noqa: E402
import jax.numpy as jnp                                   # noqa: E402

from repro.core.hts import assembler, costs, machine, programs  # noqa: E402
from repro.kernels import ops                             # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bands", type=int, default=4)
    ap.add_argument("--frames", type=int, default=64)
    args = ap.parse_args()

    bench = programs.audio_compression(args.bands, time_domain=False)
    code = assembler.assemble(bench.asm)
    out = machine.simulate(code, costs.costs_by_name("hts_spec"),
                           n_fu=np.array([2] * 10),
                           mem_init=bench.mem_init, effects=bench.effects)
    sched = machine.schedule_tuple(out)
    print(f"scheduled {len(sched)} tasks in {int(out['cycles'])} cycles "
          f"(aborted speculative: {int(out['spec_aborted'])})")

    # execute the schedule: every completed task runs its Pallas kernel
    table = ops.dsp_dispatch_table()
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((args.frames, 256), np.float32))
    issued = [row for row in sched if not row[6]]          # drop aborted
    issued.sort(key=lambda r: r[3])                        # issue order
    for uid, func, _, issue, complete, _, _ in issued:
        name = costs.FUNC_NAMES[func]
        x = table[name](x)
        # renormalize between stages: raw filter chains amplify unboundedly
        x = x / jnp.maximum(jnp.max(jnp.abs(x)), 1e-6)
        print(f"  t={issue:>7}: task {uid:>3} {name:<13} -> "
              f"out[0,:3]={np.asarray(x[0, :3]).round(3)}")
    print("pipeline output stats: mean=%.4f std=%.4f"
          % (float(x.mean()), float(x.std())))
    assert np.isfinite(np.asarray(x)).all()


if __name__ == "__main__":
    main()
