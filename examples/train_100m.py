"""End-to-end training driver: ~100M-parameter dense LM, synthetic data,
checkpoint/restart, straggler watchdog — the full runtime stack on CPU.

    PYTHONPATH=src python examples/train_100m.py --steps 300       # full run
    PYTHONPATH=src python examples/train_100m.py --steps 8 --tiny  # CI smoke
"""
import argparse
import tempfile

import jax

from repro.configs.base import ArchConfig
from repro.data import pipeline as data_lib
from repro.models import registry
from repro.optim.adamw import AdamWConfig
from repro.runtime import train as train_rt

CFG_100M = ArchConfig(                     # ≈ 110M params (gpt2-medium-ish)
    name="lm-100m", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=2048, vocab=32000,
)
CFG_TINY = CFG_100M.smoke()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = CFG_TINY if args.tiny else CFG_100M
    model = registry.build(cfg)
    print(f"arch={cfg.name} params={model.param_count()/1e6:.1f}M")

    dcfg = data_lib.DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                               global_batch=args.batch)
    source = data_lib.make_source(dcfg)
    tcfg = train_rt.TrainConfig(
        optimizer=AdamWConfig(lr=3e-4),
        warmup_steps=max(2, args.steps // 10), total_steps=args.steps,
        ckpt_every=max(args.steps // 4, 1))
    step_fn = jax.jit(train_rt.make_train_step(model, tcfg), donate_argnums=0)
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="train100m_")
    loop = train_rt.TrainLoop(
        model, source, step_fn, tcfg, ckpt_dir,
        init_fn=lambda: train_rt.init_state(model, jax.random.PRNGKey(0)))
    loop.run(args.steps)
    first, last = loop.history[0]["loss"], loop.history[-1]["loss"]
    print(f"loss: step0={first:.3f} → step{args.steps - 1}={last:.3f} "
          f"(ckpts in {ckpt_dir}; stragglers flagged: {loop.stragglers})")
    import math
    assert math.isfinite(last)
    if args.steps >= 100:          # too few steps to demand a visible trend
        assert last < first, "loss must decrease on the synthetic stream"


if __name__ == "__main__":
    main()
