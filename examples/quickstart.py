"""Quickstart: write an HTS dataflow program, schedule it 4 ways, compare.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np                                   # noqa: E402

from repro.core.hts import assembler, costs, machine  # noqa: E402

# A little dataflow graph in the paper's assembly (§V-B): an FFT feeding
# three vector-dots feeding an IIR, next to an independent FIR chain.
ASM = """
# keyname  in  isz out osz tid pid ctl meta
fft_256     10  4   20  4   0   0   0   0
vector_dot  20  4   30  1   1   0   0   0
vector_dot  20  4   31  1   2   0   0   0
vector_dot  20  4   32  1   3   0   0   0
iir         30  3   40  3   4   0   0   0
real_fir    10  4   50  4   5   0   0   0
real_fir    50  4   58  4   6   0   0   0
"""

def main():
    code = assembler.assemble(ASM)
    print(f"{'scheduler':<12} {'cycles':>10} {'speedup':>8}")
    base = None
    for sched in costs.ALL_SCHEDULERS:
        out = machine.simulate(code, costs.costs_by_name(sched),
                               n_fu=np.array([2] * 10))
        cyc = int(out["cycles"])
        base = base or cyc
        print(f"{sched:<12} {cyc:>10} {base / cyc:>8.2f}x")
    print("\nper-task schedule (hts_spec):")
    out = machine.simulate(code, costs.costs_by_name("hts_spec"),
                           n_fu=np.array([2] * 10))
    for uid, func, disp, issue, comp, bcast, aborted in \
            machine.schedule_tuple(out):
        print(f"  task {uid} ({costs.FUNC_NAMES[func]:<12}) dispatch={disp:>4}"
              f" issue={issue:>4} complete={comp:>6} broadcast={bcast:>6}")


if __name__ == "__main__":
    main()
