"""Quickstart: build an HTS dataflow program, schedule it 4 ways, compare.

    PYTHONPATH=src python examples/quickstart.py

(or ``pip install -e .`` once and drop the PYTHONPATH.)
"""
from repro.core import hts


def build_program() -> hts.Program:
    """A little dataflow graph (paper §V-B): an FFT feeding three
    vector-dots feeding an IIR, next to an independent FIR chain."""
    p = hts.Program("quickstart")
    frame = p.input(0x10, 4, "frame")
    fft = p.task("fft_256", in_=frame, out=4, tid=0)
    dots = p.region(3, name="dots")          # the three dot results, contiguous
    for i in range(3):
        p.task("vector_dot", in_=fft, out=dots.sub(i, 1), tid=1 + i)
    p.task("iir", in_=dots, out=3, tid=4)    # RAW-dependent on ALL three dots
    fir = p.task("real_fir", in_=frame, out=4, tid=5)
    p.task("real_fir", in_=fir, out=4, tid=6)
    return p


def main():
    program = build_program()

    print(f"{'scheduler':<12} {'cycles':>10} {'speedup':>8}")
    base = None
    for sched in hts.ALL_SCHEDULERS:
        r = hts.run(program, scheduler=sched, n_fu=2)
        if base is None:
            base = r
        print(f"{sched:<12} {r.cycles:>10} {r.speedup_vs(base):>8.2f}x")

    # the compiled JAX machine and the pure-Python golden oracle produce
    # identical schedules — run both backends and check
    jax_r = hts.run(program, scheduler="hts_spec", n_fu=2, backend="jax")
    gold_r = hts.run(program, scheduler="hts_spec", n_fu=2, backend="golden")
    assert jax_r.schedule == gold_r.schedule, "backends disagree!"
    print(f"\nbackends agree: jax == golden "
          f"({jax_r.cycles} cycles, {jax_r.n_tasks} tasks)\n")
    print(jax_r.table())


if __name__ == "__main__":
    main()
