"""Performance feature flags (§Perf hillclimbing: baseline vs optimized).

The paper-faithful/baseline lowering keeps all flags False; each hillclimb
iteration toggles one flag so EXPERIMENTS.md §Perf can record isolated
before/after roofline terms (hypothesis → change → measure → validate).
"""
from __future__ import annotations

import contextlib
import dataclasses


@dataclasses.dataclass
class PerfFlags:
    #: MoE: GShard-style grouped dispatch — per-sequence position cumsum
    #: (data-sharded, short) instead of one global replicated cumsum.
    moe_grouped: bool = False
    #: decode attention: grouped-query einsum without materializing the
    #: GQA-repeated (and fp32-cast) K/V cache.
    decode_gqa_packed: bool = False
    #: decode: shard the KV-cache sequence axis over "model" when kv_heads
    #: cannot shard there (requires rules override, see dryrun --opt).
    decode_kv_seq_shard: bool = False
    #: decode: int8 KV cache with per-(token, head) scales — halves cache
    #: bytes and cache-side collective traffic (transformer family,
    #: scalar-pos decode path).
    decode_kv_int8: bool = False


FLAGS = PerfFlags()


@contextlib.contextmanager
def use_flags(**kw):
    global FLAGS
    prev = FLAGS
    FLAGS = dataclasses.replace(prev, **kw)
    try:
        yield FLAGS
    finally:
        FLAGS = prev


def optimized(level: int = 1) -> dict:
    kw = dict(moe_grouped=True, decode_gqa_packed=True,
              decode_kv_seq_shard=True)
    if level >= 3:
        kw["decode_kv_int8"] = True
    return kw
