"""Distributed train-step builder + fault-tolerant training loop.

``make_train_step`` builds the pjit'd step: bf16 compute over fp32 master
params, optional gradient accumulation (microbatching), AdamW with
warmup-cosine schedule, metrics.  ``TrainLoop`` adds checkpoint/restart
(exact resume — data is (seed, step)-deterministic), async checkpointing,
retry-on-failure, and a straggler watchdog.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.checkpoint import ckpt as ckpt_lib
from repro.optim import adamw, schedule
from repro.sharding import rules as rules_lib

log = logging.getLogger("repro.train")


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: adamw.AdamWConfig = adamw.AdamWConfig()
    remat_policy: str = "nothing"
    warmup_steps: int = 100
    total_steps: int = 1000
    microbatches: int = 1            # gradient-accumulation factor
    ckpt_every: int = 50
    ckpt_keep: int = 3
    max_restarts: int = 3
    straggler_factor: float = 2.0    # step slower than factor×median → flagged


def init_state(model, key):
    params = model.init(key)
    return {"params": params, "opt": adamw.init(params)}


def abstract_state(model):
    params = model.abstract_params()
    opt = jax.eval_shape(adamw.init, params)
    return {"params": params, "opt": opt}


def state_pspecs(model, rules):
    p = model.param_pspecs(rules)
    return {"params": p,
            "opt": {"m": p, "v": p, "step": PartitionSpec()}}


def batch_pspecs(batch_tree, rules):
    def leaf(x):
        axes = ("batch",) + (None,) * (len(x.shape) - 1)
        return rules.spec_for(x.shape, axes)
    return jax.tree.map(leaf, batch_tree)


def make_train_step(model, tcfg: TrainConfig) -> Callable:
    """Pure train step: (state, batch) → (state, metrics)."""

    def loss_fn(params, batch):
        return model.train_loss(params, batch, tcfg.remat_policy)

    def train_step(state, batch):
        if tcfg.microbatches > 1:
            k = tcfg.microbatches

            def micro(carry, mb):
                acc, = carry
                loss, g = jax.value_and_grad(loss_fn)(state["params"], mb)
                acc = jax.tree.map(jnp.add, acc,
                                   jax.tree.map(lambda x: x / k, g))
                return (acc,), loss

            split = jax.tree.map(
                lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
            (grads,), losses = jax.lax.scan(micro, (zeros,), split)
            loss = losses.mean()
        else:
            loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)

        lr_scale = schedule.warmup_cosine(state["opt"]["step"],
                                          tcfg.warmup_steps, tcfg.total_steps)
        params, opt, metrics = adamw.update(grads, state["opt"],
                                            state["params"], tcfg.optimizer,
                                            lr_scale)
        metrics["loss"] = loss
        return {"params": params, "opt": opt}, metrics

    return train_step


def jit_train_step(model, mesh, rules, tcfg: TrainConfig, batch_tree):
    """pjit the train step with explicit in/out shardings."""
    sspec = state_pspecs(model, rules)
    bspec = batch_pspecs(batch_tree, rules)
    to_shard = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, PartitionSpec))
    step = make_train_step(model, tcfg)

    def wrapped(state, batch):
        with rules_lib.use_rules(rules):
            return step(state, batch)

    return jax.jit(
        wrapped,
        in_shardings=(to_shard(sspec), to_shard(bspec)),
        out_shardings=(to_shard(sspec), None),
        donate_argnums=(0,),
    )


class TrainLoop:
    """Fault-tolerant loop: restart-exact resume, async ckpt, stragglers."""

    def __init__(self, model, source, train_step, tcfg: TrainConfig,
                 ckpt_dir: str, init_fn: Callable[[], Any],
                 failure_injector: Optional[Callable[[int], None]] = None):
        self.model = model
        self.source = source
        self.train_step = train_step
        self.tcfg = tcfg
        self.ckpt_dir = ckpt_dir
        self.init_fn = init_fn
        self.failure_injector = failure_injector
        self.saver = ckpt_lib.AsyncCheckpointer(ckpt_dir, keep=tcfg.ckpt_keep)
        self.step_times: list[float] = []
        self.stragglers: list[int] = []
        self.restarts = 0
        self.history: list[dict] = []

    def _load_or_init(self):
        last = ckpt_lib.latest_step(self.ckpt_dir)
        if last is not None:
            template = jax.eval_shape(self.init_fn)
            state, _ = ckpt_lib.restore(self.ckpt_dir, template, last)
            log.info("restored step %d", last)
            return state, last + 1
        return self.init_fn(), 0

    def run(self, steps: int):
        state, start = self._load_or_init()
        step = start
        while step < steps:
            try:
                t0 = time.monotonic()
                if self.failure_injector is not None:
                    self.failure_injector(step)
                batch = self.source.batch(step)
                state, metrics = self.train_step(state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.monotonic() - t0
                self._watch(step, dt)
                self.history.append(
                    {"step": step,
                     **{k: float(v) for k, v in metrics.items()}})
                if (step + 1) % self.tcfg.ckpt_every == 0 or step + 1 == steps:
                    self.saver.save(step, state)
                step += 1
            except (ckpt_lib.json.JSONDecodeError, OSError):
                raise
            except RuntimeError as e:       # injected / device failure
                self.restarts += 1
                log.warning("step %d failed (%s); restart %d", step, e,
                            self.restarts)
                if self.restarts > self.tcfg.max_restarts:
                    raise
                self.saver.wait()
                state, step = self._load_or_init()
        self.saver.wait()
        return state

    def _watch(self, step: int, dt: float):
        self.step_times.append(dt)
        if len(self.step_times) >= 8:
            med = sorted(self.step_times)[len(self.step_times) // 2]
            if dt > self.tcfg.straggler_factor * med:
                self.stragglers.append(step)
                log.warning("straggler: step %d took %.3fs (median %.3fs)",
                            step, dt, med)
