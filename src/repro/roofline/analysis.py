"""Roofline-term derivation from a compiled dry-run artifact (deliverable g).

Hardware model: TPU v5e —
    197 TFLOP/s bf16 per chip, 819 GB/s HBM per chip, ~50 GB/s per ICI link.

Terms (assignment §ROOFLINE ANALYSIS):
    compute    = global_FLOPs    / (chips × peak)
    memory     = global_bytes    / (chips × hbm_bw)
    collective = global_coll_bytes / (chips × link_bw)

``cost_analysis()`` on a post-SPMD executable reports *per-device* flops and
bytes; we scale by chip count to the global figures so the assignment's
formulas apply unchanged (verified in tests/test_roofline.py against a
hand-counted matmul).  MODEL_FLOPS = 6·N·D (6·N_active·D for MoE) catches
remat/redundancy waste via the MODEL_FLOPS / HLO_FLOPs ratio.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / link


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_global: float
    bytes_global: float
    collective_global: float
    collective_per_op: dict[str, int]
    model_flops: float
    peak_bytes_per_device: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.flops_global / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.bytes_global / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.collective_global / (self.chips * LINK_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        return self.model_flops / max(self.flops_global, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Dominant-term share: ideal step time (max term) over sum — how
        close the op mix is to being limited by a single roof."""
        ts = [self.t_compute, self.t_memory, self.t_collective]
        return max(ts) / max(sum(ts), 1e-30)

    def to_dict(self) -> dict[str, Any]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_global": self.flops_global,
            "bytes_global": self.bytes_global,
            "collective_global": self.collective_global,
            "collective_per_op": self.collective_per_op,
            "model_flops": self.model_flops,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "peak_bytes_per_device": self.peak_bytes_per_device,
        }


def model_flops(param_count: int, tokens: int, kind: str,
                active_ratio: float = 1.0) -> float:
    """6·N·D for a train step (fwd+bwd); 2·N·D for pure forward/decode."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * param_count * active_ratio * tokens


def from_compiled(arch: str, shape: str, mesh_name: str, chips: int,
                  cost: dict, coll: dict, mflops: float,
                  mem_stats: Optional[dict] = None) -> Roofline:
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_global=flops_dev * chips,
        bytes_global=bytes_dev * chips,
        collective_global=float(coll["total_per_device"]) * chips,
        collective_per_op=dict(coll["per_op"]),
        model_flops=mflops,
        peak_bytes_per_device=(mem_stats or {}).get("peak_bytes"),
    )
