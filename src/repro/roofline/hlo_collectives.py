"""Collective-traffic extraction from optimized (post-SPMD) HLO text.

``cost_analysis()`` does not report collective bytes, so we parse
``compiled.as_text()`` — the per-device program — and sum the result-shape
bytes of every collective op, by kind.  Shapes in post-SPMD HLO are
*per-device* shapes; ``collective_bytes`` in the roofline table is the global
figure (per-device × chips) so the assignment's
``collective_bytes / (chips × link_bw)`` formula reduces to per-device bytes
over link bandwidth.
"""
from __future__ import annotations

import re
from typing import Any

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# one HLO instruction per line:  %name = <result-type> <op-name>(...)
_LINE = re.compile(
    r"=\s*(.*?)\s(" + "|".join(re.escape(op) for op in COLLECTIVE_OPS)
    + r")(?:-(?:start|done))?[.\s(]")
_SHAPE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes_per_device(hlo_text: str) -> dict[str, Any]:
    """Sum per-device result bytes of every collective op, by kind.

    Handles tuple results (multi-operand all-reduce) by summing every
    dtype[dims] in the result type.  ``-start``/``-done`` async pairs are
    counted once (the -done result duplicates the -start; we skip -done).
    """
    out = {op: 0 for op in COLLECTIVE_OPS}
    count = {op: 0 for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        if "-done" in line or " fusion(" in line:
            # async -done duplicates the -start result shape
            if not any(op + "-start" in line or op + "(" in line
                       for op in COLLECTIVE_OPS):
                continue
            if any(op + "-done" in line for op in COLLECTIVE_OPS):
                continue
        m = _LINE.search(line)
        if not m:
            continue
        result_type, op = m.group(1), m.group(2)
        nbytes = sum(_shape_bytes(d, s) for d, s in _SHAPE.findall(result_type))
        out[op] += nbytes
        count[op] += 1
    total = sum(out.values())
    return {"per_op": out, "counts": count, "total_per_device": total}
