"""Render the EXPERIMENTS.md roofline table from experiments/dryrun JSONs.

    PYTHONPATH=src python -m repro.roofline.report experiments/dryrun
"""
from __future__ import annotations

import json
import os
import sys


def _fmt(x, unit=""):
    if x is None:
        return "-"
    for s, d in (("P", 1e15), ("T", 1e12), ("G", 1e9), ("M", 1e6), ("k", 1e3)):
        if abs(x) >= d:
            return f"{x / d:.2f}{s}{unit}"
    return f"{x:.2f}{unit}"


def load(dirpath: str, mesh: str = "single") -> list[dict]:
    recs = []
    for fn in sorted(os.listdir(dirpath)):
        if fn.endswith(f"_{mesh}.json"):
            with open(os.path.join(dirpath, fn)) as f:
                recs.append(json.load(f))
    return recs


def table(recs: list[dict]) -> str:
    rows = ["| arch | shape | status | t_comp (s) | t_mem (s) | t_coll (s) | "
            "bottleneck | max/Σ | MODEL/HLO | HLO flops (global) | coll bytes |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] == "SKIP":
            rows.append(f"| {r['arch']} | {r['shape']} | SKIP | - | - | - | "
                        f"- | - | - | - | - |")
            continue
        if r["status"] != "OK":
            rows.append(f"| {r['arch']} | {r['shape']} | FAIL | - | - | - | "
                        f"- | - | - | - | - |")
            continue
        rf = r["roofline"]
        ts = [rf["t_compute"], rf["t_memory"], rf["t_collective"]]
        frac = max(ts) / max(sum(ts), 1e-30)
        rows.append(
            f"| {r['arch']} | {r['shape']} | OK "
            f"| {rf['t_compute']:.3g} | {rf['t_memory']:.3g} "
            f"| {rf['t_collective']:.3g} | **{rf['bottleneck']}** "
            f"| {frac:.2f} | {rf['useful_flops_ratio']:.2f} "
            f"| {_fmt(rf['flops_global'])} "
            f"| {_fmt(rf['collective_global'], 'B')} |")
    return "\n".join(rows)


def summarize(recs: list[dict]) -> str:
    ok = [r for r in recs if r["status"] == "OK"]
    skip = [r for r in recs if r["status"] == "SKIP"]
    fail = [r for r in recs if r["status"] not in ("OK", "SKIP")]
    lines = [f"cells: {len(recs)}  OK: {len(ok)}  SKIP: {len(skip)}  "
             f"FAIL: {len(fail)}"]
    if ok:
        by_frac = sorted(
            ok, key=lambda r: (max(r['roofline'][k] for k in
                                   ('t_compute', 't_memory', 't_collective'))
                               / max(sum(r['roofline'][k] for k in
                                         ('t_compute', 't_memory',
                                          't_collective')), 1e-30)))
        w = by_frac[0]
        lines.append(f"worst roofline fraction: {w['arch']} × {w['shape']}")
        by_coll = sorted(ok, key=lambda r: -(r['roofline']['t_collective']
                                             / max(sum((r['roofline']['t_compute'],
                                                        r['roofline']['t_memory'],
                                                        r['roofline']['t_collective'])),
                                                   1e-30)))
        c = by_coll[0]
        lines.append(f"most collective-bound: {c['arch']} × {c['shape']}")
    return "\n".join(lines)


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    mesh = sys.argv[2] if len(sys.argv) > 2 else "single"
    recs = load(d, mesh)
    print(table(recs))
    print()
    print(summarize(recs))


if __name__ == "__main__":
    main()
