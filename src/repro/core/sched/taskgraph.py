"""Generic task-DAG scheduling with the HTS policy (paper → runtime layer).

This is the cycle-accurate machine's scheduling *policy* (dependency-driven,
out-of-order, age-priority issue to free units) lifted to an abstract task
graph, so the framework can use it to schedule real work: pipeline-parallel
microbatch×stage grids (pipeline.py) and serving slots (serving.py).

``schedule(..., policy="inorder")`` reproduces the paper's *Naive* baseline at
this level (issue strictly in submission order, one task at a time);
``policy="ooo"`` is the HTS policy.  The makespan gap between the two is the
paper's core claim, now visible in runtime schedules.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class Task:
    uid: int
    cls: str                    # resource class ("stage3", "fft", "slot", …)
    duration: float
    deps: tuple[int, ...] = ()
    tag: Optional[tuple] = None


@dataclasses.dataclass(frozen=True)
class Placement:
    uid: int
    cls: str
    unit: int
    start: float
    end: float
    tag: Optional[tuple] = None


@dataclasses.dataclass
class Schedule:
    placements: list[Placement]
    makespan: float

    def by_unit(self) -> dict[tuple[str, int], list[Placement]]:
        out: dict[tuple[str, int], list[Placement]] = {}
        for p in self.placements:
            out.setdefault((p.cls, p.unit), []).append(p)
        return out

    def order(self) -> list[int]:
        return [p.uid for p in sorted(self.placements,
                                      key=lambda p: (p.start, p.uid))]


def schedule(tasks: Sequence[Task], units: dict[str, int],
             policy: str = "ooo") -> Schedule:
    """Event-driven list scheduling under the HTS policy.

    ooo:     any ready task may issue to a free unit of its class, oldest
             (submission order) first — the reservation-station policy.
    inorder: a task may only issue when every earlier-submitted task has
             completed (the paper's Naive CPU-driven dispatch).
    """
    assert policy in ("ooo", "inorder")
    by_uid = {t.uid: t for t in tasks}
    submit_rank = {t.uid: i for i, t in enumerate(tasks)}
    indeg = {t.uid: 0 for t in tasks}
    children: dict[int, list[int]] = {t.uid: [] for t in tasks}
    for t in tasks:
        for d in t.deps:
            indeg[t.uid] += 1
            children[d].append(t.uid)

    free: dict[str, list[int]] = {c: list(range(n)) for c, n in units.items()}
    ready = [ (submit_rank[t.uid], t.uid) for t in tasks if indeg[t.uid] == 0 ]
    heapq.heapify(ready)
    running: list[tuple[float, int, int, int]] = []   # (end, rank, uid, unit)
    done: set[int] = set()
    completed_upto = -1          # for inorder: highest contiguous done rank
    placements: list[Placement] = []
    now = 0.0

    def can_issue(uid: int) -> bool:
        if policy == "inorder":
            return submit_rank[uid] == completed_upto + 1
        return True

    pending_done: set[int] = set()
    while len(done) < len(tasks):
        # issue everything issuable at `now`
        progressed = True
        while progressed:
            progressed = False
            deferred = []
            while ready:
                rank, uid = heapq.heappop(ready)
                t = by_uid[uid]
                if can_issue(uid) and free.get(t.cls):
                    unit = free[t.cls].pop(0)
                    end = now + t.duration
                    heapq.heappush(running, (end, rank, uid, unit))
                    placements.append(Placement(uid, t.cls, unit, now, end,
                                                t.tag))
                    progressed = True
                else:
                    deferred.append((rank, uid))
                if policy == "inorder":
                    break        # at most one outstanding task
            for item in deferred:
                heapq.heappush(ready, item)
            if policy == "inorder":
                break
        if not running:
            if len(done) < len(tasks):
                raise ValueError("deadlock: cyclic dependencies or missing "
                                 "resource class")
            break
        # advance to next completion
        end, rank, uid, unit = heapq.heappop(running)
        now = end
        t = by_uid[uid]
        free[t.cls].append(unit)
        free[t.cls].sort()
        done.add(uid)
        pending_done.add(submit_rank[uid])
        while completed_upto + 1 in pending_done:
            completed_upto += 1
        for ch in children[uid]:
            indeg[ch] -= 1
            if indeg[ch] == 0:
                heapq.heappush(ready, (submit_rank[ch], ch))

    return Schedule(placements, max((p.end for p in placements), default=0.0))
