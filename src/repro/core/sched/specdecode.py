"""Speculative decoding — the paper's speculative task execution mapped onto
serving (DESIGN.md §3).

Correspondence with the HTS mechanism (paper §IV-C3):

  draft tokens            ↔ speculative tasks (predicted not-taken path)
  KV-cache tail ≥ pos     ↔ Transactional Memory region (TLB-remapped outputs)
  target verify chunk     ↔ branch resolution (the BR read on the CDB)
  accepted prefix commit  ↔ TLB mappings retained on correct speculation
  pointer rollback        ↔ TLB entry discard on mis-speculation — the stale
                            K/V beyond the accept point is dead by masking
                            and overwritten by the next chunk, exactly like
                            discarded TM regions.

Greedy self-consistent variant: the emitted stream provably equals plain
greedy decoding of the target model (tested in tests/test_sched.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T


@dataclasses.dataclass
class SpecStats:
    proposed: int = 0
    accepted: int = 0
    chunks: int = 0

    @property
    def acceptance(self) -> float:
        return self.accepted / max(self.proposed, 1)


def greedy_decode(model, params, prompt: np.ndarray, n_new: int,
                  max_len: int) -> np.ndarray:
    """Plain greedy decoding baseline (token-at-a-time)."""
    cfg = model.cfg
    B, P = prompt.shape
    cache = model.init_cache(B, max_len)
    step = jax.jit(model.decode_step)
    toks = jnp.asarray(prompt)
    out = []
    cur = toks[:, :1]
    for t in range(P + n_new - 1):
        logits, cache = step(params, cache, cur, jnp.int32(t))
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        cur = toks[:, t + 1:t + 2] if t + 1 < P else nxt
        if t + 1 >= P:
            out.append(cur[:, 0])
    return np.stack([np.asarray(o) for o in out], axis=1)


def speculative_decode(target, t_params, draft, d_params, prompt: np.ndarray,
                       n_new: int, k: int, max_len: int
                       ) -> tuple[np.ndarray, SpecStats]:
    """Greedy speculative decoding (draft k, verify 1 chunk, rollback).

    ``target``/``draft`` are transformer-family Models (draft is typically a
    reduced-depth config).  Returns (generated tokens (B, n_new), stats).
    """
    t_cfg, d_cfg = target.cfg, draft.cfg
    B, P = prompt.shape
    assert B == 1, "spec-decode path is per-sequence (slots batch upstream)"
    t_cache = target.init_cache(B, max_len)
    d_cache = draft.init_cache(B, max_len)
    d_step = jax.jit(draft.decode_step)
    t_chunk = jax.jit(
        lambda p, c, tok, pos: T.chunk_step(p, t_cfg, c, tok, pos))

    toks = list(np.asarray(prompt[0]))
    # prefill both models via chunk scoring (target) / stepping (draft)
    t_logits, t_cache = t_chunk(t_params, t_cache,
                                jnp.asarray([toks]), jnp.int32(0))
    for i in range(P):
        _, d_cache = d_step(d_params, d_cache,
                            jnp.asarray([[toks[i]]]), jnp.int32(i))
    next_tok = int(np.argmax(np.asarray(t_logits[0, -1])))

    stats = SpecStats()
    generated = [next_tok]
    # Invariant at loop top: caches hold K/V for positions [0, pos);
    # sequence[pos] = generated[-1] = next_tok (K/V not yet written — it is
    # chunk[0] of the next verify, or the first draft feed).
    pos = P
    d_pos = P
    while len(generated) < n_new:
        # --- draft proposes k tokens (speculative tasks; dc is scratch = TM)
        proposal = []
        cur = next_tok
        dc = d_cache
        for j in range(k):
            lg, dc = d_step(d_params, dc, jnp.asarray([[cur]]),
                            jnp.int32(d_pos + j))
            cur = int(np.argmax(np.asarray(lg[0, -1])))
            proposal.append(cur)
        # --- target verifies chunk = [next_tok, proposal[:-1]] (branch resolve)
        chunk = [next_tok] + proposal[:-1]
        lg, t_cache = t_chunk(t_params, t_cache, jnp.asarray([chunk]),
                              jnp.int32(pos))
        argmax = [int(a) for a in np.asarray(jnp.argmax(lg[0], axis=-1))]
        # accepted = target tokens up to and including the first mismatch
        m = k - 1
        for j in range(k):
            if proposal[j] != argmax[j]:
                m = j
                break
        accepted = argmax[:m + 1]
        stats.chunks += 1
        stats.proposed += k
        stats.accepted += sum(1 for j in range(m + 1)
                              if proposal[j] == argmax[j])
        generated.extend(accepted)
        # --- commit/rollback: pointer advances by |accepted|; chunk K/V
        #     beyond it is dead by masking and overwritten next round (the
        #     paper's TM discard on mis-speculation).
        replay = [next_tok] + accepted[:-1]     # sequence[d_pos : pos+|acc|]
        for j, tk in enumerate(replay):
            _, d_cache = d_step(d_params, d_cache, jnp.asarray([[tk]]),
                                jnp.int32(d_pos + j))
        pos += len(accepted)
        d_pos += len(replay)
        next_tok = generated[-1]

    return np.asarray([generated[:n_new]]), stats
