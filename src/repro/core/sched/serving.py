"""Continuous-batching serving engine scheduled HTS-style (DESIGN.md §3).

Mapping from the paper's scheduler to a model server:

  decode slots (batch lanes)   ↔ accelerator functional units
  slot busy bitmap             ↔ Accelerator Status Register (ASR)
  request queue                ↔ Task Queue
  admission of a request       ↔ Task Dispatch (out-of-order: any free slot
                                 takes the oldest *ready* request — requests
                                 have no inter-dependencies, the common case)
  finished-request retirement  ↔ CDB completion broadcast
  "naive" mode                 ↔ the paper's Naive baseline: the whole batch
                                 is drained before new requests are admitted
                                 (static batching) — throughput gap asserted
                                 in tests/test_sched.py.

The engine drives the jitted ``decode_step`` of any registry Model; prompts
are absorbed token-by-token into the slot's cache lane (chunked prefill is a
recorded follow-up).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class ServeStats:
    steps: int = 0
    slot_busy_steps: int = 0
    completed: int = 0

    def utilization(self, n_slots: int) -> float:
        return self.slot_busy_steps / max(self.steps * n_slots, 1)


class Server:
    """Slot-based continuous batching over a single jitted decode step."""

    def __init__(self, model, params, n_slots: int, max_len: int,
                 policy: str = "ooo", eos: Optional[int] = None):
        assert policy in ("ooo", "naive")
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.policy = policy
        self.eos = eos
        self.cache = model.init_cache(n_slots, max_len)
        self.step_fn = jax.jit(model.decode_step)
        # ASR: per-slot state
        self.busy = [False] * n_slots            # the ASR bitmap
        self.slot_req: list[Optional[Request]] = [None] * n_slots
        self.slot_pos = [0] * n_slots            # per-slot sequence position
        self.slot_feed = [0] * n_slots           # next prompt index to feed
        self.queue: list[Request] = []
        self.stats = ServeStats()

    # -- task queue ---------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        if self.policy == "naive" and any(self.busy):
            return                                # drain before re-admission
        for s in range(self.n_slots):
            if not self.busy[s] and self.queue:
                req = self.queue.pop(0)
                self.busy[s] = True               # ASR set
                self.slot_req[s] = req
                self.slot_pos[s] = 0
                self.slot_feed[s] = 0
                self._reset_slot_cache(s)

    def _reset_slot_cache(self, s: int):
        def zero_lane(leaf, axes):
            bdim = axes.index("cache_batch")
            idx = [slice(None)] * leaf.ndim
            idx[bdim] = s
            return leaf.at[tuple(idx)].set(0)
        self.cache = jax.tree.map(
            zero_lane, self.cache, self.model.cache_axes)

    # -- one engine step: feed every busy slot one token --------------------
    def step(self):
        self._admit()
        self.stats.steps += 1
        active = [s for s in range(self.n_slots) if self.busy[s]]
        if not active:
            return
        self.stats.slot_busy_steps += len(active)
        feed = np.zeros((self.n_slots, 1), np.int32)
        for s in active:
            req = self.slot_req[s]
            if self.slot_feed[s] < len(req.prompt):
                feed[s, 0] = req.prompt[self.slot_feed[s]]
            else:
                feed[s, 0] = req.out[-1]
        # transformer-family decode supports per-lane positions (true
        # continuous batching); other families fall back to a uniform pos
        # (their tests submit equal-length requests).
        if self.model.cfg.family in ("dense", "moe", "vlm"):
            pos = jnp.asarray([self.slot_pos[s] for s in
                               range(self.n_slots)], jnp.int32)
        else:
            pos = jnp.int32(max(self.slot_pos[s] for s in active))
        logits, self.cache = self.step_fn(self.params, self.cache,
                                          jnp.asarray(feed), pos)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        for s in active:
            req = self.slot_req[s]
            self.slot_pos[s] += 1
            if self.slot_feed[s] < len(req.prompt):
                self.slot_feed[s] += 1
                if self.slot_feed[s] == len(req.prompt):
                    req.out.append(int(nxt[s]))
            else:
                req.out.append(int(nxt[s]))
            done = (len(req.out) >= req.max_new
                    or (self.eos is not None and req.out
                        and req.out[-1] == self.eos)
                    or self.slot_pos[s] >= self.max_len - 1)
            if done and len(req.out) > 0 and self.slot_feed[s] >= len(req.prompt):
                req.done = True                   # CDB retirement
                self.busy[s] = False              # ASR clear
                self.slot_req[s] = None
                self.stats.completed += 1

    def run(self, max_steps: int = 10_000) -> ServeStats:
        while (self.queue or any(self.busy)) and self.stats.steps < max_steps:
            self.step()
        return self.stats
