"""Golden cycle-accurate HTS simulator (pure Python oracle).

This module pins down the *exact* cycle-level semantics of the Hardware Task
Scheduler; ``machine.py`` re-implements the same semantics as a compiled JAX
``lax.while_loop`` program and is tested for schedule-level equivalence against
this oracle (tests/test_hts_equivalence.py, incl. hypothesis-generated
programs).

Within-cycle phase order (both simulators MUST follow it exactly):

  1. FU tick            — busy accelerators count down; on reaching 0 the task's
                          result is written to memory and a completion record is
                          queued for the CDB (ticket = completion order), the
                          accelerator is freed (ASR busy bit cleared).
  2. memread tick       — the pseudo-unit spawned by an MR branch counts down.
  3. CDB grant          — up to ``cdb_width`` queued completions whose
                          ``ready_cycle`` has arrived broadcast in ticket order:
                          RS dependencies wake, Memory-Tracker entries retire,
                          a BR branch waiting on this uid becomes resolvable.
  4. branch resolve     — evaluate condition; on speculation: commit (retain TLB
                          mappings) or squash (discard TLB, abort speculative
                          tasks, redirect PC).  Non-speculative stalls unblock.
  5. RS issue           — ready reservation-station entries issue to idle
                          accelerators of their class, up to ``issue_width``
                          per cycle.  Order is the policy's issue key:
                          priority class first (per-pid weight, higher wins),
                          age within a class; a pid at its per-class FU quota
                          is masked out without consuming the unit
                          (``policy.SchedPolicy``; all-default = pure age
                          order, the paper's arbiter).  Unit selection
                          within the class: greedy = lowest free index;
                          ``issue_mode="eft"`` = earliest predicted finish
                          under the per-(class, unit) cost tables
                          (``HtsParams.fu_cost``), ties to lowest index.
  6. frontend           — the frontend *arbiter* grants one eligible dispatch
                          stream (per-tenant frontends, ``frontend.py``) and
                          fetch/decode/dispatches its next instruction (tasks
                          allocate RS + tracker + optionally TLB/TM; control
                          instructions execute on the scheduler's GPRs).  A
                          stream is eligible when it has arrived (``cycle >=
                          arrival``), is not drained, its decode window is
                          free, it is not stalled on its own unresolved
                          branch, and its next instruction can act — a TASK
                          blocked on a full RS / full tracker / its pid's RS
                          admission cap (``policy.rs_caps``) makes the stream
                          ineligible, so the arbiter skips it and the stall
                          backpressures *that tenant only*.  Arbitration is
                          round-robin over eligible streams; with
                          ``SchedPolicy(fe_mode="weighted")`` a stream's pid
                          weight ranks first (round-robin within a class).
                          One branch unit and one speculation domain are
                          shared: while a speculation is open only the
                          speculating stream is granted.  The default single
                          stream covering the whole program reproduces the
                          historical merged in-order frontend bit-for-bit.
  7. halt check / cycle++

Memory-value semantics: the simulator tracks *scheduling*, not DSP math — as in
the paper's Python model.  Task outputs take their values from a benchmark-
provided ``effects`` image: completing a task copies
``effect_mem[orig_out + i] → mem[phys_out + i]``.  Branch conditions read
``mem`` (TLB-remapped), so benchmarks control taken/not-taken outcomes by
seeding ``mem_init`` / ``effects``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from . import isa
from .costs import (FUNC_CYCLES, MEM_READ_CYCLES, NUM_FUNCS, SchedulerCosts,
                    norm_fu_cost)
from .policy import AGE_SPAN, NUM_PIDS, PRIO_CAP, SchedPolicy

# ---------------------------------------------------------------------------
# Capacities (design-time parameters of the HTS, paper §IV-C)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class HtsParams:
    num_regs: int = 32          # GPR bank size
    mem_words: int = 1024       # main memory image (region address space used)
    rs_entries: int = 32        # reservation-station capacity (instruction window)
    tracker_entries: int = 64   # Memory Tracker capacity
    tlb_entries: int = 16       # Task Lookup Buffer capacity
    tm_slots: int = 16          # Transactional Memory slots
    tm_slot_words: int = 16     # words per TM slot
    tlb_drain_cycles: int = 20  # cost to drain one committed TLB entry (TM→mem)
    mem_read_cycles: int = MEM_READ_CYCLES
    max_tasks: int = 1024       # schedule-trace capacity
    #: CDB completion-queue capacity.  ``None`` = ``max_tasks`` (can never
    #: bind).  The golden oracle's queue is unbounded either way; in the
    #: compiled machine an exceeded capacity raises the ``overflow`` flag
    #: (a loud refusal, like a uid overflow), and a right-sized value
    #: shrinks the per-step state the population batch pays for.
    cdb_entries: Optional[int] = None
    n_fu: tuple[int, ...] = (1,) * NUM_FUNCS   # units per function class
    policy: SchedPolicy = SchedPolicy()        # per-pid weights + FU quotas
    #: per-(class, unit) execution-latency multipliers — heterogeneous FU
    #: instances within a class.  Hashable tuple-of-rows form (build with
    #: ``costs.fu_cost_tuple``); ``None`` = every unit identical (cost 1),
    #: the paper's machine.  Unit ``u`` of class ``c`` executes a task in
    #: ``FUNC_CYCLES[c] * fu_cost[c][u]`` cycles.
    fu_cost: Optional[tuple] = None

    @property
    def tm_base(self) -> int:
        return self.mem_words

    @property
    def total_mem(self) -> int:
        return self.mem_words + self.tm_slots * self.tm_slot_words


@dataclasses.dataclass
class TaskRecord:
    uid: int
    func: int
    dispatch_cycle: int
    issue_cycle: int = -1
    complete_cycle: int = -1
    broadcast_cycle: int = -1
    dep_uid: int = 0
    is_spec: bool = False
    aborted: bool = False
    pid: int = 0                # owning process (ISA pid field, multi-tenant)
    #: flattened FU-pool index the task executed on (-1 = never issued).
    #: Oracle-only instrumentation for the EFT invariant tests — NOT part
    #: of ``schedule_tuple`` (the machine does not record it).
    unit: int = -1


@dataclasses.dataclass
class Result:
    cycles: int
    tasks: list[TaskRecord]
    mem: np.ndarray
    regs: np.ndarray
    fu_busy_cycles: np.ndarray          # (total_fus,)
    spec_aborted: int
    stall_cycles: int
    halted: bool                        # False ⇒ hit max_cycles (bug or livelock)
    #: per-stream dispatch-stall cycles: cycles a stream had arrived and
    #: still held undispatched instructions but was not granted the
    #: frontend (single merged stream ⇒ one entry).
    fe_stall: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(1, dtype=np.int64))

    def schedule_tuple(self):
        """Canonical tuple for equivalence testing against the JAX machine."""
        return [(t.uid, t.func, t.dispatch_cycle, t.issue_cycle,
                 t.complete_cycle, t.broadcast_cycle, t.aborted, t.pid)
                for t in self.tasks]


class _RS:
    __slots__ = ("uid", "func", "dep_uid", "age", "out_s", "out_e", "src_s",
                 "exec_cycles", "is_spec", "pid")

    def __init__(self, uid, func, dep_uid, age, out_s, out_e, src_s,
                 exec_cycles, is_spec, pid=0):
        self.uid, self.func, self.dep_uid, self.age = uid, func, dep_uid, age
        self.out_s, self.out_e, self.src_s = out_s, out_e, src_s
        self.exec_cycles, self.is_spec, self.pid = exec_cycles, is_spec, pid


def run(code: np.ndarray,
        costs: SchedulerCosts,
        params: HtsParams = HtsParams(),
        mem_init: Optional[dict[int, int]] = None,
        effects: Optional[dict[int, int]] = None,
        max_cycles: int = 5_000_000,
        streams: Optional[np.ndarray] = None) -> Result:
    """Execute ``code`` under scheduler cost model ``costs``; return the schedule.

    ``streams`` is the per-tenant frontend table — (n_streams, 4) int32 rows
    of ``frontend.STREAM_FIELDS`` (start, end, arrival, weight).  ``None``
    (the default) is the historical single merged in-order frontend covering
    the whole program.
    """
    tbl = isa.decode_table(code)
    P = len(tbl)
    p = params

    if streams is None:
        streams = np.asarray([[0, P, 0, 0]], dtype=np.int64)
    else:
        streams = np.asarray(streams, dtype=np.int64)
    NS = len(streams)
    s_start = [int(x) for x in streams[:, 0]]
    s_end = [int(x) for x in streams[:, 1]]
    s_arr = [int(x) for x in streams[:, 2]]
    s_w = [min(max(int(x), 0), PRIO_CAP) for x in streams[:, 3]]
    s_active = [s_end[i] > s_start[i] for i in range(NS)]

    regs = np.zeros(p.num_regs, dtype=np.int64)
    mem = np.zeros(p.total_mem, dtype=np.int64)
    effect_mem = np.zeros(p.total_mem, dtype=np.int64)
    for k, v in (mem_init or {}).items():
        mem[k] = v
    for k, v in (effects or {}).items():
        effect_mem[k] = v

    pcs = list(s_start)                # per-stream program counters
    fe_waits = [0] * NS                # per-stream decode windows
    fe_ptr = 0                         # frontend round-robin pointer
    fe_stall = np.zeros(NS, dtype=np.int64)
    cycle = 0
    next_uid = 1
    age_ctr = 0
    ticket_ctr = 0
    stall_cycles = 0
    spec_aborted = 0

    rs: list[_RS] = []
    # FU pool: flattened (class, unit) with existence from n_fu.  Each unit
    # carries its latency multiplier from the per-(class, unit) cost table
    # (all ones unless params.fu_cost makes the pool heterogeneous).
    _ct = norm_fu_cost(p.fu_cost, width=max((16,) + tuple(p.n_fu)))
    fu_cls: list[int] = []
    fu_cost: list[int] = []
    for c in range(NUM_FUNCS):
        fu_cls.extend([c] * p.n_fu[c])
        fu_cost.extend(int(_ct[c, u]) for u in range(p.n_fu[c]))
    n_total_fu = len(fu_cls)
    fu_busy = [False] * n_total_fu
    fu_uid = [0] * n_total_fu
    fu_rem = [0] * n_total_fu
    fu_pid = [0] * n_total_fu          # owning pid while busy (quota accounting)
    fu_meta: list[Optional[tuple]] = [None] * n_total_fu  # (out_s,out_e,src_s,is_spec)
    fu_busy_cycles = np.zeros(n_total_fu, dtype=np.int64)

    # scheduling policy: per-pid priority weights and per-class FU quotas.
    # The arbiter orders ready RS entries by the scalar issue key
    # (priority class first, age within class) — see policy.SchedPolicy.
    _wt = p.policy.weight_array(NUM_PIDS).astype(np.int64)
    _qt = p.policy.quota_array(NUM_PIDS).astype(np.int64)
    _rc = p.policy.rs_cap_array(NUM_PIDS).astype(np.int64)
    _eft = p.policy.issue_mode == "eft"

    tracker: list[dict] = []          # {s, e, uid, is_spec}
    tlb: list[dict] = []              # {os, oe, tm_s, spec, committed, seq}
    tlb_seq = 0
    tm_free = list(range(p.tm_slots))
    cdb: list[dict] = []              # {uid, ticket, ready, is_spec}
    memread_active = False
    memread_rem = 0

    # branch bookkeeping (one shared branch unit; ``stream`` owns it)
    br: Optional[dict] = None         # {kind, pc, off, cond, thr, addr, wait_uid,
    #                                    speculating, stream}
    spec_active = False
    spec_regs_ckpt: Optional[np.ndarray] = None   # GPR checkpoint at spec entry

    tasks: list[TaskRecord] = []
    by_uid: dict[int, TaskRecord] = {}

    def remap(addr: int) -> int:
        """TLB remap of a physical read address (latest matching entry wins)."""
        best = None
        for e in tlb:
            if e["os"] <= addr < e["oe"]:
                if best is None or e["seq"] > best["seq"]:
                    best = e
        if best is None:
            return addr
        return p.tm_base + best["tm_s"] * p.tm_slot_words + (addr - best["os"])

    def tracker_lookup(s: int, e: int) -> int:
        """Latest in-flight producer overlapping [s, e); 0 if none."""
        best = 0
        for t in tracker:
            if t["s"] < e and s < t["e"]:
                best = max(best, t["uid"])
        return best

    def eval_cond(cond: int, v: int, thr: int) -> bool:
        if cond == isa.CND_EQ:
            return v == thr
        if cond == isa.CND_NEQ:
            return v != thr
        if cond == isa.CND_GE:
            return v >= thr
        return v <= thr

    def machine_empty() -> bool:
        return (not rs and not any(fu_busy) and not cdb
                and not memread_active and br is None)

    while cycle < max_cycles:
        # ---- 1. FU tick ------------------------------------------------
        for i in range(n_total_fu):
            if fu_busy[i]:
                fu_busy_cycles[i] += 1
                fu_rem[i] -= 1
                if fu_rem[i] == 0:
                    out_s, out_e, src_s, is_spec = fu_meta[i]
                    for j in range(out_e - out_s):
                        mem[out_s + j] = effect_mem[src_s + j]
                    cdb.append({"uid": fu_uid[i], "ticket": ticket_ctr,
                                "ready": cycle + costs.completion_extra,
                                "is_spec": is_spec})
                    ticket_ctr += 1
                    by_uid[fu_uid[i]].complete_cycle = cycle
                    fu_busy[i] = False
                    fu_uid[i] = 0

        # ---- 2. memread tick -------------------------------------------
        br_value_ready = False
        if memread_active:
            memread_rem -= 1
            if memread_rem == 0:
                memread_active = False
                br_value_ready = True

        # ---- 3. CDB grant ----------------------------------------------
        granted = 0
        while granted < costs.cdb_width:
            ready = [e for e in cdb if e["ready"] <= cycle]
            if not ready:
                break
            e = min(ready, key=lambda x: x["ticket"])
            cdb.remove(e)
            granted += 1
            uid = e["uid"]
            by_uid[uid].broadcast_cycle = cycle
            for r in rs:
                if r.dep_uid == uid:
                    r.dep_uid = 0
            tracker[:] = [t for t in tracker if t["uid"] != uid]
            if br is not None and br["kind"] == isa.BR_BR and br["wait_uid"] == uid:
                br_value_ready = True

        # ---- 4. branch resolve -------------------------------------------
        if br is not None and br_value_ready:
            value = int(mem[remap(br["addr"])])
            taken = eval_cond(br["cond"], value, br["thr"])
            target = br["pc"] + (br["off"] if taken else 1)
            if br["speculating"]:
                if not taken:          # predicted not-taken → correct
                    for t in tlb:
                        if not t["committed"]:
                            t["committed"] = True
                    for t in tracker:
                        t["is_spec"] = False
                    for r in rs:
                        r.is_spec = False
                    for i in range(n_total_fu):
                        if fu_busy[i] and fu_meta[i][3]:
                            fu_meta[i] = fu_meta[i][:3] + (False,)
                    for e in cdb:
                        e["is_spec"] = False
                else:                  # mis-speculation → squash
                    for t in tlb:
                        if not t["committed"]:
                            tm_free.append(t["tm_s"])
                    tlb[:] = [t for t in tlb if t["committed"]]
                    tracker[:] = [t for t in tracker if not t["is_spec"]]
                    for r in rs:
                        if r.is_spec:
                            by_uid[r.uid].aborted = True
                            spec_aborted += 1
                    rs[:] = [r for r in rs if not r.is_spec]
                    for i in range(n_total_fu):
                        if fu_busy[i] and fu_meta[i][3]:
                            by_uid[fu_uid[i]].aborted = True
                            spec_aborted += 1
                            fu_busy[i] = False
                            fu_uid[i] = 0
                    cdb[:] = [e for e in cdb if not e["is_spec"]]
                    if spec_regs_ckpt is not None:
                        regs[:] = spec_regs_ckpt   # roll back GPR side effects
                    pcs[br["stream"]] = target
                    fe_waits[br["stream"]] = 0
                spec_active = False
                spec_regs_ckpt = None
            else:
                pcs[br["stream"]] = target
            br = None

        # ---- 5. RS issue --------------------------------------------------
        # Weighted arbiter: ready entries considered priority-class first
        # (higher weight wins), age order within a class; a pid at its
        # per-class in-flight quota is skipped without consuming the unit
        # (work-conserving — the unit falls to the next eligible entry).
        issued = 0
        inflight: dict[tuple[int, int], int] = {}
        for i in range(n_total_fu):
            if fu_busy[i]:
                k = (fu_pid[i], fu_cls[i])
                inflight[k] = inflight.get(k, 0) + 1
        for r in sorted(rs, key=lambda x:
                        (PRIO_CAP - _wt[x.pid]) * AGE_SPAN + x.age):
            if issued >= costs.issue_width:
                break
            if r.dep_uid != 0:
                continue
            free_slots = [i for i in range(n_total_fu)
                          if fu_cls[i] == r.func and not fu_busy[i]]
            if not free_slots:
                continue
            if inflight.get((r.pid, r.func), 0) >= _qt[r.pid]:
                continue                   # quota mask: pid at its class cap
            if _eft:
                # EFT unit selection: grant the free unit with the earliest
                # predicted finish (busy units are not candidates, so the
                # busy-horizon term is 0 and finish = base cycles × unit
                # cost); ties break to the lowest index.  Uniform costs
                # reduce this to the greedy lowest-index rule exactly.
                slot = min(free_slots,
                           key=lambda i: (r.exec_cycles * fu_cost[i], i))
            else:
                slot = free_slots[0]
            fu_busy[slot] = True
            fu_uid[slot] = r.uid
            fu_rem[slot] = r.exec_cycles * fu_cost[slot]
            fu_pid[slot] = r.pid
            fu_meta[slot] = (r.out_s, r.out_e, r.src_s, r.is_spec)
            inflight[(r.pid, r.func)] = inflight.get((r.pid, r.func), 0) + 1
            by_uid[r.uid].issue_cycle = cycle
            by_uid[r.uid].unit = slot
            rs.remove(r)
            issued += 1

        # ---- 6. frontend (arbitrated per-tenant streams) -------------------
        # Eligibility snapshot: arrived, undrained streams whose decode
        # window is free, not stalled on their own branch, and whose next
        # instruction can act this cycle.  A structurally-stalled TASK
        # (full RS / full tracker / pid at its rs_cap) makes the stream
        # ineligible — the arbiter skips it, so admission caps backpressure
        # one tenant instead of head-of-line blocking everyone.
        drained_pre = [pcs[i] >= s_end[i] for i in range(NS)]
        arrived = [cycle >= s_arr[i] for i in range(NS)]
        elig = []
        for i in range(NS):
            ok = (s_active[i] and arrived[i] and not drained_pre[i]
                  and fe_waits[i] == 0)
            if ok and br is not None:
                # one shared branch unit / speculation domain: while a
                # speculation is open only the speculating stream runs;
                # a non-speculative branch stalls only its own stream
                ok = ((i == br["stream"]) if br["speculating"]
                      else (i != br["stream"]))
            if ok:
                op_i = int(tbl[pcs[i]][0])
                if op_i == isa.OP_TASK:
                    pid_i = int(tbl[pcs[i]][7])
                    if costs.in_order and not machine_empty():
                        ok = False
                    elif (len(rs) >= p.rs_entries
                          or len(tracker) >= p.tracker_entries
                          or sum(1 for r in rs if r.pid == pid_i)
                          >= _rc[pid_i]):
                        ok = False   # structural stall (incl. RS admission
                        #              cap: this pid is at its RS quota)
                    elif spec_active:
                        if not tm_free:
                            # drainable only if a committed victim exists
                            ok = any(t["committed"] for t in tlb)
                        elif len(tlb) >= p.tlb_entries:
                            ok = False
                elif op_i == isa.OP_IF:
                    if br is not None:
                        # depth-1 speculation: the one branch unit is busy
                        ok = False
                    elif ((int(tbl[pcs[i]][8]) & 0x3) != isa.BR_RR
                          and costs.in_order and not machine_empty()):
                        ok = False
            elig.append(ok)

        granted = None
        if any(elig):
            # round-robin over eligible streams; fe_mode="weighted" ranks
            # a stream's pid priority weight first (policy.fe_mode is
            # lowered into the table's weight column by the caller)
            granted = min((i for i in range(NS) if elig[i]),
                          key=lambda i: ((PRIO_CAP - s_w[i]) * NS
                                         + (i - fe_ptr) % NS))
            fe_ptr = (granted + 1) % NS
        for i in range(NS):
            # dispatch-stall accounting (per-stream head-of-line metric)
            if (s_active[i] and arrived[i] and not drained_pre[i]
                    and i != granted):
                fe_stall[i] += 1
            if fe_waits[i] > 0:        # decode windows tick every cycle
                fe_waits[i] -= 1

        progressed = granted is not None
        if granted is not None:
            g = granted
            pc = pcs[g]
            op, acc, a, asz, b, bsz, tid, pid_, ctl, meta = (int(x) for x in tbl[pc])
            if op == isa.OP_TASK:
                in_s = int(regs[a]) if ctl & isa.CTL_IN_INDIRECT else a
                out_s = int(regs[b]) if ctl & isa.CTL_OUT_INDIRECT else b
                in_e, out_e = in_s + asz, out_s + bsz
                phys_in = remap(in_s)
                dep = tracker_lookup(phys_in, phys_in + (in_e - in_s))
                if spec_active:
                    if not tm_free:
                        # TLB/TM full: drain the oldest committed entry
                        # (eligibility guaranteed one exists).  Structural
                        # work, not a dispatch — the cycle still stalls.
                        committed = [t for t in tlb if t["committed"]]
                        victim = min(committed, key=lambda t: t["seq"])
                        base = (p.tm_base
                                + victim["tm_s"] * p.tm_slot_words)
                        for j in range(victim["oe"] - victim["os"]):
                            mem[victim["os"] + j] = mem[base + j]
                        tm_free.append(victim["tm_s"])
                        tlb.remove(victim)
                        fe_waits[g] = p.tlb_drain_cycles
                        progressed = False
                    else:
                        slot_id = min(tm_free)   # lowest-index slot (matches machine)
                        tm_free.remove(slot_id)
                        tlb.append({"os": out_s, "oe": out_e, "tm_s": slot_id,
                                    "committed": False, "seq": tlb_seq})
                        tlb_seq += 1
                        phys_out = p.tm_base + slot_id * p.tm_slot_words
                        self_spec = True
                        _dispatch_task(rs, tracker, by_uid, tasks, acc, dep,
                                       phys_out, phys_out + (out_e - out_s),
                                       out_s, next_uid, age_ctr, cycle,
                                       self_spec, pid_)
                        next_uid += 1
                        age_ctr += 1
                        fe_waits[g] = costs.dispatch_serial_cost - 1
                        pcs[g] = pc + 1
                else:
                    _dispatch_task(rs, tracker, by_uid, tasks, acc, dep,
                                   out_s, out_e, out_s, next_uid, age_ctr,
                                   cycle, False, pid_)
                    next_uid += 1
                    age_ctr += 1
                    fe_waits[g] = costs.dispatch_serial_cost - 1
                    pcs[g] = pc + 1
            elif op == isa.OP_ADD:
                regs[b] = regs[a] + regs[asz]
                pcs[g] = pc + 1
            elif op == isa.OP_MUL:
                regs[b] = regs[a] * regs[asz]
                pcs[g] = pc + 1
            elif op == isa.OP_MOV:
                regs[b] = a if ctl & isa.CTL_IMM else regs[a]
                pcs[g] = pc + 1
            elif op == isa.OP_JUMP:
                pcs[g] = a                # absolute (stream-relocated at build)
            elif op == isa.OP_LBEG:
                regs[asz] = int(regs[a]) if ctl & 1 else a
                pcs[g] = pc + 1
            elif op == isa.OP_LEND:
                regs[asz] -= 1
                pcs[g] = pc - b if regs[asz] > 0 else pc + 1
            elif op == isa.OP_IF:
                kind = ctl & 0x3
                cond = (ctl >> 2) & 0x3
                thr = int(regs[asz])
                if kind == isa.BR_RR:
                    taken = eval_cond(cond, int(regs[a]), thr)
                    pcs[g] = pc + b if taken else pc + 1
                    fe_waits[g] = 1  # single-cycle bubble (paper §IV-C3)
                else:
                    phys = remap(a)
                    wait_uid = tracker_lookup(phys, phys + 1)
                    eff_kind = kind
                    if kind == isa.BR_BR and wait_uid == 0:
                        eff_kind = isa.BR_MR   # producer already done
                    speculate = costs.speculation and not spec_active
                    br = {"kind": eff_kind, "pc": pc, "off": b, "cond": cond,
                          "thr": thr, "addr": a, "wait_uid": wait_uid,
                          "speculating": speculate, "stream": g}
                    if eff_kind == isa.BR_MR:
                        memread_active = True
                        memread_rem = p.mem_read_cycles
                    if speculate:
                        spec_active = True
                        spec_regs_ckpt = regs.copy()
                        pcs[g] = pc + 1    # predicted not-taken
            else:   # OP_NOP
                pcs[g] = pc + 1

        if not progressed:
            stall_cycles += 1

        cycle += 1

        # ---- 7. halt check ----------------------------------------------
        if (all(pcs[i] >= s_end[i] for i in range(NS))
                and not rs and not any(fu_busy) and not cdb
                and br is None and not memread_active
                and all(w == 0 for w in fe_waits)):
            return Result(cycles=cycle, tasks=tasks, mem=mem, regs=regs,
                          fu_busy_cycles=fu_busy_cycles,
                          spec_aborted=spec_aborted, stall_cycles=stall_cycles,
                          halted=True, fe_stall=fe_stall)

    return Result(cycles=cycle, tasks=tasks, mem=mem, regs=regs,
                  fu_busy_cycles=fu_busy_cycles, spec_aborted=spec_aborted,
                  stall_cycles=stall_cycles, halted=False, fe_stall=fe_stall)


def _dispatch_task(rs, tracker, by_uid, tasks, acc, dep, out_s, out_e, src_s,
                   uid, age, cycle, is_spec, pid=0):
    """Shared dispatch bookkeeping (RS + tracker + trace)."""
    # WAW replacement: a new producer of an overlapping range supersedes
    # older tracker entries (strict paper mode would skip this; see DESIGN.md).
    tracker[:] = [t for t in tracker
                  if not (t["s"] < out_e and out_s < t["e"])]
    tracker.append({"s": out_s, "e": out_e, "uid": uid, "is_spec": is_spec})
    rs.append(_RS(uid, acc, dep, age, out_s, out_e, src_s,
                  FUNC_CYCLES[acc], is_spec, pid))
    rec = TaskRecord(uid=uid, func=acc, dispatch_cycle=cycle, dep_uid=dep,
                     is_spec=is_spec, pid=pid)
    tasks.append(rec)
    by_uid[uid] = rec
