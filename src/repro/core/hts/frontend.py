"""Per-tenant frontends: N independent dispatch streams into one HTS.

The paper's system model (Fig. 1) has N general-purpose CPUs, *each*
pushing its own task stream into the shared scheduler.  The repo's
original multi-tenant model collapsed that to ONE merged in-order stream
(``Program.merge`` round-robin splices the tenants' instructions), and the
``rs_admission`` study in ``BENCH_priority.json`` measured the
consequence: with a single frontend, dispatch order IS stream order, so a
blocking admission stall on a greedy tenant also stalls every tenant
behind it — no admission policy can help a late arrival
(head-of-line blocking at the frontend, not the RS, binds).

This module is the mechanism that closes that bound.  A
:class:`MultiProgram` keeps the tenants' instruction streams *separate*
inside one code image: stream ``i`` owns the half-open PC range
``[start_i, end_i)`` and has its own program counter, decode/serial-cost
window (``fe_wait``) and **arrival offset** (the cycle its CPU starts
pushing).  Each cycle a *frontend arbiter* picks one eligible stream and
dispatches its next instruction into the shared reservation station:

* **eligible** — arrived (``cycle >= arrival``), not drained, decode
  window free, not stalled on its own unresolved branch, and its next
  instruction can actually act (a TASK blocked on a full RS / full
  tracker / its pid's ``rs_caps`` admission cap is *skipped*, not
  waited on — that skip is precisely what turns ``SchedPolicy.rs_caps``
  from a structural stall of everyone into per-stream backpressure);
* **arbitration** — round-robin over eligible streams by default;
  ``SchedPolicy(fe_mode="weighted")`` orders streams by their pid's
  priority weight first (round-robin within a weight class), echoing the
  per-queue decoupled dispatch of hardware-HEFT (Fusco et al. 2022).

One branch unit and one speculation domain are shared: a stream whose
MR/BR branch is unresolved stalls only *itself*; while a speculation is
open the arbiter grants only the speculating stream (its GPR checkpoint
and the TLB/TM speculative state belong to that path alone).

Both simulators implement the identical arbitration — ``golden.py``
scalar-wise, ``machine.py`` as a vectorised argmin over a traced
``(n_streams, 4)`` stream table that rides the same shape buckets as the
program table — and ``hts.compare`` proves them schedule-equivalent
across event-skip modes, including batched populations.

A single stream covering the whole program (the default built by
``api``/``batch`` when a program has no stream table) degrades
bit-for-bit to the historical merged-frontend model; the degradation is
pinned by ``tests/test_hts_frontend.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import numpy as np

from . import isa
from .builder import BuilderError, Program, _collect_pids
from .policy import PRIO_CAP, SchedPolicy

#: columns of the machine-facing stream table (int32, one row per stream).
#: ``weight`` is the *frontend* arbitration weight — resolved from the run's
#: :class:`SchedPolicy` at call time (``fe_mode="weighted"`` maps a stream to
#: its pid's priority weight; the default round-robin mode zeroes the
#: column), so the compiled machine never needs the policy object itself.
STREAM_FIELDS = ("start", "end", "arrival", "weight")

#: streams are tenant CPUs; the 4-bit ISA pid field bounds useful counts.
MAX_STREAMS = 16


@dataclasses.dataclass(frozen=True)
class Stream:
    """One tenant frontend: a PC range of the shared code image + arrival."""
    start: int                  # first instruction (absolute PC)
    end: int                    # one past the last instruction
    arrival: int = 0            # cycle this CPU starts pushing
    pid: int = 0                # owning process (weight lookup + metrics)
    name: str = ""

    def __post_init__(self):
        if self.start < 0 or self.end < self.start:
            raise BuilderError(f"stream {self.name!r}: bad PC range "
                               f"[{self.start}, {self.end})")
        if self.arrival < 0:
            raise BuilderError(f"stream {self.name!r}: arrival offset must "
                               f"be >= 0, got {self.arrival}")

    def __len__(self) -> int:
        return self.end - self.start


@dataclasses.dataclass(frozen=True)
class StreamSet:
    """The per-tenant frontends of one program (ordered, immutable).

    The machine-facing form is :meth:`table` — a ``(n_streams, 4)`` int32
    array in :data:`STREAM_FIELDS` order, a *runtime input* of the
    compiled machine exactly like the policy tables (sweeping arrivals or
    frontend weights never recompiles).
    """
    streams: tuple[Stream, ...]

    def __post_init__(self):
        if not self.streams:
            raise BuilderError("a StreamSet needs at least one stream")
        if len(self.streams) > MAX_STREAMS:
            raise BuilderError(f"{len(self.streams)} streams exceed "
                               f"MAX_STREAMS={MAX_STREAMS}")

    def __len__(self) -> int:
        return len(self.streams)

    def __iter__(self):
        return iter(self.streams)

    @property
    def pids(self) -> tuple[int, ...]:
        return tuple(s.pid for s in self.streams)

    @property
    def arrivals(self) -> tuple[int, ...]:
        return tuple(s.arrival for s in self.streams)

    def arrival_of(self, pid: int) -> int:
        """Earliest arrival among the streams owned by ``pid`` (0 if none)."""
        arr = [s.arrival for s in self.streams if s.pid == pid]
        return min(arr) if arr else 0

    @classmethod
    def single(cls, length: int, pid: int = 0) -> "StreamSet":
        """The degenerate one-stream set: the historical merged frontend."""
        return cls((Stream(0, int(length), 0, pid, "merged"),))

    def table(self, policy: Optional[SchedPolicy] = None) -> np.ndarray:
        """(n_streams, 4) int32 machine table; frontend weights resolved
        from ``policy`` (zero — pure round-robin — unless the policy's
        ``fe_mode`` is ``"weighted"``)."""
        pol = policy or SchedPolicy()
        weighted = pol.fe_mode == "weighted"
        out = np.zeros((len(self.streams), len(STREAM_FIELDS)), np.int32)
        for i, s in enumerate(self.streams):
            w = pol.weight_of(s.pid) if weighted else 0
            out[i] = (s.start, s.end, s.arrival,
                      min(max(int(w), 0), PRIO_CAP))
        return out


@dataclasses.dataclass(frozen=True)
class MultiProgram:
    """A built multi-stream program: one code image, N dispatch streams.

    Accepted everywhere a program is (``hts.run``/``run_many``/``sweep``/
    ``compare``, ``pack_population``); :mod:`batch` lowers it to the code
    array plus the :class:`StreamSet` stream table.
    """
    name: str
    code: np.ndarray
    streams: StreamSet
    mem_init: dict[int, int]
    effects: dict[int, int]
    keynames: dict[str, int]
    policy: Optional[SchedPolicy] = None

    @property
    def n_streams(self) -> int:
        return len(self.streams)

    @property
    def asm(self) -> str:
        """Disassembly of the shared code image (stream ranges in order)."""
        names = {v: k for k, v in self.keynames.items()}
        return isa.disassemble(self.code, names)

    def with_arrivals(self, arrivals: Sequence[int]) -> "MultiProgram":
        """The same program with per-stream arrival offsets replaced."""
        if len(arrivals) != len(self.streams):
            raise BuilderError(f"got {len(arrivals)} arrivals for "
                               f"{len(self.streams)} streams")
        new = StreamSet(tuple(
            dataclasses.replace(s, arrival=int(a))
            for s, a in zip(self.streams, arrivals)))
        return dataclasses.replace(self, streams=new)


def _stream_pid(prog: Program) -> int:
    """The owning pid of a tenant program (its tasks' unique pid; 0 when
    the program emits no tasks or mixes pids)."""
    pids = _collect_pids(prog._nodes)
    return pids.pop() if len(pids) == 1 else 0


def build_frontends(programs: Sequence[Program], name: str = "shared", *,
                    arrivals: Optional[Sequence[int]] = None,
                    require_distinct_pids: bool = True,
                    priorities: Optional[dict[int, int]] = None,
                    quotas: Optional[dict[int, int]] = None,
                    rs_caps: Optional[dict[int, int]] = None,
                    fe_mode: Optional[str] = None) -> MultiProgram:
    """Lower N tenant :class:`Program`\\ s to one :class:`MultiProgram`.

    The tenants' isolation invariants (disjoint written regions, disjoint
    register sets, optionally distinct pids) and policy/image unioning are
    exactly :meth:`Program.merge`'s — the same checks run here — but the
    instruction streams stay **separate**: stream ``i`` occupies the code
    range ``[start_i, end_i)``, registers are numbered jointly across the
    streams (they share the scheduler's one GPR bank), and absolute
    ``jump`` targets are relocated by each stream's base.

    ``arrivals`` (cycles, one per program, default all-0) stagger the
    tenants' CPUs.  ``priorities``/``quotas``/``rs_caps`` attach a
    :class:`SchedPolicy` exactly as in ``merge``; ``fe_mode`` ("rr" or
    "weighted") selects the frontend arbitration of that policy.
    """
    programs = list(programs)
    if not programs:
        raise BuilderError("build_frontends needs at least one program")
    if arrivals is not None and len(arrivals) != len(programs):
        raise BuilderError(f"got {len(arrivals)} arrivals for "
                           f"{len(programs)} programs")
    # one merge runs every isolation check and unions images/keynames/policy
    merged = Program.merge(programs, name,
                           require_distinct_pids=require_distinct_pids,
                           priorities=priorities, quotas=quotas,
                           rs_caps=rs_caps)
    policy = merged.policy
    if fe_mode is not None:
        policy = dataclasses.replace(policy or SchedPolicy(),
                                     fe_mode=SchedPolicy._norm_fe_mode(fe_mode))

    # flatten each tenant separately: stream boundaries are just the
    # cumulative flat lengths, independent of register numbering
    flats: list[list] = []
    for p in programs:
        flat: list = []
        p._flatten(p._nodes, flat)
        flats.append(flat)
    regmap = merged._resolve_regs([op for f in flats for op in f])

    def rr(x):
        return regmap[x] if not isinstance(x, int) else int(x)

    instrs: list[isa.Instr] = []
    rows: list[Stream] = []
    start = 0
    for i, (p, flat) in enumerate(zip(programs, flats)):
        for o in flat:
            a = rr(o.a)
            if o.op == isa.OP_JUMP:
                a += start          # relocate absolute jump targets
            instrs.append(isa.Instr(op=o.op, acc=o.acc, a=a, asz=rr(o.asz),
                                    b=rr(o.b), bsz=o.bsz, tid=o.tid,
                                    pid=o.pid, ctl=o.ctl, meta=o.meta))
        end = start + len(flat)
        rows.append(Stream(start, end,
                           int(arrivals[i]) if arrivals is not None else 0,
                           _stream_pid(p), p.name))
        start = end
    return MultiProgram(name=name, code=isa.encode_program(instrs),
                        streams=StreamSet(tuple(rows)),
                        mem_init=dict(merged.mem_init),
                        effects=dict(merged.effects),
                        keynames=dict(merged.keynames), policy=policy)


__all__ = ["MAX_STREAMS", "STREAM_FIELDS", "Stream", "StreamSet",
           "MultiProgram", "build_frontends"]
