"""Seeded multi-tenant workload generator (the paper's headline claim,
driven at scale).

The abstract promises "an example heterogeneous system to enable multiple
applications to share the available accelerators", but the repo's original
evaluation exercised exactly two hand-written apps through a pairwise
interleave.  This module generates *scenarios*: N tenant programs (2–8
processes, distinct ISA pids) built on the Program Builder — random mixes of
FIR/FFT/DCT-class kernels, dependency chains, fan-outs, loops and
mem/bus-kind branches — merged N-way through :meth:`builder.Program.merge`.
Related hardware-scheduler evaluations (hardware-HEFT, priority-aware NoC
scheduling) use exactly this kind of generated heterogeneous DAG workload
with per-application slowdown metrics.

Every scenario is a pure function of its seed (``numpy`` Generator), so a
failing fuzz case is one integer away from a reproduction:

    >>> sc = generate_scenario(1234)
    >>> from repro.core import hts
    >>> hts.compare(sc.merged)                  # golden ≡ machine, all modes
    >>> shared = hts.run(sc.merged, n_fu=2)
    >>> shared.fairness(solo_results(sc, n_fu=2)).max_slowdown

``mixed_priority=True`` scenarios additionally draw per-pid priority
weights (and sometimes a per-class FU quota and/or a per-pid RS admission
cap) into a :class:`~repro.core.hts.policy.SchedPolicy` attached to the
merge, so the same differential fuzzing loop exercises the weighted/quota
arbiter and the RS admission stall — ``hts.compare`` picks the policy up
automatically.

Population batches
------------------
:func:`generate_population` is the scenario generator at population scale:
N seeded scenarios grouped into *shape buckets* (``batch.prog_bucket`` of
the merged program length), each bucket a :class:`Population` whose merged
programs pack into one ``hts.run_many`` vmap batch — the unit of work for
population-scale sweeps (``benchmarks/population.py``).

Resource rationing
------------------
One merged machine must hold every tenant at once, so the generator rations
the two global namespaces the ISA exposes:

* **task memory** — tenant ``i`` gets the span ``[base_i, base_i + span)``
  of the default 1024-word memory image (the shared read-only input frame at
  ``INPUT`` is the only span tenants may have in common), and the generator
  tracks its own words so the bump allocator can never cross into a
  neighbour;
* **GPRs** — loops (counter + walking base + stride) and branches
  (threshold) consume registers; each tenant's feature mix is gated on a
  ``31 // n_tenants`` register budget so ``merge`` always fits the bank.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from .builder import Program
from .costs import NUM_FUNCS, fu_cost_tuple
from .policy import SchedPolicy
from .programs import Bench, INPUT, INPUT_WORDS

#: first tenant region base (above the shared input frame) and the top of the
#: generator's address space (default ``HtsParams.mem_words``).
TENANT_BASE = 0x40
MEM_WORDS = 1024
_ALIGN = 0x8

#: kernel pools (Table II keynames) by execution-cycle weight.  The cheap mix
#: keeps golden/no-event-skip differential runs fast (every kernel < 1k
#: cycles); the full mix adds the long-latency FFT/FIR heavyweights.
CHEAP_MIX = ("vector_dot", "vector_add", "vector_max", "dct", "correlation")
DSP_MIX = CHEAP_MIX + ("real_fir", "iir")
FULL_MIX = DSP_MIX + ("complex_fir", "adaptive_fir", "fft_256")

_SHAPES = ("chain", "fanout", "mixed", "loop", "branch")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One generated multi-tenant workload: N solo programs + their merge."""
    name: str
    seed: int
    pids: tuple[int, ...]
    tenants: tuple[Bench, ...]          # builder-backed, one per pid
    merged: Bench                       # N-way Program.merge, distinct pids
    policy: Optional[SchedPolicy] = None  # mixed-priority scenarios only
    #: the same tenants as per-tenant frontend streams (``frontends=True``
    #: scenarios) — one MultiProgram, same pids, same policy
    multi: Optional[object] = None
    #: per-tenant arrival offsets (``arrivals=True``; index-aligned with
    #: ``pids``); () when arrivals were not drawn
    arrivals: tuple[int, ...] = ()
    #: heterogeneous per-(class, unit) cost table
    #: (``heterogeneous_fus=True``; the hashable
    #: :func:`~repro.core.hts.costs.fu_cost_tuple` form — pass it as the
    #: ``fu_cost=`` of ``hts.run``/``hts.compare``); None = uniform units
    fu_cost: Optional[tuple] = None

    @property
    def n_tenants(self) -> int:
        return len(self.pids)

    def solo(self, pid: int) -> Bench:
        """The standalone program of tenant ``pid``."""
        return self.tenants[self.pids.index(pid)]


class _Tenant:
    """Generation state for one tenant: its Program plus resource budgets."""

    def __init__(self, pid: int, base: int, span: int, reg_budget: int):
        self.prog = Program(f"tenant{pid}", region_base=base)
        self.pid = pid
        self.words_left = span
        self.regs_left = reg_budget
        self.frame = self.prog.input(INPUT, INPUT_WORDS, "frame")

    def take(self, regs: int, words: int) -> bool:
        """Deduct both budgets atomically (no leak when one check fails)."""
        words = -(-words // _ALIGN) * _ALIGN    # the allocator aligns to 8
        if regs > self.regs_left or words > self.words_left:
            return False
        self.regs_left -= regs
        self.words_left -= words
        return True


def _emit_straight(rng: np.random.Generator, t: _Tenant, kernels, n: int,
                   chain: bool) -> None:
    """``n`` tasks reading the frame (fanout) or each other (chain)."""
    prev = t.frame
    for i in range(n):
        if not t.take(0, 4):
            return
        h = t.prog.task(str(rng.choice(kernels)), in_=prev, out=4,
                        in_size=4, tid=i & 0xF)
        if chain or (not chain and rng.random() < 0.2):
            prev = h                        # occasional dep even in fanout


def _emit_loop(rng: np.random.Generator, t: _Tenant, kernels) -> bool:
    """A 2–4 iteration loop walking a fresh output span (3 registers)."""
    iters = int(rng.integers(2, 5))
    stride = _ALIGN
    if not t.take(3, iters * stride):       # counter + walking base + stride
        return False
    w = t.prog.walker(stride=stride, count=iters, name=f"w{t.pid}")
    with t.prog.loop(iters):
        t.prog.task(str(rng.choice(kernels)), in_=t.frame, out=w,
                    out_size=4, tid=1)
        w.advance()
    return True


def _emit_branch(rng: np.random.Generator, t: _Tenant, kernels) -> bool:
    """A mem- or bus-kind branch with 1–2 tasks per arm (1 register)."""
    n_each = int(rng.integers(1, 3))
    # cond region + both arms' outs, each rounded up to the 8-word alignment
    if not t.take(1, _ALIGN + n_each * 2 * _ALIGN):     # 1 reg: threshold
        return False
    kind = str(rng.choice(("mem", "bus")))
    taken = bool(rng.random() < 0.5)
    cond = t.prog.region(1, name=f"cond{t.pid}")
    if kind == "bus":
        t.prog.task("correlation", in_=t.frame, out=cond, tid=0)
        cond.effect(9 if taken else 1)
    else:
        cond.init(9 if taken else 1)
    br = t.prog.branch(on=cond, cond=">=", thr=5, kind=kind)
    with br.not_taken():                    # speculated path
        for i in range(n_each):
            t.prog.task(str(rng.choice(kernels)), in_=t.frame, out=4,
                        tid=i & 0xF)
    with br.taken():
        for i in range(n_each):
            t.prog.task(str(rng.choice(kernels)), in_=t.frame, out=4,
                        tid=(i + 4) & 0xF)
    return True


def _generate_tenant(rng: np.random.Generator, pid: int, base: int, span: int,
                     reg_budget: int, kernels: Sequence[str],
                     max_tasks: int) -> Bench:
    t = _Tenant(pid, base, span, reg_budget)
    shape = str(rng.choice(_SHAPES))
    with t.prog.process(pid):
        if shape == "loop" and not _emit_loop(rng, t, kernels):
            shape = "chain"
        elif shape == "branch" and not _emit_branch(rng, t, kernels):
            shape = "fanout"
        if shape in ("chain", "fanout"):
            _emit_straight(rng, t, kernels, int(rng.integers(2, max_tasks + 1)),
                           chain=(shape == "chain"))
        elif shape == "mixed":
            _emit_straight(rng, t, kernels, int(rng.integers(1, 3)),
                           chain=True)
            if rng.random() < 0.5:
                _emit_loop(rng, t, kernels)
            else:
                _emit_straight(rng, t, kernels, int(rng.integers(1, 3)),
                               chain=False)
        else:                               # loop/branch got their core; pad
            _emit_straight(rng, t, kernels, int(rng.integers(0, 2)),
                           chain=False)
    return Bench.of(t.prog)


#: weight pool for ``mixed_priority`` scenarios: a QoS class per tenant,
#: skewed towards best-effort (0) with occasional high-priority tenants.
PRIORITY_POOL = (0, 0, 1, 2, 4, 8)


#: largest drawn per-tenant arrival offset (cycles).  Big enough that an
#: early tenant can flood the shared window before a late one arrives,
#: small relative to generated-program makespans (kernels are 53–18673
#: cycles), so arrival-staggered scenarios still overlap.
MAX_ARRIVAL = 256


def generate_scenario(seed: int, *, n_tenants: Optional[int] = None,
                      kernels: Sequence[str] = DSP_MIX,
                      max_tasks: int = 5,
                      name: Optional[str] = None,
                      mixed_priority: bool = False,
                      frontends: bool = False,
                      arrivals: bool = False,
                      heterogeneous_fus: bool = False) -> Scenario:
    """One seeded scenario: ``n_tenants`` (2–8, drawn when omitted) programs
    with distinct pids, disjoint region/register budgets, merged N-way.

    ``mixed_priority=True`` additionally draws a :class:`SchedPolicy` for the
    merge — per-pid priority weights from :data:`PRIORITY_POOL` (at least one
    tenant strictly above the rest so the weighted arbiter provably engages)
    and, each with probability ½ per scenario, a per-class FU quota of 1–2
    units on one tenant and an RS admission cap of 1–4 entries on one
    tenant.  The tenant *programs* are identical to the unprioritised
    scenario of the same seed (the policy draws happen after program
    generation), so fuzz failures stay one integer away from reproduction.

    ``frontends=True`` additionally builds :attr:`Scenario.multi` — the
    same tenants as per-tenant frontend streams
    (:func:`frontend.build_frontends`, same pids and policy), the fuzz
    target for the multi-stream dispatch model.  ``arrivals=True``
    (implies ``frontends``) draws seeded per-tenant arrival offsets in
    ``[0, MAX_ARRIVAL]`` into the stream table; the draws happen *after*
    program and policy generation, so same-seed programs are unchanged.

    ``heterogeneous_fus=True`` draws (last of all, so every earlier draw
    of the same seed is unchanged) a per-(class, unit) cost table into
    :attr:`Scenario.fu_cost` — each class gets, with probability ½, a row
    of small integer multipliers (slow units deliberately land at *low*
    unit indices sometimes, where the greedy arbiter picks them first) —
    and, with probability ½, flips the scenario policy to
    ``issue_mode="eft"`` so the earliest-finish-time arbiter is fuzzed on
    the same programs.
    """
    rng = np.random.default_rng(seed)
    if n_tenants is None:
        n_tenants = int(rng.integers(2, 9))
    if not 1 <= n_tenants <= 8:
        raise ValueError(f"n_tenants must be in [1, 8], got {n_tenants}")
    span = ((MEM_WORDS - TENANT_BASE) // n_tenants) // _ALIGN * _ALIGN
    reg_budget = 31 // n_tenants
    pids = tuple(range(1, n_tenants + 1))
    tenants = tuple(
        _generate_tenant(rng, pid, TENANT_BASE + i * span, span, reg_budget,
                         kernels, max_tasks)
        for i, pid in enumerate(pids))
    priorities = quotas = rs_caps = None
    if mixed_priority:
        weights = {pid: int(rng.choice(PRIORITY_POOL)) for pid in pids}
        boosted = int(rng.choice(pids))
        weights[boosted] = max(weights.values()) + int(rng.integers(1, 4))
        priorities = weights
        quotas = ({int(rng.choice(pids)): int(rng.integers(1, 3))}
                  if rng.random() < 0.5 else None)
        rs_caps = ({int(rng.choice(pids)): int(rng.integers(1, 5))}
                   if rng.random() < 0.5 else None)
    merged_prog = Program.merge([b.program for b in tenants],
                                name or f"scenario_{seed}",
                                require_distinct_pids=True,
                                priorities=priorities, quotas=quotas,
                                rs_caps=rs_caps)
    multi = None
    arrival_offsets: tuple[int, ...] = ()
    if frontends or arrivals:
        if arrivals:    # drawn last: same-seed programs/policies unchanged
            arrival_offsets = tuple(
                int(rng.integers(0, MAX_ARRIVAL + 1)) for _ in pids)
        from .frontend import build_frontends
        multi = build_frontends(
            [b.program for b in tenants], f"{merged_prog.name}_fe",
            arrivals=arrival_offsets or None, require_distinct_pids=True,
            priorities=priorities, quotas=quotas, rs_caps=rs_caps)
    fu_cost = None
    if heterogeneous_fus:   # drawn last: every earlier same-seed draw intact
        table = {}
        for fid in range(NUM_FUNCS):
            if rng.random() < 0.5:
                row = tuple(int(v) for v in rng.choice(
                    (1, 1, 2, 3, 4, 8), size=int(rng.integers(2, 5))))
                if any(v != 1 for v in row):
                    table[fid] = row
        fu_cost = fu_cost_tuple(table) if table else None
        if rng.random() < 0.5:      # fuzz the EFT arbiter on the same DAGs
            eft_pol = dataclasses.replace(
                merged_prog.policy or SchedPolicy(), issue_mode="eft")
            merged_prog.policy = eft_pol
            if multi is not None:
                multi = dataclasses.replace(multi, policy=eft_pol)
    return Scenario(name=merged_prog.name, seed=seed, pids=pids,
                    tenants=tenants, merged=Bench.of(merged_prog),
                    policy=merged_prog.policy, multi=multi,
                    arrivals=arrival_offsets, fu_cost=fu_cost)


def generate_scenarios(n: int, *, seed0: int = 0, **kwargs):
    """``n`` scenarios with consecutive seeds (fuzzing convenience)."""
    for s in range(seed0, seed0 + n):
        yield generate_scenario(s, **kwargs)


# ---------------------------------------------------------------------------
# open-loop arrival streams: the serving workload
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Arrival:
    """One open-loop request: ``scenario`` arrives ``t`` seconds into the
    stream (host seconds — the serving clock, not scheduler cycles)."""
    t: float
    scenario: Scenario


def arrival_stream(seed: int, rate: float, n: int, *, seed0: int = 0,
                   dist: str = "poisson", **scenario_kwargs
                   ) -> tuple[Arrival, ...]:
    """A seeded open-loop request stream: ``n`` scenarios with arrival times.

    Closed-batch replay (everything available at t=0) is where batching
    looks free; open arrivals are where a scheduler earns its keep — this
    is the reproducible request stream the serving benchmark
    (``benchmarks/serving.py``) and the serve fuzz tests draw from.

    Inter-arrival gaps are ``Exp(1/rate)`` (``dist="poisson"``, a Poisson
    process) or ``Uniform(0, 2/rate)`` (``dist="uniform"``) — mean arrival
    rate ``rate`` requests/second either way.  The arrival draws come from
    their own ``numpy`` Generator seeded with ``seed``, and scenario ``i``
    **is** ``generate_scenario(seed0 + i, **scenario_kwargs)`` — so
    changing the stream's ``seed``/``rate``/``dist`` never changes the
    scenario programs, and a failing stream case replays from two
    integers.
    """
    if rate <= 0:
        raise ValueError(f"rate must be positive, got {rate}")
    if n < 0:
        raise ValueError(f"n must be non-negative, got {n}")
    rng = np.random.default_rng(seed)
    if dist == "poisson":
        gaps = rng.exponential(1.0 / rate, n)
    elif dist == "uniform":
        gaps = rng.uniform(0.0, 2.0 / rate, n)
    else:
        raise ValueError(f'dist must be "poisson" or "uniform", got {dist!r}')
    times = np.cumsum(gaps)
    return tuple(Arrival(float(times[i]),
                         generate_scenario(seed0 + i, **scenario_kwargs))
                 for i in range(n))


# ---------------------------------------------------------------------------
# populations: scenarios grouped into vmap-ready shape buckets
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Population:
    """One shape bucket of scenarios — the unit of a ``run_many`` batch.

    All merged programs fit ``max_prog`` (their common power-of-two table
    bucket), so the whole population simulates as one compiled, vmapped
    machine call:

        >>> pops = generate_population(64, kernels=CHEAP_MIX)
        >>> from repro.core import hts
        >>> results = [hts.run_many(pop.programs, n_fu=2,
        ...                         max_prog=pop.max_prog) for pop in pops]
    """
    scenarios: tuple[Scenario, ...]
    max_prog: int

    def __len__(self) -> int:
        return len(self.scenarios)

    @property
    def programs(self) -> tuple[Bench, ...]:
        """The merged (shared) program of every scenario, batch order."""
        return tuple(sc.merged for sc in self.scenarios)

    @property
    def seeds(self) -> tuple[int, ...]:
        return tuple(sc.seed for sc in self.scenarios)


def generate_population(n: int, *, seed0: int = 0, bucket: bool = True,
                        **kwargs) -> tuple[Population, ...]:
    """``n`` seeded scenarios grouped into shape-bucketed populations.

    Scenario ``seed0 + i`` is identical to ``generate_scenario(seed0 + i,
    **kwargs)`` — bucketing only *groups* scenarios (by the power-of-two
    program-table bucket of their merged instruction count), it never
    changes them.  With ``bucket=False`` everything lands in one
    :class:`Population` padded to the largest bucket (one compile, one
    batch — what the population benchmark uses); with the default
    bucketing, each returned population compiles once per distinct bucket,
    which keeps padding waste bounded on long-tailed program lengths.
    """
    from .batch import prog_bucket, work_estimate
    scenarios = [generate_scenario(s, **kwargs)
                 for s in range(seed0, seed0 + n)]
    sizes = [prog_bucket(work_estimate(sc.merged)) for sc in scenarios]
    if not bucket:
        return (Population(tuple(scenarios), max(sizes, default=0)),)
    buckets: dict[int, list[Scenario]] = {}
    for sc, size in zip(scenarios, sizes):
        buckets.setdefault(size, []).append(sc)
    return tuple(Population(tuple(scs), size)
                 for size, scs in sorted(buckets.items()))


def solo_results(scenario: Scenario, *, scheduler="hts_spec", n_fu=2,
                 backend: str = "jax", **run_kwargs) -> dict:
    """Each tenant's standalone :class:`api.Result` (fairness baselines)."""
    from . import api
    return {pid: api.run(scenario.solo(pid), scheduler=scheduler, n_fu=n_fu,
                         backend=backend, **run_kwargs)
            for pid in scenario.pids}
