"""JAX cycle-accurate HTS machine (the paper's simulator as a compiled program).

Same semantics as :mod:`golden` (see its docstring for the within-cycle phase
order) but implemented with fixed-capacity state arrays and ``jax.lax``
control flow, so that

  * one simulation is a single ``jit``-compiled ``lax.while_loop``;
  * the per-class accelerator count ``n_fu`` is a *runtime argument*, so the
    Fig-10 strong-scaling sweep is one ``vmap`` over FU configurations;
  * an optional **event-skip** mode (beyond-paper) advances time directly to
    the next scheduler event instead of ticking every cycle — exact-equivalent
    schedules (tested), 10-400× faster wall-clock for interrupt-dominated
    (naive/software) cost models;
  * the scheduling policy (per-pid priority weights, per-class FU quotas and
    per-pid RS admission caps, ``policy.py``) enters as traced
    ``prio``/``quota``/``rs_cap`` arrays — like ``n_fu``, runtime arguments,
    so policy sweeps share one compilation;
  * the *program itself* is a runtime input (``ftab``/``p_len`` plus the
    ``mem_init``/``effects`` images), so a **population of scenarios** is one
    more ``vmap`` axis — ``batch.py`` packs N programs to a shared static
    shape and ``api.run_many`` drives them through one compiled machine.

GPR side effects on a squashed speculative path are rolled back from a
checkpoint taken at speculation entry (the paper is silent on GPR recovery;
an OoO core would checkpoint the RAT — DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import isa
from .costs import FUNC_CYCLES, NUM_FUNCS, SchedulerCosts, norm_fu_cost
from .golden import HtsParams
from .policy import AGE_SPAN, NUM_PIDS, PRIO_CAP, SchedPolicy

I32 = jnp.int32
NEG = jnp.int32(-1)
BIG = jnp.int32(2**30)


@dataclasses.dataclass(frozen=True)
class ResumableMachine:
    """The population machine factored into snapshot/resume pieces.

    ``init(ftab, p_len, n_fu, mem_init, effects, prio, quota, rs_cap,
    fu_cost, eft, streams)`` builds the while-loop carry (one state row
    per lane);
    ``run_slice(carry, <same 11 args>, budget)`` advances every alive lane
    by at most ``budget`` machine steps (while-loop trips — the unit wall
    time is spent in under event-skip) and returns the carry — lanes at
    their limit (or halted) are fixed points, so slices compose exactly:
    any split of a run into slices reaches the same final state as one
    uninterrupted run.  ``collect(carry)`` maps a carry (or one host-side
    row of it) to the usual output dict.

    ``budget`` is traced runtime data — varying it never recompiles — and
    the carry is an ordinary dict of arrays, so a host can snapshot it,
    harvest halted lanes, splice in freshly initialised rows (lane
    *refill*: only ``pc`` and ``mem`` of a fresh row depend on the
    program; see ``init``'s state layout) and resume.  That is the whole
    mechanism behind ``serve.py``'s slice-and-refill continuous batching.
    """
    init: Any
    run_slice: Any
    collect: Any


#: valid ``step_impl`` values — how the step body's scatter/select-heavy
#: phases are implemented (identical schedules, different lowering):
#: ``"xla"`` (default) is the restructured XLA form (cumsum ranks instead of
#: the argsort, one shared key-comparison matrix in the RS arbiter,
#: per-class unit ranking, fused trace selectors); ``"xla_base"`` preserves
#: the pre-restructure phase bodies verbatim (the honest benchmark
#: baseline); ``"pallas"`` runs the population step's hot phases as fused
#: ``pl.pallas_call`` kernels with a lane-per-program grid
#: (:mod:`pallas_step` — interpreted on CPU, real lowering on TPU).
STEP_IMPLS = ("xla", "xla_base", "pallas")


@dataclasses.dataclass(frozen=True)
class MachineSpec:
    """Static configuration baked into the compiled machine."""
    params: HtsParams = HtsParams()
    costs: SchedulerCosts = None
    max_fu_per_class: int = 16     # FU pool width (n_fu may be ≤ this, traced)
    event_skip: bool = True
    max_cycles: int = 5_000_000
    #: largest task-output dataframe (words) the completion datapath can
    #: write back in one cycle — a hardware write-port capacity.  Dispatching
    #: a task with a wider output raises the ``overflow`` flag (the
    #: simulation is refused, like a uid overflow).  Every Table-II bench
    #: and generated workload writes ≤ 8 words; the default matches the
    #: transactional-memory slot width (speculative outputs can never be
    #: wider than a TM slot anyway).
    max_out_words: int = 16
    #: step-body implementation (see :data:`STEP_IMPLS`).  Part of the
    #: compile key like every other field; the default value keeps the
    #: default path in the same compile bucket as before the field existed.
    step_impl: str = "xla"


def make_machine(spec: MachineSpec, max_prog: int = 256,
                 population: bool = False, resumable: bool = False,
                 step_impl: str | None = None):
    """Build the machine under ``spec``; returns
    ``run(ftab, p_len, n_fu, mem_init, effects, prio, quota, rs_cap,
    fu_cost, eft, streams)``.

    ``step_impl`` overrides ``spec.step_impl`` (see :data:`STEP_IMPLS`):
    ``"xla"`` / ``"xla_base"`` / ``"pallas"`` select how the hot step
    phases lower — all three produce bit-identical schedules (pinned by
    the differential tests).  The pallas implementation is population-
    level (``pl.pallas_call`` cannot sit under ``jax.vmap``), so a
    single-lane pallas machine runs as a population of one and squeezes
    the lane axis off its outputs — integer math, still bit-identical.

    With ``population=True`` the returned runner expects every argument
    with a leading *scenario* axis and simulates the whole batch in one
    while loop (scalar any-lane-alive condition, vmapped step body) — the
    fast path behind ``api.run_many``.  Unlike ``jax.vmap(run)``, it pays
    no per-lane select over the loop carry.

    With ``population=True, resumable=True`` the same machine comes back
    factored as a :class:`ResumableMachine` — the carry is built once
    (``init``), advanced in bounded step slices (``run_slice(carry, ...,
    budget)``; a lane at its per-lane step limit is a fixed point of the
    step, exactly like a halted lane, so slices compose bit-exactly with
    run-to-completion) and read out with ``collect``.  ``serve.py``
    builds continuous batching (harvest halted lanes between slices,
    refill their slots) on top of it.

    The *program is a runtime input* — ``ftab`` is the (max_prog, 10) decoded
    field table (``isa.decode_table`` output, zero-padded) and ``p_len`` its
    true length — so one compilation serves every benchmark, and ``vmap`` can
    batch over programs as well as FU configurations.

    ``n_fu``: (NUM_FUNCS,) int32 — units per accelerator class (traced).
    ``mem_init``/``effects``: (total_mem,) int32 images.
    ``prio``/``quota``/``rs_cap``: (NUM_PIDS,) int32 scheduling-policy tables
    (traced, like ``n_fu`` — one compilation serves every policy; see
    ``policy.py``).  ``prio`` holds per-pid priority weights (default
    all-zero = age order), ``quota`` per-pid in-flight unit caps per class
    (default uncapped), ``rs_cap`` per-pid RS-entry admission caps (default
    uncapped — a pid at its cap takes a structural dispatch stall exactly
    like a full RS).
    ``fu_cost``: (NUM_FUNCS, width) int32 per-(class, unit) execution-latency
    multipliers (traced; ``None`` = all ones — every unit of a class
    identical).  A width other than ``max_fu_per_class`` is sliced or
    1-padded to fit, so tables pack at the canonical ``costs.FU_COST_WIDTH``
    regardless of the machine's pool width.
    ``eft``: scalar int32 flag (traced) — nonzero selects earliest-finish-time
    unit ranking in the RS arbiter (``policy.issue_mode``); 0 is the
    historical greedy lowest-index rule, bit-identical.
    ``streams``: (n_streams, 4) int32 per-tenant frontend table —
    ``frontend.STREAM_FIELDS`` rows (start, end, arrival, weight); one
    per-stream program counter + decode window each, a frontend arbiter
    (round-robin, weight-class first) granting one eligible stream per
    cycle (see ``frontend.py`` and the golden docstring's phase 6).
    ``None`` = the historical single merged in-order frontend covering
    ``[0, p_len)`` — bit-identical to the pre-frontend machine.  The
    stream count is a *shape* (one compilation per stream count); the
    table's contents — boundaries, arrivals, weights — are traced runtime
    data, so arrival/weight sweeps never recompile.
    Returns a dict of schedule/trace arrays (see ``out`` at the bottom).

    Every argument is a runtime input, so ``vmap`` can batch any of three
    axes: the *scenario* axis (all arguments batched — a population of
    programs in one compiled machine), the *FU* axis (``n_fu`` alone) and
    the *policy* axis (``prio``/``quota``/``rs_cap``, with ``fu_cost``/
    ``eft`` riding the scenario axis); ``api.py`` composes them.
    """
    impl = spec.step_impl if step_impl is None else step_impl
    if impl not in STEP_IMPLS:
        raise ValueError(f"step_impl must be one of {STEP_IMPLS}, "
                         f"got {impl!r}")
    base = impl == "xla_base"
    p = spec.params
    c = spec.costs
    if p.max_tasks > AGE_SPAN:
        raise ValueError(
            f"max_tasks {p.max_tasks} exceeds the issue-key age span "
            f"{AGE_SPAN} (policy.AGE_SPAN); the int32 weighted-arbiter key "
            "would overflow")
    P = max_prog
    NF = NUM_FUNCS
    NFU = NF * spec.max_fu_per_class
    S = p.rs_entries
    T = p.tracker_entries
    L = p.tlb_entries
    M = p.total_mem
    U = p.max_tasks + 1            # uid-indexed trace arrays (uid 0 unused)
    C = p.cdb_entries or p.max_tasks   # CDB queue capacity (overflow-flagged)

    fu_cls = jnp.asarray(np.repeat(np.arange(NF), spec.max_fu_per_class), I32)
    fu_pos = jnp.asarray(np.tile(np.arange(spec.max_fu_per_class), NF), I32)
    func_cycles = jnp.asarray(FUNC_CYCLES, I32)
    mem_idx = jnp.arange(M, dtype=I32)
    # slot iotas: single-slot inserts are written as broadcast `where`
    # selects, not `.at[i].set` scatters — under the scenario vmap a
    # batched-index scatter lowers ~10x slower than a masked select
    s_iota = jnp.arange(S, dtype=I32)
    t_iota = jnp.arange(T, dtype=I32)
    l_iota = jnp.arange(L, dtype=I32)
    c_iota = jnp.arange(C, dtype=I32)
    u_iota = jnp.arange(U, dtype=I32)

    def trace_write(arr, uid, value, enable):
        """``arr[uid] = value where enable`` for uid-indexed trace arrays.

        ``uid``/``enable`` may be scalars or aligned vectors (one slot per
        RS entry / FU).  The single machine writes through a scatter; the
        *base* population machine uses a one-hot select (its historical
        form — batched scatters were assumed to pay per *update × lane*).
        The restructured path scatters in the population machine too: a
        one-hot costs K×U compares per lane per trip whether or not any
        event fired, while the scatter costs only the handful of actual
        updates — measured even at serving-sized tables (U=65) and ~3×
        cheaper per trip at default capacities (U=1025), which is where
        the lane-width slope of the step body lived.  The per-lane pallas
        kernels made this obvious: inside a kernel body (no batch axis)
        the write is naturally a scatter (:func:`tw_scatter`).
        """
        uid = jnp.asarray(uid)
        if not population or not base:
            idx = jnp.where(enable, uid, U)
            return arr.at[idx].set(value, mode="drop")
        if uid.ndim == 0:
            hit = enable & (u_iota == uid)
        else:
            hit = (enable[:, None] & (uid[:, None] == u_iota[None, :])).any(0)
        return jnp.where(hit, value, arr)

    def tw_scatter(arr, uid, value, enable):
        """The single-machine scatter form of :func:`trace_write`, used
        *inside* pallas kernels too: a kernel body runs per lane (no batch
        axis), so scatters are cheap again there even when the machine as
        a whole is a population."""
        uid = jnp.asarray(uid)
        idx = jnp.where(enable, uid, U)
        return arr.at[idx].set(value, mode="drop")

    # several trace arrays written under ONE (uid, enable) pair — e.g. the
    # frontend's four dispatch traces — share one selector instead of
    # recomputing it per array.  On the scatter path the selector is the
    # guarded index itself; the base population machine shares the
    # (U,)-wide one-hot (the dominant per-lane cost of its trace writes).
    def trace_sel(uid, enable):
        if not population or not base:
            return jnp.where(enable, uid, U)
        return enable & (u_iota == uid)

    def trace_put(arr, sel, value):
        if not population or not base:
            return arr.at[sel].set(value, mode="drop")
        return jnp.where(sel, value, arr)

    def init_state(mem_init, streams):
        # NB the read-only ``effects`` image is NOT part of the state: the
        # while-loop carry is select-masked per lane under batching, so
        # every loop-invariant array kept out of it is bandwidth saved on
        # every step of every scenario.
        z = functools.partial(jnp.zeros, dtype=I32)
        zb = functools.partial(jnp.zeros, dtype=jnp.bool_)
        NS = streams.shape[0]
        return dict(
            # per-stream frontends: a PC + decode window per tenant stream,
            # the arbiter's round-robin pointer, and per-stream
            # dispatch-stall counters (see frontend phase)
            pc=jnp.asarray(streams[:, 0], I32), cycle=I32(0), dt=I32(1),
            fe_wait=z(NS), fe_ptr=I32(0), fe_stall=z(NS),
            next_uid=I32(1), age=I32(0), ticket=I32(0),
            regs=z(p.num_regs), mem=jnp.asarray(mem_init, I32),
            rs_valid=zb(S), rs_uid=z(S), rs_func=z(S), rs_dep=z(S),
            rs_age=z(S), rs_out_s=z(S), rs_out_e=z(S), rs_src=z(S),
            rs_exec=z(S), rs_spec=zb(S), rs_pid=z(S),
            fu_busy=zb(NFU), fu_uid=z(NFU), fu_rem=z(NFU),
            fu_out_s=z(NFU), fu_out_e=z(NFU), fu_src=z(NFU), fu_spec=zb(NFU),
            fu_busy_cycles=z(NFU), fu_pid=z(NFU),
            trk_valid=zb(T), trk_s=z(T), trk_e=z(T), trk_uid=z(T), trk_spec=zb(T),
            tlb_valid=zb(L), tlb_os=z(L), tlb_oe=z(L), tlb_slot=z(L),
            tlb_seq=z(L), tlb_com=zb(L), tlb_seq_ctr=I32(0),
            cdb_valid=zb(C), cdb_uid=z(C), cdb_ticket=z(C), cdb_ready=z(C),
            cdb_spec=zb(C),
            br_active=jnp.bool_(False), br_kind=I32(0), br_pc=I32(0),
            br_off=I32(0), br_cond=I32(0), br_thr=I32(0), br_addr=I32(0),
            br_wait=I32(0), br_speculating=jnp.bool_(False),
            br_stream=I32(0),
            spec_active=jnp.bool_(False), spec_ckpt=z(p.num_regs),
            mr_active=jnp.bool_(False), mr_rem=I32(0),
            halted=jnp.bool_(False), overflow=jnp.bool_(False),
            stall_cycles=I32(0), spec_aborted=I32(0), steps=I32(0),
            # uid-indexed trace
            tr_func=jnp.full((U,), NEG, I32), tr_dispatch=jnp.full((U,), NEG, I32),
            tr_issue=jnp.full((U,), NEG, I32), tr_complete=jnp.full((U,), NEG, I32),
            tr_broadcast=jnp.full((U,), NEG, I32), tr_dep=z(U),
            tr_aborted=zb(U), tr_pid=z(U),
        )

    # ------------------------------------------------------------------
    # shared helpers
    # ------------------------------------------------------------------
    def remap(st, addr):
        match = st["tlb_valid"] & (st["tlb_os"] <= addr) & (addr < st["tlb_oe"])
        seq = jnp.where(match, st["tlb_seq"], -1)
        best = jnp.argmax(seq)
        phys = (p.tm_base + st["tlb_slot"][best] * p.tm_slot_words
                + (addr - st["tlb_os"][best]))
        return jnp.where(match.any(), phys, addr)

    def tracker_lookup(st, s, e):
        ov = st["trk_valid"] & (st["trk_s"] < e) & (s < st["trk_e"])
        return jnp.max(jnp.where(ov, st["trk_uid"], 0))

    def eval_cond(cond, v, thr):
        return jnp.select(
            [cond == isa.CND_EQ, cond == isa.CND_NEQ, cond == isa.CND_GE],
            [v == thr, v != thr, v >= thr], v <= thr)

    def machine_empty(st):
        return (~st["rs_valid"].any() & ~st["fu_busy"].any()
                & ~st["cdb_valid"].any() & ~st["mr_active"] & ~st["br_active"])

    # ------------------------------------------------------------------
    # phase 1: FU tick (+ completion writes & CDB enqueue, FU-index order).
    # No per-unit conditional or full-memory masked copies — under the
    # scenario vmap a `lax.cond` becomes a select that runs every
    # iteration, and a per-unit loop of (total_mem,)-wide copies in the
    # hot body is what made population batches slower than a Python loop.
    # Memory effect-writes go through `copy_window` (a max_out_words-wide
    # dynamic-update-slice, sequential per unit — exact last-writer
    # ordering); the CDB enqueue is vectorised with rank computations:
    # the k-th completing unit (by FU index) takes the k-th free slot (by
    # slot index) and the k-th consecutive ticket, which is precisely what
    # the sequential argmin loop produced.
    # ------------------------------------------------------------------
    W = spec.max_out_words
    w_iota = jnp.arange(W, dtype=I32)

    def copy_window(dst_arr, src_arr, dst, src, n, enable):
        """``dst_arr[dst:dst+n] = src_arr[src:src+n]`` via one W-wide DUS.

        Exactly a masked full-memory range copy for ``n <= W`` (the
        dispatch guard enforces that), at window cost instead of
        (total_mem,) cost per call.
        """
        dst_c = jnp.clip(dst, 0, M - W)
        off = dst - dst_c
        cur = jax.lax.dynamic_slice(dst_arr, (dst_c,), (W,))
        vals = src_arr[jnp.clip(w_iota - off + src, 0, M - 1)]
        mask = enable & (w_iota >= off) & (w_iota < off + n)
        return jax.lax.dynamic_update_slice(dst_arr,
                                            jnp.where(mask, vals, cur),
                                            (dst_c,))

    def fu_exec(st, exists, effect, alive):
        """Per-unit execution tick + completion memory writes; returns the
        ``done`` mask for the slot-side CDB enqueue (its own phase so the
        pallas machine can vmap this half and kernel the enqueue)."""
        busy = st["fu_busy"] & exists & alive
        st["fu_busy_cycles"] = st["fu_busy_cycles"] + jnp.where(busy, st["dt"], 0)
        rem = jnp.where(busy, st["fu_rem"] - st["dt"], st["fu_rem"])
        done = busy & (rem <= 0)
        st["fu_rem"] = rem

        # --- memory writes (FU-index order: later units overwrite)
        def mem_trip(i, mem):
            return copy_window(mem, effect, st["fu_out_s"][i],
                               st["fu_src"][i],
                               st["fu_out_e"][i] - st["fu_out_s"][i],
                               done[i])
        st["mem"] = jax.lax.fori_loop(0, NFU, mem_trip, st["mem"])
        return st, done

    def cdb_enqueue(st, done, tw):
        # --- CDB enqueue: k-th done unit → k-th free slot, ticket + k.
        # Written slot-side ((C,)-wide selects + gathers, no scatters —
        # batched scatters pay per update) — identical to the sequential
        # argmin loop: the slot of free-rank r receives the done unit of
        # FU-index-rank r and the r-th consecutive ticket.
        n_done = jnp.sum(done, dtype=I32)
        free = ~st["cdb_valid"]
        free_rank = jnp.cumsum(free.astype(I32)) - 1              # slot rank
        n_free = jnp.sum(free, dtype=I32)
        n_enq = jnp.minimum(n_done, n_free)
        fr = jnp.clip(free_rank, 0, NFU - 1)
        if base:
            # unit_of_rank[r]: the r-th completing unit in FU-index order
            k = jnp.cumsum(done.astype(I32)) - 1                  # unit rank
            unit_of_rank = jnp.argsort(jnp.where(done, k, BIG)).astype(I32)
            u = unit_of_rank[fr]                                  # (C,)
        else:
            # same rank → unit map without the (NFU,)-argsort: csum[i]
            # counts completions through unit i, so the first index with
            # csum ≥ r+1 IS the r-th completing unit — a log2(NFU) binary
            # search per slot.  Slots past n_enq are masked by ``take``.
            csum = jnp.cumsum(done.astype(I32))
            u = jnp.clip(jnp.searchsorted(csum, fr + 1, side="left"),
                         0, NFU - 1).astype(I32)
        take = free & (free_rank < n_enq)
        st["cdb_valid"] = st["cdb_valid"] | take
        st["cdb_uid"] = jnp.where(take, st["fu_uid"][u], st["cdb_uid"])
        st["cdb_ticket"] = jnp.where(take, st["ticket"] + free_rank,
                                     st["cdb_ticket"])
        st["cdb_ready"] = jnp.where(take, st["cycle"] + c.completion_extra,
                                    st["cdb_ready"])
        st["cdb_spec"] = jnp.where(take, st["fu_spec"][u], st["cdb_spec"])
        st["ticket"] = st["ticket"] + n_enq
        st["overflow"] = st["overflow"] | (n_done > n_free)

        # --- trace + unit release
        st["tr_complete"] = tw(st["tr_complete"], st["fu_uid"],
                               st["cycle"], done)
        st["fu_busy"] = st["fu_busy"] & ~done
        st["fu_uid"] = jnp.where(done, 0, st["fu_uid"])
        return st

    # ------------------------------------------------------------------
    # phase 2+3: memread tick and CDB grant
    # ------------------------------------------------------------------
    def memread_tick(st, alive):
        ticking = st["mr_active"] & alive
        rem = jnp.where(ticking, st["mr_rem"] - st["dt"], st["mr_rem"])
        fired = ticking & (rem <= 0)
        st["mr_rem"] = rem
        st["mr_active"] = st["mr_active"] & ~fired
        return st, fired

    def cdb_grant(st, br_ready, alive, tw, unroll=False):
        def grant_one(st, br_ready):
            ready = st["cdb_valid"] & (st["cdb_ready"] <= st["cycle"]) & alive
            idx = jnp.argmin(jnp.where(ready, st["cdb_ticket"], BIG))
            has = ready.any()
            uid = st["cdb_uid"][idx]
            st["cdb_valid"] = st["cdb_valid"] & ~(has & (c_iota == idx))
            st["rs_dep"] = jnp.where(has & (st["rs_dep"] == uid), 0, st["rs_dep"])
            st["trk_valid"] = st["trk_valid"] & ~(has & (st["trk_uid"] == uid))
            st["tr_broadcast"] = tw(st["tr_broadcast"], uid,
                                    st["cycle"], has)
            br_ready = br_ready | (has & st["br_active"]
                                   & (st["br_kind"] == isa.BR_BR)
                                   & (st["br_wait"] == uid))
            return st, br_ready
        # every scheduler model grants one broadcast per cycle (cdb_width
        # 1), so the restructured path inlines the single grant instead of
        # paying a length-1 ``lax.scan``; kernels unroll wider widths too
        # (a Python loop of the same body — identical ops, no scan carry)
        if (not base and c.cdb_width == 1) or unroll:
            for _ in range(c.cdb_width):
                st, br_ready = grant_one(st, br_ready)
            return st, br_ready

        def body(carry, _):
            return grant_one(*carry), None
        (st, br_ready), _ = jax.lax.scan(body, (st, br_ready), None,
                                         length=c.cdb_width)
        return st, br_ready

    # ------------------------------------------------------------------
    # phase 4: branch resolution
    # ------------------------------------------------------------------
    def branch_core(st, br_ready):
        """Branch resolution minus the two ``tr_aborted`` trace writes —
        returns the kill masks plus the uid arrays *as of the squash* (the
        core zeroes ``fu_uid``, and the frontend later overwrites
        ``rs_uid`` slots) so the caller can apply the aborted traces in
        whichever form suits its backend."""
        fire = st["br_active"] & br_ready
        value = st["mem"][remap(st, st["br_addr"])]
        taken = eval_cond(st["br_cond"], value, st["br_thr"])
        target = st["br_pc"] + jnp.where(taken, st["br_off"], 1)
        spec = st["br_speculating"]

        commit = fire & spec & ~taken
        squash = fire & spec & taken
        plain = fire & ~spec

        # --- commit: speculative state becomes architectural
        st["tlb_com"] = st["tlb_com"] | (commit & st["tlb_valid"])
        st["trk_spec"] = st["trk_spec"] & ~commit
        st["rs_spec"] = st["rs_spec"] & ~commit
        st["fu_spec"] = st["fu_spec"] & ~commit
        st["cdb_spec"] = st["cdb_spec"] & ~commit

        # --- squash: discard speculative state, roll back, redirect
        rs_kill = squash & st["rs_valid"] & st["rs_spec"]
        fu_kill = squash & st["fu_busy"] & st["fu_spec"]
        rs_uid_k, fu_uid_k = st["rs_uid"], st["fu_uid"]
        st["spec_aborted"] = (st["spec_aborted"]
                              + rs_kill.sum(dtype=I32) + fu_kill.sum(dtype=I32))
        st["rs_valid"] = st["rs_valid"] & ~rs_kill
        st["fu_busy"] = st["fu_busy"] & ~fu_kill
        st["fu_uid"] = jnp.where(fu_kill, 0, st["fu_uid"])
        st["trk_valid"] = st["trk_valid"] & ~(squash & st["trk_spec"])
        st["tlb_valid"] = st["tlb_valid"] & ~(squash & ~st["tlb_com"])
        st["cdb_valid"] = st["cdb_valid"] & ~(squash & st["cdb_spec"])
        st["regs"] = jnp.where(squash, st["spec_ckpt"], st["regs"])
        # the redirect (and the squash's decode-window reset) lands on the
        # branch-owning stream only
        mine = jnp.arange(st["pc"].shape[0], dtype=I32) == st["br_stream"]
        st["pc"] = jnp.where((squash | plain) & mine, target, st["pc"])
        st["fe_wait"] = jnp.where(squash & mine, 0, st["fe_wait"])

        st["spec_active"] = st["spec_active"] & ~(commit | squash)
        st["br_active"] = st["br_active"] & ~fire
        return st, (rs_uid_k, rs_kill, fu_uid_k, fu_kill)

    def abort_traces(st, kills, tw):
        rs_uid_k, rs_kill, fu_uid_k, fu_kill = kills
        st["tr_aborted"] = tw(st["tr_aborted"], rs_uid_k, True, rs_kill)
        st["tr_aborted"] = tw(st["tr_aborted"], fu_uid_k, True, fu_kill)
        return st

    # ------------------------------------------------------------------
    # phase 5: RS issue — weighted arbiter.  Ready entries are ordered by
    # the policy's scalar issue key (priority class first, age within a
    # class; all-equal weights degrade to pure age order).  A pid at its
    # per-class in-flight quota is masked out of the per-class free-rank
    # computation without consuming the unit, so the arbiter stays
    # work-conserving.  ``prio``/``quota`` are traced runtime arrays
    # (like ``n_fu``), so policies sweep under vmap without recompiling.
    # Unit selection within a class is a ranking too: free units are
    # ordered by ``ckey`` — plain FU index under greedy, (cost, index)
    # under EFT (``eft`` traced flag).  A granted entry's predicted
    # finish on a *free* unit is base cycles × unit cost (the busy
    # horizon of a free unit is zero, and busy units are never granted),
    # and the base is constant per class, so cost order IS finish order
    # for every entry — the k-th fired entry taking the k-th ckey-ranked
    # unit reproduces the golden oracle's sequential earliest-finish
    # pick exactly.  With eft=0 ckey is the FU index and the arbiter is
    # bit-identical to the historical greedy one.
    # ------------------------------------------------------------------
    nfu_iota = jnp.arange(NFU, dtype=I32)

    def _issue_apply(st, m, fire, cost, tw):
        """Shared arbiter tail: apply the entry→unit match matrix."""
        entry_of_unit = jnp.argmax(m, axis=0)      # valid where any col
        unit_hit = m.any(axis=0)

        st["fu_busy"] = st["fu_busy"] | unit_hit
        st["fu_uid"] = jnp.where(unit_hit, st["rs_uid"][entry_of_unit], st["fu_uid"])
        st["fu_rem"] = jnp.where(unit_hit,
                                 st["rs_exec"][entry_of_unit] * cost,
                                 st["fu_rem"])
        st["fu_out_s"] = jnp.where(unit_hit, st["rs_out_s"][entry_of_unit],
                                   st["fu_out_s"])
        st["fu_out_e"] = jnp.where(unit_hit, st["rs_out_e"][entry_of_unit],
                                   st["fu_out_e"])
        st["fu_src"] = jnp.where(unit_hit, st["rs_src"][entry_of_unit], st["fu_src"])
        st["fu_spec"] = jnp.where(unit_hit, st["rs_spec"][entry_of_unit],
                                  st["fu_spec"])
        st["fu_pid"] = jnp.where(unit_hit, st["rs_pid"][entry_of_unit],
                                 st["fu_pid"])
        st["tr_issue"] = tw(st["tr_issue"], st["rs_uid"],
                            st["cycle"], fire)
        st["rs_valid"] = st["rs_valid"] & ~fire
        return st

    def rs_issue_base(st, exists, prio, quota, cost, eft, alive, tw):
        ready = st["rs_valid"] & (st["rs_dep"] == 0) & alive
        free = exists & ~st["fu_busy"]
        n_free = jnp.zeros((NF,), I32).at[fu_cls].add(free.astype(I32))
        w = jnp.clip(prio[st["rs_pid"]], 0, PRIO_CAP)
        key = jnp.where(ready, (PRIO_CAP - w) * AGE_SPAN + st["rs_age"], BIG)
        key_lt = key[None, :] < key[:, None]
        same_cls = st["rs_func"][:, None] == st["rs_func"][None, :]
        same_pid = st["rs_pid"][:, None] == st["rs_pid"][None, :]
        # quota mask: units already running for (pid, class) plus ready
        # same-(pid, class) entries ahead in key order must stay under cap.
        # (An ahead entry that fails to issue can only fail for a resource
        # — class units or issue width — that equally blocks this entry,
        # so counting candidates instead of winners is exact.)
        busy = st["fu_busy"] & exists
        inflight = ((busy[None, :]
                     & (st["fu_pid"][None, :] == st["rs_pid"][:, None])
                     & (fu_cls[None, :] == st["rs_func"][:, None]))
                    .sum(axis=1).astype(I32))
        q_ahead = key_lt & same_cls & same_pid & ready[None, :]
        q_rank = q_ahead.sum(axis=1).astype(I32)
        quota_ok = inflight + q_rank < quota[st["rs_pid"]]
        eligible = ready & quota_ok
        # rank among eligible entries of the same class, by key
        c_ahead = key_lt & same_cls & eligible[None, :]
        cls_rank = c_ahead.sum(axis=1).astype(I32)
        issuable = eligible & (cls_rank < n_free[st["rs_func"]])
        # global width cap: smallest keys among issuable
        g_key = jnp.where(issuable, key, BIG)
        g_rank = (g_key[None, :] < g_key[:, None]).sum(axis=1).astype(I32)
        fire = issuable & (g_rank < c.issue_width)
        # among fired entries of a class, k-th by key → k-th free unit by index
        f_key = jnp.where(fire, key, BIG)
        f_ahead = (f_key[None, :] < f_key[:, None]) & same_cls & fire[None, :]
        f_rank = f_ahead.sum(axis=1).astype(I32)
        # per-class free rank: rank among free units of same class, by
        # ckey (greedy: FU index; eft: cost-major, index-minor — ckey is
        # unique per unit, so the ranking is a strict total order)
        ckey = (jnp.where(eft != 0, cost, 0) * NFU
                + jnp.arange(NFU, dtype=I32))
        cls_eq = fu_cls[None, :] == fu_cls[:, None]
        lower = cls_eq & free[None, :] & (ckey[None, :] < ckey[:, None])
        unit_rank = lower.sum(axis=1).astype(I32)
        # match matrix: entry e → unit u
        m = (fire[:, None] & free[None, :]
             & (st["rs_func"][:, None] == fu_cls[None, :])
             & (f_rank[:, None] == unit_rank[None, :]))
        return _issue_apply(st, m, fire, cost, tw)

    def rs_issue_fast(st, exists, prio, quota, cost, eft, alive, tw):
        """The restructured arbiter: same selection function as
        :func:`rs_issue_base` (bit-identical by the differential tests),
        restructured for the population width-cost curve — ONE (S, S) key
        comparison matrix feeds every rank (the issue key is unique among
        ready entries, so masking columns of ``key_lt`` IS re-ranking the
        masked subset), the unit ranking collapses from (NFU, NFU) to a
        per-class (NF, W, W) block, and rank sums narrow to int16 (S ≤ 32
        entries, ≤ W ≤ 16 units per class — int16 is exact)."""
        I16 = jnp.int16
        ready = st["rs_valid"] & (st["rs_dep"] == 0) & alive
        free = exists & ~st["fu_busy"]
        n_free = jnp.zeros((NF,), I32).at[fu_cls].add(free.astype(I32))
        w = jnp.clip(prio[st["rs_pid"]], 0, PRIO_CAP)
        key = jnp.where(ready, (PRIO_CAP - w) * AGE_SPAN + st["rs_age"], BIG)
        key_lt = key[None, :] < key[:, None]
        same_cls = st["rs_func"][:, None] == st["rs_func"][None, :]
        same_pid = st["rs_pid"][:, None] == st["rs_pid"][None, :]
        busy = st["fu_busy"] & exists
        inflight = ((busy[None, :]
                     & (st["fu_pid"][None, :] == st["rs_pid"][:, None])
                     & (fu_cls[None, :] == st["rs_func"][:, None]))
                    .sum(axis=1, dtype=I16))
        q_rank = (key_lt & same_cls & same_pid
                  & ready[None, :]).sum(axis=1, dtype=I16)
        quota_ok = inflight + q_rank < quota[st["rs_pid"]]
        eligible = ready & quota_ok
        cls_rank = (key_lt & same_cls & eligible[None, :]).sum(axis=1,
                                                               dtype=I16)
        issuable = eligible & (cls_rank < n_free[st["rs_func"]])
        # ``key`` is BIG on every non-ready entry and unique among ready
        # ones, so "rank within subset X" is just key_lt with X's columns
        # — no per-subset masked key or fresh comparison matrix needed
        g_rank = (key_lt & issuable[None, :]).sum(axis=1, dtype=I16)
        fire = issuable & (g_rank < c.issue_width)
        f_rank = (key_lt & same_cls & fire[None, :]).sum(axis=1, dtype=I16)
        # per-class unit ranking: units only ever compare within their own
        # class, so the (NFU, NFU) cls_eq matrix is 1/NF dead weight —
        # rank inside (NF, W, W) blocks instead
        Wc = spec.max_fu_per_class
        ckey = (jnp.where(eft != 0, cost, 0) * NFU
                + nfu_iota).reshape(NF, Wc)
        free_c = free.reshape(NF, Wc)
        lower = free_c[:, None, :] & (ckey[:, None, :] < ckey[:, :, None])
        unit_rank = lower.sum(axis=2, dtype=I16).reshape(NFU)
        m = (fire[:, None] & free[None, :]
             & (st["rs_func"][:, None] == fu_cls[None, :])
             & (f_rank[:, None] == unit_rank[None, :]))
        return _issue_apply(st, m, fire, cost, tw)

    rs_issue = rs_issue_base if base else rs_issue_fast

    # ------------------------------------------------------------------
    # phase 6: frontend — N per-tenant streams, one arbitrated dispatch.
    # Eligibility is computed per stream (arrived, undrained, decode
    # window free, not stalled on its own branch, next instruction able
    # to act), then one stream is granted by the arbiter key: frontend
    # weight class first, round-robin within a class.  A structurally
    # stalled TASK (full RS / tracker / pid at its rs_cap) makes its
    # stream ineligible — the arbiter skips it, which is what turns RS
    # admission caps into per-stream backpressure instead of the merged
    # model's head-of-line stall.  A single stream covering [0, p_len)
    # reduces to the historical merged frontend bit-for-bit.
    # ------------------------------------------------------------------
    def frontend_core(st, F, p_len, rs_cap, streams, alive):
        NS = streams.shape[0]
        ns_iota = jnp.arange(NS, dtype=I32)
        s_start, s_end = streams[:, 0], streams[:, 1]
        s_arr = streams[:, 2]
        s_w = jnp.clip(streams[:, 3], 0, PRIO_CAP)
        s_active = s_end > s_start
        pcs = st["pc"]
        drained_pre = pcs >= s_end
        arrived = st["cycle"] >= s_arr
        fe_free = st["fe_wait"] == 0

        # one shared branch unit / speculation domain: while a speculation
        # is open only the speculating stream runs; a non-speculative
        # branch stalls only its own stream
        br_mine = ns_iota == st["br_stream"]
        br_ok = jnp.where(st["br_active"],
                          jnp.where(st["br_speculating"], br_mine, ~br_mine),
                          True)
        base_elig = s_active & arrived & ~drained_pre & fe_free & br_ok & alive

        pccs = jnp.clip(pcs, 0, max(P - 1, 0))
        ops_s = F["op"][pccs]
        pids_s = F["pid"][pccs]
        kinds_s = F["ctl"][pccs] & 0x3

        # TASK-instruction gates (structural stalls + speculative TLB/TM)
        rs_full = st["rs_valid"].all()
        trk_full = st["trk_valid"].all()
        rs_of_pid = (st["rs_valid"][None, :]
                     & (st["rs_pid"][None, :] == pids_s[:, None])
                     ).sum(axis=1).astype(I32)
        pid_capped_s = rs_of_pid >= rs_cap[pids_s]
        empty_req = jnp.bool_(c.in_order) & ~machine_empty(st)
        spec = st["spec_active"]
        slot_used = jax.vmap(
            lambda s: (st["tlb_valid"] & (st["tlb_slot"] == s)).any())(
                jnp.arange(p.tm_slots))
        tm_slot = jnp.argmin(slot_used)
        tm_avail = ~slot_used.all()
        tlb_full = st["tlb_valid"].all()
        committed_seq = jnp.where(st["tlb_valid"] & st["tlb_com"],
                                  st["tlb_seq"], BIG)
        victim = jnp.argmin(committed_seq)
        has_victim = (committed_seq[victim] < BIG)
        # under speculation a TASK can act iff it can take a TLB/TM slot,
        # or a committed victim can be drained to free one
        spec_gate = jnp.where(tm_avail, ~tlb_full, has_victim)
        task_ok = (~rs_full & ~trk_full & ~pid_capped_s & ~empty_req
                   & (~spec | spec_gate))
        # IF: the one branch unit must be free; MR/BR additionally respect
        # the in-order cost model's empty-machine requirement
        if_ok = ~st["br_active"] & ((kinds_s == isa.BR_RR) | ~empty_req)
        elig = base_elig & jnp.where(ops_s == isa.OP_TASK, task_ok,
                                     jnp.where(ops_s == isa.OP_IF, if_ok,
                                               True))

        # the arbiter: weight class first, round-robin within a class
        key = jnp.where(elig, (PRIO_CAP - s_w) * NS
                        + ((ns_iota - st["fe_ptr"]) % NS), BIG)
        gidx = jnp.argmin(key).astype(I32)
        has = elig.any()
        gmask = has & (ns_iota == gidx)
        st["fe_ptr"] = jnp.where(has, (gidx + 1) % NS, st["fe_ptr"])

        # decode windows tick every cycle on every stream
        st["fe_wait"] = jnp.where(alive,
                                  jnp.maximum(st["fe_wait"] - st["dt"], 0),
                                  st["fe_wait"])

        # dispatch-stall accounting for this cycle (the event-skipped
        # window behind it is charged at the top of ``step`` — from
        # pre-phase state, before a branch squash can redirect a pc)
        stalled_now = s_active & arrived & ~drained_pre & ~gmask
        st["fe_stall"] = st["fe_stall"] + jnp.where(
            alive, stalled_now.astype(I32), 0)

        # scalar fetch of the granted stream's instruction
        pcc = pccs[gidx]
        pc_g = pcs[gidx]
        op = ops_s[gidx]
        a, asz, b, bsz = F["a"][pcc], F["asz"][pcc], F["b"][pcc], F["bsz"][pcc]
        ctl = F["ctl"][pcc]
        acc = F["acc"][pcc]
        active = has

        progressed = jnp.bool_(False)

        # ---- control ops (1 cycle each) --------------------------------
        is_add = active & (op == isa.OP_ADD)
        is_mul = active & (op == isa.OP_MUL)
        is_mov = active & (op == isa.OP_MOV)
        is_jmp = active & (op == isa.OP_JUMP)
        is_lbeg = active & (op == isa.OP_LBEG)
        is_lend = active & (op == isa.OP_LEND)
        is_nop = active & (op == isa.OP_NOP)

        regs = st["regs"]
        val = jnp.select(
            [is_add, is_mul, is_mov, is_lbeg],
            [regs[a] + regs[asz], regs[a] * regs[asz],
             jnp.where(ctl & isa.CTL_IMM, a, regs[a]),
             jnp.where(ctl & 1, regs[a], a)],
            0)
        wr_reg = jnp.select([is_add | is_mul | is_mov, is_lbeg],
                            [b, asz], -1)
        lend_val = regs[asz] - 1
        regs = jnp.where((jnp.arange(p.num_regs) == wr_reg)
                         & (is_add | is_mul | is_mov | is_lbeg), val, regs)
        regs = jnp.where((jnp.arange(p.num_regs) == asz) & is_lend,
                         lend_val, regs)
        st["regs"] = regs

        pc_next = pc_g
        pc_next = jnp.where(is_add | is_mul | is_mov | is_lbeg | is_nop,
                            pc_g + 1, pc_next)
        pc_next = jnp.where(is_jmp, a, pc_next)
        pc_next = jnp.where(is_lend,
                            jnp.where(lend_val > 0, pc_g - b, pc_g + 1),
                            pc_next)
        progressed = progressed | is_add | is_mul | is_mov | is_jmp \
            | is_lbeg | is_lend | is_nop

        # ---- task dispatch ---------------------------------------------
        # eligibility already cleared the structural gates (full RS /
        # tracker / rs_cap / in-order) and the speculative TLB/TM gate for
        # the granted stream — a granted TASK either dispatches or drains
        is_task = active & (op == isa.OP_TASK)
        in_s = jnp.where(ctl & isa.CTL_IN_INDIRECT, regs[a], a)
        out_s = jnp.where(ctl & isa.CTL_OUT_INDIRECT, regs[b], b)
        in_e, out_e = in_s + asz, out_s + bsz
        phys_in = remap(st, in_s)
        dep = tracker_lookup(st, phys_in, phys_in + (in_e - in_s))

        # drain path: TM full and a committed victim exists
        do_drain = is_task & spec & ~tm_avail
        vic_base = p.tm_base + st["tlb_slot"][victim] * p.tm_slot_words
        st["mem"] = copy_window(st["mem"], st["mem"], st["tlb_os"][victim],
                                vic_base,
                                st["tlb_oe"][victim] - st["tlb_os"][victim],
                                do_drain)
        st["tlb_valid"] = st["tlb_valid"] & ~(do_drain
                                              & (l_iota == victim))
        st["fe_wait"] = jnp.where(gmask & do_drain, p.tlb_drain_cycles,
                                  st["fe_wait"])

        dispatch = is_task & ~do_drain
        phys_out = jnp.where(spec, p.tm_base + tm_slot * p.tm_slot_words, out_s)
        phys_oe = phys_out + (out_e - out_s)

        # TLB insert for speculative dispatch
        tlb_slot_new = jnp.argmin(st["tlb_valid"])
        ins_tlb = dispatch & spec
        tlb_sel = ins_tlb & (l_iota == tlb_slot_new)
        st["tlb_valid"] = st["tlb_valid"] | tlb_sel
        st["tlb_os"] = jnp.where(tlb_sel, out_s, st["tlb_os"])
        st["tlb_oe"] = jnp.where(tlb_sel, out_e, st["tlb_oe"])
        st["tlb_slot"] = jnp.where(tlb_sel, tm_slot, st["tlb_slot"])
        st["tlb_seq"] = jnp.where(tlb_sel, st["tlb_seq_ctr"], st["tlb_seq"])
        st["tlb_com"] = st["tlb_com"] & ~tlb_sel
        st["tlb_seq_ctr"] = st["tlb_seq_ctr"] + jnp.where(ins_tlb, 1, 0)

        # WAW replacement + tracker insert
        waw = dispatch & st["trk_valid"] & (st["trk_s"] < phys_oe) \
            & (phys_out < st["trk_e"])
        st["trk_valid"] = st["trk_valid"] & ~waw
        trk_new = jnp.argmin(st["trk_valid"])
        trk_sel = dispatch & (t_iota == trk_new)
        st["trk_valid"] = st["trk_valid"] | trk_sel
        st["trk_s"] = jnp.where(trk_sel, phys_out, st["trk_s"])
        st["trk_e"] = jnp.where(trk_sel, phys_oe, st["trk_e"])
        st["trk_uid"] = jnp.where(trk_sel, st["next_uid"], st["trk_uid"])
        st["trk_spec"] = jnp.where(trk_sel, spec, st["trk_spec"])

        # RS insert
        rs_new = jnp.argmin(st["rs_valid"])
        uid = st["next_uid"]
        st["overflow"] = st["overflow"] | (dispatch & (uid >= U)) \
            | (dispatch & (out_e - out_s > W))
        uidc = jnp.clip(uid, 0, U - 1)
        pidv = F["pid"][pcc]
        rs_sel = dispatch & (s_iota == rs_new)
        st["rs_valid"] = st["rs_valid"] | rs_sel
        for k, v in (("rs_uid", uid), ("rs_func", acc),
                     ("rs_dep", dep), ("rs_age", st["age"]),
                     ("rs_out_s", phys_out), ("rs_out_e", phys_oe),
                     ("rs_src", out_s), ("rs_exec", func_cycles[jnp.clip(acc, 0, NF - 1)]),
                     ("rs_pid", pidv)):
            st[k] = jnp.where(rs_sel, v, st[k])
        st["rs_spec"] = jnp.where(rs_sel, spec, st["rs_spec"])
        st["next_uid"] = st["next_uid"] + jnp.where(dispatch, 1, 0)
        st["age"] = st["age"] + jnp.where(dispatch, 1, 0)
        st["fe_wait"] = jnp.where(gmask & dispatch,
                                  c.dispatch_serial_cost - 1, st["fe_wait"])
        pc_next = jnp.where(dispatch, pc_g + 1, pc_next)
        progressed = progressed | dispatch

        # ---- if / branches ----------------------------------------------
        is_if = active & (op == isa.OP_IF) & ~st["br_active"]
        kind = ctl & 0x3
        cond = (ctl >> 2) & 0x3
        thr = regs[asz]
        # RR: resolve inline with a 1-cycle bubble
        rr = is_if & (kind == isa.BR_RR)
        rr_taken = eval_cond(cond, regs[a], thr)
        pc_next = jnp.where(rr, jnp.where(rr_taken, pc_g + b, pc_g + 1),
                            pc_next)
        st["fe_wait"] = jnp.where(gmask & rr, 1, st["fe_wait"])
        # MR/BR (eligibility already cleared the in-order empty-machine
        # requirement and the branch unit being free)
        mrbr = is_if & (kind != isa.BR_RR)
        phys_a = remap(st, a)
        wait_uid = tracker_lookup(st, phys_a, phys_a + 1)
        eff_kind = jnp.where((kind == isa.BR_BR) & (wait_uid == 0),
                             I32(isa.BR_MR), kind)
        speculate = jnp.bool_(c.speculation) & ~st["spec_active"]
        st["br_active"] = st["br_active"] | mrbr
        for k, v in (("br_kind", eff_kind), ("br_pc", pc_g), ("br_off", b),
                     ("br_cond", cond), ("br_thr", thr), ("br_addr", a),
                     ("br_wait", wait_uid), ("br_stream", gidx)):
            st[k] = jnp.where(mrbr, v, st[k])
        st["br_speculating"] = jnp.where(mrbr, speculate, st["br_speculating"])
        start_mr = mrbr & (eff_kind == isa.BR_MR)
        st["mr_active"] = st["mr_active"] | start_mr
        st["mr_rem"] = jnp.where(start_mr, p.mem_read_cycles, st["mr_rem"])
        enter_spec = mrbr & speculate
        st["spec_active"] = st["spec_active"] | enter_spec
        st["spec_ckpt"] = jnp.where(enter_spec, regs, st["spec_ckpt"])
        pc_next = jnp.where(enter_spec, pc_g + 1, pc_next)
        progressed = progressed | rr | mrbr

        st["pc"] = jnp.where(gmask, pc_next, pcs)
        st["stall_cycles"] = st["stall_cycles"] + jnp.where(
            progressed | ~alive, 0, 1)
        # the four dispatch trace writes share one (uid, enable) selector;
        # the caller applies them (select-form, scatter-form, or a fused
        # pallas kernel, per step_impl)
        fe = dict(uid=uidc, acc=acc, dep=dep, pid=pidv, dispatch=dispatch)
        return st, fe

    def dispatch_traces(st, fe, tw=None):
        if tw is not None or base:
            tw = tw or trace_write
            st["tr_func"] = tw(st["tr_func"], fe["uid"], fe["acc"],
                               fe["dispatch"])
            st["tr_dispatch"] = tw(st["tr_dispatch"], fe["uid"],
                                   st["cycle"], fe["dispatch"])
            st["tr_dep"] = tw(st["tr_dep"], fe["uid"], fe["dep"],
                              fe["dispatch"])
            st["tr_pid"] = tw(st["tr_pid"], fe["uid"], fe["pid"],
                              fe["dispatch"])
            return st
        sel = trace_sel(fe["uid"], fe["dispatch"])
        st["tr_func"] = trace_put(st["tr_func"], sel, fe["acc"])
        st["tr_dispatch"] = trace_put(st["tr_dispatch"], sel, st["cycle"])
        st["tr_dep"] = trace_put(st["tr_dep"], sel, fe["dep"])
        st["tr_pid"] = trace_put(st["tr_pid"], sel, fe["pid"])
        return st

    # ------------------------------------------------------------------
    # event-skip: time to the next scheduler event
    # ------------------------------------------------------------------
    def next_dt(st, exists, F, p_len, rs_cap, streams):
        if not spec.event_skip:
            return I32(1)
        busy = st["fu_busy"] & exists
        cands = jnp.where(busy, st["fu_rem"], BIG)
        dt = jnp.min(cands)
        dt = jnp.minimum(dt, jnp.where(st["mr_active"], st["mr_rem"], BIG))
        cdb_dt = jnp.where(st["cdb_valid"],
                           jnp.maximum(st["cdb_ready"] - st["cycle"], 1), BIG)
        dt = jnp.minimum(dt, jnp.min(cdb_dt))
        dt = jnp.minimum(dt, jnp.min(jnp.where(st["fe_wait"] > 0,
                                               st["fe_wait"], BIG)))
        # any stream's frontend can act next cycle → no skipping
        NS = streams.shape[0]
        ns_iota = jnp.arange(NS, dtype=I32)
        s_end, s_arr = streams[:, 1], streams[:, 2]
        s_active = s_end > streams[:, 0]
        drained = st["pc"] >= s_end
        pccs = jnp.clip(st["pc"], 0, max(P - 1, 0))
        at_op = F["op"][pccs]
        in_order_block = (jnp.bool_(c.in_order) & ~machine_empty(st)
                          & ((at_op == isa.OP_TASK) | (at_op == isa.OP_IF)))
        # structural stall: a TASK blocked on a full RS / Memory Tracker /
        # its pid's RS admission cap can only unblock via an issue (covered
        # below) or a CDB grant (in the min) — skippable
        pid_here = F["pid"][pccs]
        pid_capped = ((st["rs_valid"][None, :]
                       & (st["rs_pid"][None, :] == pid_here[:, None]))
                      .sum(axis=1).astype(I32) >= rs_cap[pid_here])
        struct_block = ((at_op == isa.OP_TASK)
                        & (st["rs_valid"].all() | st["trk_valid"].all()
                           | pid_capped))
        br_mine = ns_iota == st["br_stream"]
        br_ok = jnp.where(st["br_active"],
                          jnp.where(st["br_speculating"], br_mine, ~br_mine),
                          True)
        fe_act = ((st["fe_wait"] == 0) & br_ok & s_active & ~drained
                  & (s_arr <= st["cycle"] + 1)
                  & ~in_order_block & ~struct_block)
        dt = jnp.where(fe_act.any(), 1, dt)
        # never skip across a stream arrival (frontend state changes there,
        # and the per-stream stall accounting relies on windows lying
        # entirely on one side of every arrival)
        arr_dt = jnp.where(s_active & ~drained & (s_arr > st["cycle"]),
                           s_arr - st["cycle"], BIG)
        dt = jnp.minimum(dt, jnp.min(arr_dt))
        # a ready RS entry with a free unit issues next cycle
        free = exists & ~st["fu_busy"]
        n_free = jnp.zeros((NF,), I32).at[fu_cls].add(free.astype(I32))
        ready = st["rs_valid"] & (st["rs_dep"] == 0)
        issue_now = (ready & (n_free[st["rs_func"]] > 0)).any()
        dt = jnp.where(issue_now, 1, dt)
        return jnp.clip(dt, 1, BIG)

    # ------------------------------------------------------------------
    # full step + driver
    # ------------------------------------------------------------------
    def alive_of(st):
        return (~st["halted"] & ~st["overflow"]
                & (st["cycle"] < spec.max_cycles))

    def step_top(st, streams, limit):
        # ``alive`` gates every phase: a halted/overflowed lane is a fixed
        # point of the step, so the batched population machine can run one
        # while-loop with a scalar any-lane-alive condition and NO
        # per-lane carry select (see ``run_population``).  In the single
        # machine the while condition implies alive == True, so the gates
        # are identities.  ``limit`` is the lane's *step-count* ceiling
        # for this entry (BIG = run to completion): a lane at its limit
        # freezes as a fixed point too, which is what lets ``run_slice``
        # pause and re-enter the loop with bit-exact composition.  The
        # ceiling counts steps (while-loop trips), not cycles, because
        # under event-skip a trip's cycle advance is arbitrary — steps
        # are the unit wall time is actually spent in, so a step ceiling
        # bounds a slice's cost where a cycle ceiling cannot (one
        # event-dense lane can burn hundreds of trips inside a modest
        # cycle window).
        alive = alive_of(st) & (st["steps"] < limit)
        st["steps"] = st["steps"] + jnp.where(alive, 1, 0)
        # Per-stream dispatch-stall accounting for the event-skipped window
        # behind this step (``dt - 1`` cycles with no events, hence no
        # grants).  It must read *pre-phase* state: the window's cycles lie
        # before this step's branch resolve, which may redirect a stream's
        # pc (squash) and flip its drained status.  next_dt clamps dt to
        # arrival boundaries, so a stream was either arrived for the whole
        # window or for none of it.
        w_stalled = ((streams[:, 1] > streams[:, 0])
                     & (st["pc"] < streams[:, 1])
                     & (streams[:, 2] <= st["cycle"] - st["dt"]))
        st["fe_stall"] = st["fe_stall"] + jnp.where(
            alive & w_stalled, st["dt"] - 1, 0)
        return st, alive

    def step_bottom(st, exists, F, p_len, rs_cap, streams, alive):
        done = ((st["pc"] >= streams[:, 1]).all() & ~st["rs_valid"].any()
                & ~st["fu_busy"].any()
                & ~st["cdb_valid"].any() & ~st["br_active"] & ~st["mr_active"]
                & (st["fe_wait"] == 0).all())
        dt = next_dt(st, exists, F, p_len, rs_cap, streams)
        st["cycle"] = st["cycle"] + jnp.where(alive,
                                              jnp.where(done, 1, dt), 0)
        st["dt"] = jnp.where(alive, dt, st["dt"])
        st["halted"] = st["halted"] | (alive & done)
        return st

    def step(st, exists, F, p_len, prio, quota, rs_cap, cost, eft, streams,
             effects, limit):
        st, alive = step_top(st, streams, limit)
        st, fu_done = fu_exec(st, exists, effects, alive)
        st = cdb_enqueue(st, fu_done, trace_write)
        st, br_ready = memread_tick(st, alive)
        st, br_ready = cdb_grant(st, br_ready, alive, trace_write)
        st, kills = branch_core(st, br_ready)
        st = abort_traces(st, kills, trace_write)
        st = rs_issue(st, exists, prio, quota, cost, eft, alive, trace_write)
        st, fe = frontend_core(st, F, p_len, rs_cap, streams, alive)
        st = dispatch_traces(st, fe)
        return step_bottom(st, exists, F, p_len, rs_cap, streams, alive)

    # ------------------------------------------------------------------
    # the pallas population step: the same phase functions, but the
    # scatter/select-heavy ones run as fused lane-per-program kernels
    # (pallas_step.py) over the whole population, and the rest are
    # vmapped.  ``pl.pallas_call`` cannot sit under ``jax.vmap``, which
    # is why this is a population-level step rather than a per-lane one.
    # Inside a kernel there is no batch axis, so the trace writes use the
    # single-machine scatter form (``tw_scatter``) — cheap per lane.
    # ------------------------------------------------------------------
    ENQ_KEYS = ("cdb_valid", "cdb_uid", "cdb_ticket", "cdb_ready",
                "cdb_spec", "ticket", "overflow", "tr_complete",
                "fu_busy", "fu_uid")
    GRANT_KEYS = ("cdb_valid", "rs_dep", "trk_valid", "tr_broadcast")
    ISSUE_KEYS = ("fu_busy", "fu_uid", "fu_rem", "fu_out_s", "fu_out_e",
                  "fu_src", "fu_spec", "fu_pid", "tr_issue", "rs_valid")
    TRACE_KEYS = ("tr_func", "tr_dispatch", "tr_dep", "tr_pid",
                  "tr_aborted")

    def make_pop_step():
        from . import pallas_step as ps

        def k_enqueue(v):
            return cdb_enqueue(v, v["done"], tw_scatter)

        def k_grant(v):
            st2, br = cdb_grant(v, v["br_ready"], v["alive"], tw_scatter,
                                unroll=True)
            st2["br_ready"] = br
            return st2

        def k_issue(v):
            return rs_issue(v, v["exists"], v["prio"], v["quota"],
                            v["cost"], v["eft"], v["alive"], tw_scatter)

        def k_traces(v):
            st2 = dict(v)
            st2 = abort_traces(
                st2, (v["rs_uid_k"], v["rs_kill"], v["fu_uid_k"],
                      v["fu_kill"]), tw_scatter)
            sel = jnp.where(v["dispatch"], v["uid"], U)
            for key, val in (("tr_func", v["acc"]),
                             ("tr_dispatch", v["cycle"]),
                             ("tr_dep", v["dep"]), ("tr_pid", v["pid"])):
                st2[key] = st2[key].at[sel].set(val, mode="drop")
            return st2

        def pop_step(st, exists, F, p_len, prio, quota, rs_cap, cost, eft,
                     streams, effects, limit):
            st, alive = jax.vmap(step_top)(st, streams, limit)
            st, fu_done = jax.vmap(fu_exec)(st, exists, effects, alive)

            ins = {k: st[k] for k in ENQ_KEYS}
            ins.update(done=fu_done, cycle=st["cycle"],
                       fu_spec=st["fu_spec"])
            st.update(ps.lane_phase(k_enqueue, ins, ENQ_KEYS))

            st, fired = jax.vmap(memread_tick)(st, alive)

            ins = {k: st[k] for k in GRANT_KEYS}
            ins.update(cdb_ready=st["cdb_ready"], cdb_ticket=st["cdb_ticket"],
                       cdb_uid=st["cdb_uid"], cycle=st["cycle"],
                       trk_uid=st["trk_uid"], br_active=st["br_active"],
                       br_kind=st["br_kind"], br_wait=st["br_wait"],
                       br_ready=fired, alive=alive)
            out = ps.lane_phase(k_grant, ins, GRANT_KEYS + ("br_ready",))
            br_ready = out.pop("br_ready")
            st.update(out)

            st, kills = jax.vmap(branch_core)(st, br_ready)
            rs_uid_k, rs_kill, fu_uid_k, fu_kill = kills

            ins = {k: st[k] for k in ISSUE_KEYS}
            ins.update(rs_dep=st["rs_dep"], rs_pid=st["rs_pid"],
                       rs_age=st["rs_age"], rs_func=st["rs_func"],
                       rs_uid=st["rs_uid"], rs_exec=st["rs_exec"],
                       rs_out_s=st["rs_out_s"], rs_out_e=st["rs_out_e"],
                       rs_src=st["rs_src"], rs_spec=st["rs_spec"],
                       cycle=st["cycle"], exists=exists, prio=prio,
                       quota=quota, cost=cost, eft=eft, alive=alive)
            st.update(ps.lane_phase(k_issue, ins, ISSUE_KEYS))

            st, fe = jax.vmap(frontend_core)(st, F, p_len, rs_cap, streams,
                                             alive)

            ins = {k: st[k] for k in TRACE_KEYS}
            ins.update(rs_uid_k=rs_uid_k, rs_kill=rs_kill,
                       fu_uid_k=fu_uid_k, fu_kill=fu_kill,
                       uid=fe["uid"], acc=fe["acc"], dep=fe["dep"],
                       pid=fe["pid"], dispatch=fe["dispatch"],
                       cycle=st["cycle"])
            st.update(ps.lane_phase(k_traces, ins, TRACE_KEYS))

            return jax.vmap(step_bottom)(st, exists, F, p_len, rs_cap,
                                         streams, alive)

        return pop_step

    # the population step: kernel-phased under pallas, plain vmap otherwise
    vstep = make_pop_step() if impl == "pallas" else jax.vmap(step)

    def norm_args(ftab, p_len, n_fu, prio, quota, rs_cap, fu_cost, eft,
                  streams):
        F = {name: ftab[..., i].astype(I32)
             for i, name in enumerate(isa.FIELDS)}
        p_len = jnp.asarray(p_len, I32)
        exists = fu_pos < n_fu[..., fu_cls]
        if prio is None:
            prio = jnp.zeros((NUM_PIDS,), I32)
        if quota is None:
            quota = jnp.full((NUM_PIDS,), BIG, I32)
        if rs_cap is None:
            rs_cap = jnp.full((NUM_PIDS,), BIG, I32)
        if fu_cost is None:
            # all-ones = every unit of a class identical (the paper's pool)
            cost = jnp.ones(p_len.shape + (NFU,), I32)
        else:
            fu_cost = jnp.asarray(fu_cost, I32)
            w = fu_cost.shape[-1]
            if w > spec.max_fu_per_class:
                # tables are packed at the canonical width (costs.
                # FU_COST_WIDTH); a narrower machine uses the prefix —
                # unit indices ≥ max_fu_per_class don't exist here
                fu_cost = fu_cost[..., :spec.max_fu_per_class]
            elif w < spec.max_fu_per_class:
                fu_cost = jnp.concatenate(
                    [fu_cost, jnp.ones(fu_cost.shape[:-1]
                                       + (spec.max_fu_per_class - w,), I32)],
                    axis=-1)
            # flatten (NF, max_fu) → (NFU,) row-major: matches fu_cls/fu_pos
            cost = fu_cost.reshape(fu_cost.shape[:-2] + (NFU,))
        eft = (jnp.zeros(p_len.shape, I32) if eft is None
               else jnp.asarray(eft, I32))
        if streams is None:
            # the historical single merged frontend: one stream covering
            # [0, p_len), arrival 0 (population form gets a leading axis)
            streams = (jnp.zeros(p_len.shape + (1, 4), I32)
                       .at[..., 0, 1].set(p_len))
        else:
            streams = jnp.asarray(streams, I32)
        return F, p_len, exists, prio, quota, rs_cap, cost, eft, streams

    def collect(st):
        return dict(
            cycles=st["cycle"], halted=st["halted"], overflow=st["overflow"],
            n_tasks=st["next_uid"] - 1, spec_aborted=st["spec_aborted"],
            stall_cycles=st["stall_cycles"], steps=st["steps"],
            fe_stall=st["fe_stall"],
            fu_busy_cycles=st["fu_busy_cycles"],
            mem=st["mem"], regs=st["regs"],
            tr_func=st["tr_func"], tr_dispatch=st["tr_dispatch"],
            tr_issue=st["tr_issue"], tr_complete=st["tr_complete"],
            tr_broadcast=st["tr_broadcast"], tr_dep=st["tr_dep"],
            tr_aborted=st["tr_aborted"], tr_pid=st["tr_pid"],
        )

    def run(ftab, p_len, n_fu, mem_init, effects, prio=None, quota=None,
            rs_cap=None, fu_cost=None, eft=None, streams=None):
        F, p_len, exists, prio, quota, rs_cap, cost, eft, streams = norm_args(
            ftab, p_len, n_fu, prio, quota, rs_cap, fu_cost, eft, streams)
        effects = jnp.asarray(effects, I32)
        st = init_state(mem_init, streams)
        st = jax.lax.while_loop(
            lambda s: alive_of(s).any(),
            lambda s: step(s, exists, F, p_len, prio, quota, rs_cap,
                           cost, eft, streams, effects, BIG),
            st)
        return collect(st)

    def run_population(ftab, p_len, n_fu, mem_init, effects,
                       prio, quota, rs_cap, fu_cost=None, eft=None,
                       streams=None):
        """The scenario-batched machine: every argument carries a leading
        scenario axis, and the whole population runs in ONE while loop
        whose condition is scalar (any lane alive).  Because a dead lane
        is a fixed point of ``step``, no per-lane select over the carry is
        needed — which is what makes this markedly faster than
        ``vmap(run)`` (the generic batching of a while loop masks the
        whole ~25 KB/lane state every iteration)."""
        F, p_len, exists, prio, quota, rs_cap, cost, eft, streams = norm_args(
            ftab, p_len, n_fu, prio, quota, rs_cap, fu_cost, eft, streams)
        effects = jnp.asarray(effects, I32)
        st = jax.vmap(init_state)(jnp.asarray(mem_init, I32), streams)

        limit = jnp.full_like(p_len, BIG)
        st = jax.lax.while_loop(
            lambda s: alive_of(s).any(),
            lambda s: vstep(s, exists, F, p_len, prio, quota, rs_cap,
                            cost, eft, streams, effects, limit),
            st)
        return collect(st)

    # ------------------------------------------------------------------
    # resumable population machine: the same while loop, re-enterable
    # ------------------------------------------------------------------
    def init_population(ftab, p_len, n_fu, mem_init, effects,
                        prio, quota, rs_cap, fu_cost=None, eft=None,
                        streams=None):
        """The population while-loop carry, fresh: one state row per lane.

        Only ``pc`` (= each stream's start pc) and ``mem`` (= the memory
        image) depend on the arguments — every other field is a constant
        fill — which is the invariant lane refill relies on (a host can
        build a fresh row for a *different* program from any fresh row by
        overwriting just those two fields).  ``fu_cost``/``eft`` stay out
        of the carry for the same reason: like ``prio`` they are
        loop-invariant step inputs, re-supplied on every slice.
        """
        _, p_len, _, _, _, _, _, _, streams = norm_args(
            ftab, p_len, n_fu, prio, quota, rs_cap, fu_cost, eft, streams)
        return jax.vmap(init_state)(jnp.asarray(mem_init, I32), streams)

    def run_slice(carry, ftab, p_len, n_fu, mem_init, effects,
                  prio, quota, rs_cap, fu_cost, eft, streams, budget):
        """Advance every alive lane by at most ``budget`` machine steps.

        Per-lane limits are ``carry steps + budget`` at entry, so every
        lane pauses exactly at its ceiling and the returned carry feeds
        straight back in.  The budget counts *steps* (while-loop trips),
        not cycles: under event-skip a trip's cycle advance is data-
        dependent, so only a step ceiling bounds what a slice costs in
        wall time — which is the whole point of slicing.  ``budget`` is
        traced: sweeping it never recompiles.  ``mem_init`` is unused
        (the carry owns the memory image) but kept so the argument list
        stays exactly ``PackedPopulation.machine_args()``.
        """
        F, p_len, exists, prio, quota, rs_cap, cost, eft, streams = norm_args(
            ftab, p_len, n_fu, prio, quota, rs_cap, fu_cost, eft, streams)
        effects = jnp.asarray(effects, I32)
        limit = carry["steps"] + jnp.asarray(budget, I32)
        return jax.lax.while_loop(
            lambda s: (alive_of(s) & (s["steps"] < limit)).any(),
            lambda s: vstep(s, exists, F, p_len, prio, quota, rs_cap,
                            cost, eft, streams, effects, limit),
            carry)

    def run_one(ftab, p_len, n_fu, mem_init, effects, prio=None, quota=None,
                rs_cap=None, fu_cost=None, eft=None, streams=None):
        """Single-lane pallas machine: a population of one, squeezed.

        ``pl.pallas_call`` cannot sit under ``jax.vmap``, so the pallas
        step only exists in population form — the single machine lifts
        its arguments onto a width-1 scenario axis and drops it from the
        outputs.  Nones that ``norm_args`` would default *unbatched*
        (the pid tables) are defaulted here first."""
        if prio is None:
            prio = jnp.zeros((NUM_PIDS,), I32)
        if quota is None:
            quota = jnp.full((NUM_PIDS,), BIG, I32)
        if rs_cap is None:
            rs_cap = jnp.full((NUM_PIDS,), BIG, I32)

        def lift(x):
            return None if x is None else jnp.asarray(x)[None]
        out = run_population(lift(ftab), lift(p_len), lift(n_fu),
                             lift(mem_init), lift(effects), lift(prio),
                             lift(quota), lift(rs_cap), lift(fu_cost),
                             lift(eft), lift(streams))
        return jax.tree.map(lambda x: x[0], out)

    if resumable:
        if not population:
            raise ValueError("resumable=True requires population=True")
        return ResumableMachine(init=init_population, run_slice=run_slice,
                                collect=collect)
    if population:
        return run_population
    return run_one if impl == "pallas" else run


@functools.lru_cache(maxsize=32)
def _compiled(spec: MachineSpec, max_prog: int):
    return jax.jit(make_machine(spec, max_prog))


def pack_program(code: np.ndarray, max_prog: int) -> tuple[np.ndarray, int]:
    """Decode + zero-pad a program to the machine's static table shape."""
    tbl = isa.decode_table(code)
    p_len = len(tbl)
    if p_len > max_prog:
        raise ValueError(f"program length {p_len} > max_prog {max_prog}")
    pad = np.zeros((max_prog, tbl.shape[1]), np.int32)
    pad[:p_len] = tbl
    # padding rows decode as acc-id 0 tasks but are never fetched (pc >= p_len)
    return pad, p_len


def images(params: HtsParams, mem_init=None, effects=None):
    mem = np.zeros((params.total_mem,), np.int32)
    eff = np.zeros((params.total_mem,), np.int32)
    for k, v in (mem_init or {}).items():
        mem[k] = v
    for k, v in (effects or {}).items():
        eff[k] = v
    return mem, eff


def simulate(code: np.ndarray, costs: SchedulerCosts,
             params: HtsParams = HtsParams(),
             n_fu=None, mem_init=None, effects=None,
             event_skip: bool = True, max_cycles: int = 5_000_000,
             max_fu_per_class: int = 16, max_prog: int = 256,
             policy: SchedPolicy | None = None,
             fu_cost=None,
             streams=None, step_impl: str = "xla") -> dict[str, Any]:
    """One-shot convenience wrapper around the cached compiled machine.

    ``policy`` (defaulting to ``params.policy``) is lowered to the traced
    ``prio``/``quota`` runtime arrays — the compiled machine is shared
    across policies, so sweeping weights never recompiles.  ``fu_cost``
    (defaulting to ``params.fu_cost``) is the per-(class, unit) latency
    table, and the policy's ``issue_mode`` lowers to the traced ``eft``
    flag — both runtime data too, so heterogeneous cost sweeps and
    greedy/EFT flips share the one compilation.  ``streams`` is the
    optional (n_streams, 4) per-tenant frontend table
    (``frontend.STREAM_FIELDS``); ``None`` = one merged frontend.
    """
    pol = policy if policy is not None else params.policy
    cost = fu_cost if fu_cost is not None else params.fu_cost
    # the policy and cost table reach the machine as runtime data, never as
    # part of the compilation key — canonicalise them out of the cached
    # MachineSpec
    ms = MachineSpec(params=dataclasses.replace(params, policy=SchedPolicy(),
                                                fu_cost=None),
                     costs=costs, event_skip=event_skip,
                     max_cycles=max_cycles, max_fu_per_class=max_fu_per_class,
                     step_impl=step_impl)
    run = _compiled(ms, max_prog)
    ftab, p_len = pack_program(code, max_prog)
    n_fu = jnp.asarray(n_fu if n_fu is not None else params.n_fu, I32)
    mem, eff = images(params, mem_init, effects)
    out = run(jnp.asarray(ftab), p_len, n_fu, jnp.asarray(mem),
              jnp.asarray(eff), jnp.asarray(pol.weight_array(), I32),
              jnp.asarray(pol.quota_array(), I32),
              jnp.asarray(pol.rs_cap_array(), I32),
              jnp.asarray(norm_fu_cost(cost), I32),
              jnp.asarray(1 if pol.issue_mode == "eft" else 0, I32),
              None if streams is None else jnp.asarray(streams, I32))
    return jax.tree.map(np.asarray, out)


def schedule_tuple(out: dict[str, Any]) -> list[tuple]:
    """Match golden.Result.schedule_tuple() for equivalence tests."""
    n = int(out["n_tasks"])
    rows = []
    for uid in range(1, n + 1):
        rows.append((uid, int(out["tr_func"][uid]), int(out["tr_dispatch"][uid]),
                     int(out["tr_issue"][uid]), int(out["tr_complete"][uid]),
                     int(out["tr_broadcast"][uid]), bool(out["tr_aborted"][uid]),
                     int(out["tr_pid"][uid])))
    return rows
