"""Multi-application accelerator sharing (the paper's headline motivation).

Abstract: "developing an example heterogeneous system to enable multiple
applications to share the available accelerators."  The ISA's *process id*
field tags each task's owner; this module builds a second real application
(image compression — DCT-quantize-correlate per tile, Fig 2's image-processing
example), interleaves it with the audio-compression stream under one HTS, and
compares shared execution against running the two programs serially on the
same accelerator pool.

Complementary mixes (audio = FIR/FFT-heavy, image = DCT-heavy) are where
Function-level accelerators pay off: the shared makespan approaches
max(app_a, app_b) rather than their sum.
"""
from __future__ import annotations

from .programs import Bench

IMG_BASE = 0x800        # image app's region space (disjoint from audio's)


def _ptask(func, in_s, in_sz, out_s, out_sz, tid=0, pid=0):
    return f"{func} {in_s:x} {in_sz:x} {out_s:x} {out_sz:x} " \
           f"{tid & 0xF:x} {pid:x} 0 0"


def image_compression(tiles: int = 8) -> Bench:
    """Per 8×8 tile: DCT → vector_max (quantization range proxy) →
    correlation against the previous tile (inter-tile prediction) →
    vector_add (residual).  Straight-line (unrolled), pid=1."""
    lines = []
    prev_out = 0
    for t in range(tiles):
        tile_in = IMG_BASE + t * 0x20
        dct_out = tile_in + 0x8
        max_out = tile_in + 0x10
        cor_out = tile_in + 0x11
        res_out = tile_in + 0x18
        lines.append(_ptask("dct", tile_in, 8, dct_out, 8, tid=t, pid=1))
        lines.append(_ptask("vector_max", dct_out, 8, max_out, 1, tid=t,
                            pid=1))
        if prev_out:
            lines.append(_ptask("correlation", dct_out, 8, cor_out, 1,
                                tid=t, pid=1))
        lines.append(_ptask("vector_add", dct_out, 8, res_out, 8, tid=t,
                            pid=1))
        prev_out = dct_out
    return Bench("image_compression", "\n".join(lines), {}, {})


def audio_straightline(bands: int = 8) -> Bench:
    """Unrolled audio compression, frequency-domain path (pid=0)."""
    lines = [_ptask("correlation", 0x10, 4, 0x20, 1, tid=0)]
    for b in range(bands):
        base = 0x100 + b * 0x20
        lines.append(_ptask("fft_256", base, 4, base + 8, 4, tid=1))
        for j in range(3):
            lines.append(_ptask("vector_dot", base + 8, 4, base + 0x10 + j,
                                1, tid=2 + j))
        lines.append(_ptask("fft_256", base + 0x10, 4, base + 0x18, 4, tid=5))
    return Bench("audio_straightline", "\n".join(lines), {}, {})


def interleave(a: Bench, b: Bench, name: str = "shared") -> Bench:
    """Round-robin merge of two straight-line task streams (two CPUs pushing
    into the one Task Queue; pids distinguish the owners)."""
    la, lb = a.asm.splitlines(), b.asm.splitlines()
    out = []
    for i in range(max(len(la), len(lb))):
        if i < len(la):
            out.append(la[i])
        if i < len(lb):
            out.append(lb[i])
    mem = dict(a.mem_init)
    mem.update(b.mem_init)
    eff = dict(a.effects)
    eff.update(b.effects)
    return Bench(name, "\n".join(out), mem, eff)
