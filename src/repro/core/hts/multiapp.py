"""Multi-application accelerator sharing (the paper's headline motivation).

Abstract: "developing an example heterogeneous system to enable multiple
applications to share the available accelerators."  The ISA's *process id*
field tags each task's owner; this module builds a second real application
(image compression — DCT-quantize-correlate per tile, Fig 2's image-processing
example), interleaves it with the audio-compression stream under one HTS, and
compares shared execution against running the two programs serially on the
same accelerator pool.

Programs are built with the Program Builder and merged at the *graph* level
via :meth:`builder.Program.interleave` — structured nodes (whole loops /
branches) stay atomic and register spaces cannot collide, unlike the old
asm-line round-robin splice, which silently tore labels and branch offsets
apart.

Complementary mixes (audio = FIR/FFT-heavy, image = DCT-heavy) are where
Function-level accelerators pay off: the shared makespan approaches
max(app_a, app_b) rather than their sum.
"""
from __future__ import annotations

from .builder import Program
from .programs import Bench, INPUT, INPUT_WORDS

IMG_BASE = 0x800        # image app's region space (disjoint from audio's)
TILE_WORDS = 0x20


def image_compression(tiles: int = 8) -> Bench:
    """Per 8×8 tile: DCT → vector_max (quantization range proxy) →
    correlation (inter-tile prediction) → vector_add (residual).
    Straight-line (unrolled), pid=1."""
    p = Program("image_compression", region_base=IMG_BASE)
    with p.process(1):
        prev = None
        for t in range(tiles):
            tile = p.region(TILE_WORDS, align=TILE_WORDS, name=f"tile{t}")
            dct = p.task("dct", in_=tile.sub(0x0, 8), out=tile.sub(0x8, 8),
                         tid=t)
            p.task("vector_max", in_=dct, out=tile.sub(0x10, 1), tid=t)
            if prev is not None:
                p.task("correlation", in_=dct, out=tile.sub(0x11, 1), tid=t)
            p.task("vector_add", in_=dct, out=tile.sub(0x18, 8), tid=t)
            prev = dct
    return Bench.of(p)


def audio_straightline(bands: int = 8) -> Bench:
    """Unrolled audio compression, frequency-domain path (pid=0)."""
    p = Program("audio_straightline")
    frame = p.input(INPUT, INPUT_WORDS, "audio")
    p.task("correlation", in_=frame, out=1, tid=0)
    for b in range(bands):
        band = p.region(TILE_WORDS, align=TILE_WORDS, name=f"band{b}")
        fft = p.task("fft_256", in_=band.sub(0x0, 4), out=band.sub(0x8, 4),
                     tid=1)
        for j in range(3):
            p.task("vector_dot", in_=fft, out=band.sub(0x10 + j, 1),
                   tid=2 + j)
        p.task("fft_256", in_=band.sub(0x10, 4), out=band.sub(0x18, 4),
               tid=5)
    return Bench.of(p)


def merge(benches, name: str = "shared", *,
          require_distinct_pids: bool = False) -> Bench:
    """N-way round-robin merge of applications' task streams (N CPUs pushing
    into the one Task Queue; pids distinguish the owners) — performed on the
    program graphs, not on assembly text."""
    benches = list(benches)
    if any(b.program is None for b in benches):
        raise ValueError("merge needs builder-backed Bench objects")
    return Bench.of(Program.merge(
        [b.program for b in benches], name,
        require_distinct_pids=require_distinct_pids))


def interleave(a: Bench, b: Bench, name: str = "shared") -> Bench:
    """Two-way :func:`merge` (kept for the original pairwise API)."""
    return merge([a, b], name)
