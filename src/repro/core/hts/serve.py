"""hts.serve — continuous batching over the population machine.

:func:`api.run_many` answers "simulate this population"; this module
answers "keep simulating whatever arrives".  A :class:`Server` accepts
single scenarios (``submit() -> Future[Result]``), routes each into an
open batch for its *shape bucket*, and launches buckets through the same
compiled population machine everything else uses — so a serving workload
gets batched-throughput economics on an open arrival stream instead of a
pre-packed population.

The pieces, and why each exists:

* **Bucket router** — the compiled machine is shaped by the program-table
  size and the frontend-stream width, so requests are keyed by
  ``(prog_bucket(p_len), prog_bucket(n_streams))`` (the same power-of-two
  ladder as :func:`batch.prog_bucket`).  One open batch per bucket.  The
  key is read straight off the :class:`~repro.core.hts.batch.Prepared`
  request (program length = code rows, stream count = stream-set size) —
  admission is the engine's hot path and never decodes the program.
* **Launch-on-full / launch-on-deadline** — a batch launches the moment
  it reaches ``max_batch`` (inline, inside ``submit``), or when its
  oldest request has waited ``deadline`` seconds (checked by ``poll()``,
  which ``submit`` also runs on entry).  The clock is injectable
  (:class:`ManualClock`) so deadline behaviour is deterministically
  testable.
* **Slice-and-refill compaction** (``slice_steps=``) — a static launch
  holds all its lanes until the *slowest* one halts, which is exactly
  where heterogeneous streams lose their batching win.  With
  ``slice_steps`` set, a launch instead runs the resumable machine
  (:func:`machine.make_machine` ``resumable=True``) in bounded slices:
  after each slice, lanes whose machines have halted are harvested
  (their futures resolve immediately) and the freed slots are
  **refilled** from the bucket's queue — the batch never idles a lane
  while requests wait.  The budget counts *machine steps* (while-loop
  trips), not cycles: under event-skip a step's cycle advance is data-
  dependent, and steps are where wall time actually goes, so only a step
  budget stops one event-dense request from stalling the whole width for
  an unbounded stretch.  ``slice_steps="auto"`` sizes each slice from
  the bucket's measured completed-request step counts, so a slice is a
  few typical requests long.  In this mode ``submit`` lets a bucket's
  queue deepen past ``max_batch`` (the queue *is* the refill reservoir)
  and launches on deadline, ``drain()``, or queue pressure.
* **Stable launch shapes** — partial batches are padded to the bucket's
  one lane width (``max_batch``, rounded up to a device multiple) by
  replicating the batch's first request, and ``pack_population(
  max_prog=bucket, max_streams=bucket)`` pins the other two shape axes,
  so *every* launch of a bucket presents the identical signature to the
  jitted runner: one XLA compile per bucket, ever (two for a sliced
  bucket: carry init + slice, both compiled once — the slice budget is
  traced, so adapting it never recompiles).  :meth:`Server.cache_info`
  proves it — ``jit_compiles`` reads the runners' own compilation-cache
  sizes (not a guess), so a warmed server asserts zero recompilation
  across arbitrarily many batches *and refills*.
* **Backpressure** — at most ``max_queue`` requests may be pending across
  all open batches; ``submit`` raises :class:`QueueFullError` beyond
  that, after first flushing any deadline-expired batches.  The one
  exception: a request that *completes* its bucket's batch is always
  admitted — it launches inline and frees ``max_batch`` slots, so
  refusing it would be an off-by-one that deadlocks an exactly-full
  queue.
* **Sharding** — ``ServeSpec(devices=N)`` routes every launch through the
  ``shard_map`` path (:mod:`shard` via ``run_many(devices=N)`` or the
  sharded resumable machine), so a multi-device host drains each batch
  across its devices; lane refill composes (the lane width is pinned to a
  device multiple once per server).
* **Service metrics** — every completed request records its queue wait
  and time-to-result; :meth:`Server.report` aggregates per bucket and per
  tenant (measured slice occupancy included), feeding
  ``benchmarks/serving.py``.

    >>> from repro.core import hts
    >>> with hts.serve(max_batch=4, deadline=0.01) as srv:
    ...     futs = [srv.submit(p) for p in programs]
    ...     srv.drain()
    ...     cycles = [f.result().cycles for f in futs]

The engine is deliberately single-threaded: launches happen inside
``submit``/``poll``/``drain`` on the caller's thread, and futures are
resolved before those calls return.  That keeps the semantics exactly
reproducible (no scheduler races) while preserving the asynchronous
*interface* — callers hold ``Future`` handles and may submit from
producer code that never looks at results.

Lifecycle: after :meth:`Server.close` (which flushes), ``submit``,
``poll`` and ``drain`` all raise ``RuntimeError`` — a closed server is
closed, not silently inert.  Leaving the ``with`` block normally closes
(flushes); leaving it on an exception calls :meth:`Server.abort`, which
*cancels* still-queued futures instead of launching work the caller will
never observe.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import Future
from typing import Optional, Sequence, Union

import numpy as np

from . import api, batch, machine
from .costs import SchedulerCosts
from .golden import HtsParams
from .policy import SchedPolicy


class QueueFullError(RuntimeError):
    """``submit`` refused: ``max_queue`` requests already pending (and the
    incoming request would not have completed a batch)."""


# ---------------------------------------------------------------------------
# clocks (injectable for deterministic deadline tests)
# ---------------------------------------------------------------------------
class SystemClock:
    """Wall time (``time.monotonic``) — the production clock."""

    def now(self) -> float:
        return time.monotonic()


class ManualClock:
    """A clock that only moves when told to — deadline tests advance it
    explicitly, so launch-on-deadline is exact instead of sleep-flaky."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def now(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += float(dt)
        return self.t


# ---------------------------------------------------------------------------
# spec + reports
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """Everything a :class:`Server` is configured by.

    ``n_fu``/``scheduler``/``params``/``policy``/``event_skip``/
    ``max_cycles`` mean what they mean on :func:`api.run_many` and are
    shared by every request (they are compilation-relevant, so per-request
    variation would defeat the bucket cache; per-request *policies* still
    work — attach them to the program, e.g. ``Program.merge(priorities=
    ...)``, and leave ``policy=None``).

    ``max_batch`` — lanes per launch (every launch is padded to exactly
    this — rounded up to a device multiple — so it is also the bucket's
    compiled batch shape).
    ``max_queue`` — pending-request bound across all open batches.
    ``deadline`` — seconds an open batch may age before ``poll()``
    launches it partial.  ``devices`` — shard each launch over N devices
    (``None`` = single-device path).

    ``slice_steps`` — ``None`` (default) launches static batches that
    run to completion; an int runs every launch in slices of at most that
    many machine steps per lane, with halted lanes harvested and refilled
    from the bucket queue between slices (continuous batching); ``"auto"``
    sizes slices from the bucket's measured completed-request step counts
    (4x the median of the last 64, floor {AUTO_MIN}; first launch at
    {AUTO} steps).

    ``step_impl`` — the step-body lowering
    (:data:`~repro.core.hts.machine.STEP_IMPLS`) every launch runs
    under; compilation-relevant like the rest, so it is part of the
    bucket cache key via the machine spec.
    """
    scheduler: Union[str, SchedulerCosts] = "hts_spec"
    n_fu: Union[int, Sequence[int]] = 2
    params: HtsParams = HtsParams()
    policy: Optional[SchedPolicy] = None
    event_skip: bool = True
    max_cycles: int = 5_000_000
    max_batch: int = 8
    max_queue: int = 64
    deadline: float = 0.050
    devices: Optional[int] = None
    max_fu_per_class: Optional[int] = None
    slice_steps: Optional[Union[int, str]] = None
    step_impl: str = "xla"


#: first-launch slice budget (machine steps) under ``slice_steps="auto"``
#: (no measured completions yet to take a median of)
AUTO_SLICE_STEPS = 256
#: smallest auto slice — below this, per-slice dispatch overhead dominates
AUTO_SLICE_STEPS_MIN = 32
ServeSpec.__doc__ = ServeSpec.__doc__.format(AUTO=AUTO_SLICE_STEPS,
                                             AUTO_MIN=AUTO_SLICE_STEPS_MIN)


@dataclasses.dataclass(frozen=True)
class CacheInfo:
    """Compilation accounting.  ``hits``/``misses`` count bucket-runner
    lookups at launch time (miss = first launch of a bucket); ``entries``
    is the number of distinct buckets launched; ``jit_compiles`` is the
    *runners' own* compilation-cache population — the honest number, read
    from the jitted callables (a sliced bucket's runner is two callables:
    carry init + slice), not inferred.  A warmed server launches batch
    after batch — and refill after refill — with ``jit_compiles``
    frozen."""
    hits: int
    misses: int
    entries: int
    jit_compiles: int


@dataclasses.dataclass(frozen=True)
class BucketStats:
    batches: int
    requests: int
    pad_lanes: int
    occupancy: float            # mean real-lane fraction (measured per
    #                             slice for compacted launches)
    mean_wait: float            # seconds queued before a lane ran it
    mean_ttr: float             # seconds submit -> result


@dataclasses.dataclass(frozen=True)
class TenantStats:
    requests: int
    mean_wait: float
    mean_ttr: float


@dataclasses.dataclass(frozen=True)
class ServeReport:
    """Aggregated service metrics for everything the server completed."""
    requests: int
    batches: int
    per_bucket: dict
    per_tenant: dict

    def table(self) -> str:
        lines = [f"served {self.requests} requests in {self.batches} "
                 f"batches",
                 f"{'bucket':<14} {'batches':>7} {'reqs':>6} {'occ':>6} "
                 f"{'wait(ms)':>9} {'ttr(ms)':>9}"]
        for key, b in sorted(self.per_bucket.items()):
            lines.append(f"{str(key):<14} {b.batches:>7} {b.requests:>6} "
                         f"{b.occupancy:>6.2f} {b.mean_wait * 1e3:>9.3f} "
                         f"{b.mean_ttr * 1e3:>9.3f}")
        if self.per_tenant:
            lines.append(f"{'tenant':<14} {'':>7} {'reqs':>6} {'':>6} "
                         f"{'wait(ms)':>9} {'ttr(ms)':>9}")
            for name, t in sorted(self.per_tenant.items()):
                lines.append(f"{name:<14} {'':>7} {t.requests:>6} {'':>6} "
                             f"{t.mean_wait * 1e3:>9.3f} "
                             f"{t.mean_ttr * 1e3:>9.3f}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------
_TREE_OPS = None


def _tree_ops():
    """Two shared jitted helpers for sliced launches: gather W rows out of
    a device-resident tree (``take``), and scatter W replacement rows into
    one (``put``).  Index vectors are padded to the fixed lane width with
    duplicates that carry identical rows (so scatter order cannot matter),
    which keeps each helper at one compilation per tree shape.  Per-lane
    eager indexing would instead pay dispatch overhead per *field* per
    lane — on a CPU host that overhead dwarfs the slice compute itself."""
    global _TREE_OPS
    if _TREE_OPS is None:
        import jax

        take = jax.jit(lambda tree, idx: jax.tree_util.tree_map(
            lambda v: v[idx], tree))
        put = jax.jit(lambda tree, idx, rows: jax.tree_util.tree_map(
            lambda v, r: v.at[idx].set(r), tree, rows))
        _TREE_OPS = (take, put)
    return _TREE_OPS


@dataclasses.dataclass
class _Request:
    prep: batch.Prepared
    tenant: str
    t_submit: float
    future: Future


@dataclasses.dataclass
class _OpenBatch:
    t_open: float
    requests: list


class Server:
    """The continuous-batching engine.  Build via :func:`serve`."""

    def __init__(self, spec: ServeSpec = ServeSpec(), *, clock=None):
        self.spec = spec
        self._clock = clock if clock is not None else SystemClock()
        self._cost = api._norm_costs(spec.scheduler)
        widest = max(batch.norm_n_fu(spec.n_fu))
        self._max_fu = (spec.max_fu_per_class
                        if spec.max_fu_per_class is not None
                        else max(4, widest))
        if widest > self._max_fu:
            raise ValueError(f"n_fu {widest} exceeds max_fu_per_class "
                             f"{self._max_fu}")
        if spec.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if spec.max_queue < spec.max_batch:
            raise ValueError("max_queue must be >= max_batch")
        sc = spec.slice_steps
        if sc is not None and sc != "auto" and (
                not isinstance(sc, (int, np.integer)) or sc < 1):
            raise ValueError('slice_steps must be None, "auto", or a '
                             f'positive int, got {sc!r}')
        self._compaction = sc is not None
        # lane width: max_batch rounded up to a device multiple, so the
        # sharded paths see one fixed, divisible shape per bucket
        mult = spec.devices or 1
        self._lanes = -(-spec.max_batch // mult) * mult
        self._open: dict[tuple[int, int], _OpenBatch] = {}
        self._runners: dict[tuple[int, int], object] = {}
        self._hits = 0
        self._misses = 0
        self._pending = 0
        self._closed = False
        self._req_rows: list = []      # (bucket, tenant, wait, ttr)
        self._batch_rows: list = []    # (bucket, n_real, pad, occupancy)
        self._done_steps: dict[tuple[int, int], list] = {}

    # -- admission ----------------------------------------------------------
    def bucket_of(self, program) -> tuple[int, int]:
        """The shape-bucket key a program routes to:
        ``(prog_bucket(p_len), prog_bucket(n_streams, floor=1))``."""
        return self._bucket_key(batch.prepare(program))

    @staticmethod
    def _bucket_key(prep: batch.Prepared) -> tuple[int, int]:
        # the hot admission path: length is the code-row count and the
        # stream count is the stream-set size — no program decode here
        n_streams = len(prep.streams) if prep.streams is not None else 1
        return (batch.prog_bucket(len(prep.code)),
                batch.prog_bucket(n_streams, floor=1))

    def _require_open(self) -> None:
        if self._closed:
            raise RuntimeError("server is closed")

    def submit(self, program, *, tenant: str = "-") -> Future:
        """Enqueue one scenario; the Future resolves to its
        :class:`~repro.core.hts.api.Result` when a launch runs it
        (inline on fill or queue pressure, or on a later
        ``poll``/``drain``).

        Raises :class:`QueueFullError` when ``max_queue`` requests are
        already pending (after flushing any deadline-expired batches) —
        unless this request completes its bucket's batch, in which case
        it is admitted and the batch launches inline, freeing its slots.
        Open-loop producers must shed or retry on refusal.
        """
        self._require_open()
        self.poll()                     # free space deadlines already owe
        prep = batch.prepare(program)
        key = self._bucket_key(prep)
        ob = self._open.get(key)
        waiting = len(ob.requests) if ob is not None else 0
        full = self._pending >= self.spec.max_queue
        if full and waiting + 1 < self.spec.max_batch:
            raise QueueFullError(
                f"{self._pending} requests pending >= max_queue "
                f"{self.spec.max_queue}")
        req = _Request(prep=prep, tenant=tenant,
                       t_submit=self._clock.now(), future=Future())
        if ob is None:
            ob = self._open[key] = _OpenBatch(t_open=req.t_submit,
                                              requests=[])
        ob.requests.append(req)
        self._pending += 1
        # static mode launches the moment a batch fills; compaction mode
        # lets the bucket queue deepen (it is the refill reservoir) and
        # launches on deadline/drain — or right here under queue pressure
        if len(ob.requests) >= self.spec.max_batch and (
                full or not self._compaction):
            self._launch(key)
        return req.future

    def poll(self) -> int:
        """Launch every open batch whose oldest request has aged past
        ``deadline``.  Returns the number of batches launched."""
        self._require_open()
        now = self._clock.now()
        due = [k for k, ob in self._open.items()
               if now - ob.t_open >= self.spec.deadline]
        for k in due:
            self._launch(k)
        return len(due)

    def drain(self) -> int:
        """Launch every open batch regardless of age (flush)."""
        self._require_open()
        keys = list(self._open)
        for k in keys:
            self._launch(k)
        return len(keys)

    def close(self) -> None:
        """Flush, then refuse further ``submit``/``poll``/``drain``.
        Idempotent."""
        if self._closed:
            return
        self.drain()
        self._closed = True

    def abort(self) -> None:
        """Discard queued work without launching: cancel every pending
        future, empty the queue, close the server.  This is the
        exception-path exit (``with`` blocks call it when unwinding) —
        flushing there would burn simulation time on results nobody will
        ever read."""
        if self._closed:
            return
        for ob in self._open.values():
            for r in ob.requests:
                r.future.cancel()
        self._open.clear()
        self._pending = 0
        self._closed = True

    def __enter__(self) -> "Server":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is not None:
            self.abort()
        else:
            self.close()

    @property
    def pending(self) -> int:
        """Requests admitted whose futures have not yet resolved."""
        return self._pending

    # -- execution ----------------------------------------------------------
    def _machine_spec(self) -> machine.MachineSpec:
        # mirror run_many exactly (policy/cost-stripped params) so the runner
        # fetched here for accounting IS the runner run_many executes
        return machine.MachineSpec(
            params=dataclasses.replace(self.spec.params,
                                       policy=SchedPolicy(), fu_cost=None),
            costs=self._cost, event_skip=self.spec.event_skip,
            max_cycles=self.spec.max_cycles,
            max_fu_per_class=self._max_fu,
            step_impl=self.spec.step_impl)

    def _runner(self, key: tuple[int, int]):
        r = self._runners.get(key)
        if r is not None:
            self._hits += 1
            return r
        spec = self._machine_spec()
        r = (api._slicer_for(spec, key[0], self.spec.devices)
             if self._compaction
             else api._runner_for(spec, key[0], self.spec.devices))
        self._runners[key] = r
        self._misses += 1
        return r

    def _pack(self, preps, key: tuple[int, int]) -> batch.PackedPopulation:
        return batch.pack_population(
            preps, params=self.spec.params, n_fu=self.spec.n_fu,
            policy=self.spec.policy, max_prog=key[0], max_streams=key[1])

    def _resolve(self, key, req: _Request, result, t_filled: float,
                 t_done: float) -> None:
        """Resolve one request's future (and its accounting) — the ONLY
        place ``_pending`` decrements, so a request admitted is a request
        either resolved or still counted."""
        self._pending -= 1
        self._req_rows.append((key, req.tenant, t_filled - req.t_submit,
                               t_done - req.t_submit))
        if result is not None:
            self._done_steps.setdefault(key, []).append(
                int(np.asarray(result.raw["steps"])))
            req.future.set_result(result)
        else:
            req.future.set_exception(api.SimulationError(
                f"request {req.prep.name!r} (tenant {req.tenant!r}) did "
                f"not halt within {self.spec.max_cycles} cycles"))

    def _launch(self, key: tuple[int, int]) -> None:
        ob = self._open.pop(key, None)
        if ob is None or not ob.requests:
            return
        try:
            if self._compaction:
                self._launch_sliced(key, ob.requests)
            else:
                self._launch_static(key, ob.requests)
        except BaseException as e:
            # exception-safe: a failed launch fails its *own* futures and
            # restores the queue accounting, instead of leaking hung
            # futures and permanently shrinking capacity
            for r in ob.requests:
                if not r.future.done():
                    self._pending -= 1
                    r.future.set_exception(e)
            raise

    def _launch_static(self, key: tuple[int, int], reqs: list) -> None:
        self._runner(key)           # cache accounting (run_many reuses it)
        # pad to the bucket's one-and-only launch shape (replicating the
        # first request — pad results are discarded)
        pad = self._lanes - len(reqs)
        preps = [r.prep for r in reqs] + [reqs[0].prep] * pad
        pop = self._pack(preps, key)
        t_launch = self._clock.now()
        res = api.run_many(pop, scheduler=self._cost,
                           event_skip=self.spec.event_skip,
                           max_cycles=self.spec.max_cycles,
                           max_fu_per_class=self._max_fu,
                           devices=self.spec.devices, check=False,
                           step_impl=self.spec.step_impl)
        t_done = self._clock.now()
        self._batch_rows.append((key, len(reqs), pad,
                                 len(reqs) / self._lanes))
        for i, r in enumerate(reqs):
            self._resolve(key, r, res[i] if bool(res.halted[i]) else None,
                          t_launch, t_done)

    # -- slice-and-refill (compaction) --------------------------------------
    def _slice_budget(self, key: tuple[int, int]) -> int:
        sc = self.spec.slice_steps
        if sc == "auto":
            hist = self._done_steps.get(key)
            if not hist:
                return AUTO_SLICE_STEPS
            # a few typical requests per slice: fine enough that an
            # event-dense straggler cannot stall the width for long,
            # coarse enough that dispatch overhead stays amortised
            return max(AUTO_SLICE_STEPS_MIN,
                       4 * int(np.median(hist[-64:])))
        return int(sc)

    def _lane_result(self, req: _Request, out: dict, n_fu_row,
                     wall_us: float) -> api.Result:
        fu = tuple(int(x) for x in n_fu_row)
        pol = batch.norm_policy(self.spec.policy, req.prep,
                                self.spec.params)
        return api._machine_result(req.prep.name, self._cost.name, fu, out,
                                   wall_us, pol, self._max_fu,
                                   req.prep.streams)

    def _refill_rows(self, key, fresh, req: _Request):
        """Host-side rows that splice a fresh lane for ``req`` into a
        running launch: the packed row for all 11 machine arguments, and a
        carry row that is the fresh-state template with the two program-
        dependent fields (``pc``, ``mem``) overwritten — the exact state
        ``init`` would have built for it."""
        row = self._pack([req.prep], key)
        arow = [b[0] for b in row.machine_args()]
        crow = dict(fresh)
        crow["pc"] = row.streams[0][:, 0]
        crow["mem"] = row.mem[0]
        return crow, arow

    def _launch_sliced(self, key: tuple[int, int], reqs: list) -> None:
        """Run one bucket's queue through ``self._lanes`` lanes with
        bounded step slices, harvesting halted lanes and refilling their
        slots between slices, until the queue is dry and every lane has
        drained.  Each request's future resolves the moment its own lane
        halts — not when the batch does.

        The carry and the 11 machine arguments stay **device-resident**
        across slices: per slice only the three per-lane liveness fields
        come back to the host (to decide harvests), then *all* dead lanes
        are gathered in one jitted tree-take and *all* refills spliced in
        one jitted tree-put (:func:`_tree_ops`).  The state itself never
        round-trips, so the per-slice host cost is independent of
        ``HtsParams`` capacities and of how many lanes turned over."""
        import jax
        import jax.numpy as jnp

        take_rows, put_rows = _tree_ops()
        rm = self._runner(key)
        queue = list(reqs)                       # FIFO submit order
        W = self._lanes
        take, queue = queue[:W], queue[W:]
        pad = W - len(take)
        pop = self._pack([r.prep for r in take] + [take[0].prep] * pad, key)
        args = [jnp.asarray(a) for a in pop.machine_args()]
        n_fu_host = np.array(pop.machine_args()[2])   # host mirror for reads
        carry = dict(rm.init(*args))
        # one fresh state row as the refill template: machine.init only
        # varies pc and mem with the program (documented invariant), so a
        # fresh row for ANY program is this template + those two fields
        fresh = jax.device_get({k: v[0] for k, v in carry.items()})
        lanes: list = list(take) + [None] * pad
        # retire pad lanes before the first slice: marking the clones
        # halted makes them step fixed points and immediately refillable
        if pad:
            carry["halted"] = carry["halted"].at[len(take):].set(True)
        t_fill = [self._clock.now()] * W
        served = 0
        occ_num = 0.0
        occ_den = 0
        while any(r is not None for r in lanes):
            occ_num += sum(r is not None for r in lanes) / W
            occ_den += 1
            budget = np.int32(self._slice_budget(key))
            carry = dict(rm.run_slice(carry, *args, budget))
            now = self._clock.now()
            halted, overflow, cycle = jax.device_get(
                (carry["halted"], carry["overflow"], carry["cycle"]))
            dead = halted | overflow | (cycle >= self.spec.max_cycles)
            done = [i for i in range(W)
                    if lanes[i] is not None and dead[i]]
            if not done:
                continue
            # one gather for every lane that died this slice (index vector
            # padded to W so the helper keeps a single compiled shape)
            idx = np.asarray(done + [done[0]] * (W - len(done)), np.int32)
            rows = jax.device_get(take_rows(carry, idx))
            ref_idx: list[int] = []
            ref_crows: list[dict] = []
            ref_arows: list[list] = []
            for j, i in enumerate(done):
                r = lanes[i]
                row = rm.collect({k: v[j] for k, v in rows.items()})
                res = self._lane_result(r, row, n_fu_host[i],
                                        (now - t_fill[i]) * 1e6)
                self._resolve(key, r, res if res.halted else None,
                              t_fill[i], now)
                served += 1
                lanes[i] = None
                if queue:
                    nxt = queue.pop(0)
                    crow, arow = self._refill_rows(key, fresh, nxt)
                    ref_idx.append(i)
                    ref_crows.append(crow)
                    ref_arows.append(arow)
                    n_fu_host[i] = np.array(arow[2])
                    lanes[i] = nxt
                    t_fill[i] = now
            if ref_idx:
                # one splice for every refill this slice (padded with
                # duplicates of refill 0 — identical rows, so the scatter
                # is order-independent)
                k = W - len(ref_idx)
                ridx = np.asarray(ref_idx + [ref_idx[0]] * k, np.int32)
                ref_crows += [ref_crows[0]] * k
                ref_arows += [ref_arows[0]] * k
                crows = {f: np.stack([c[f] for c in ref_crows])
                         for f in ref_crows[0]}
                arows = [np.stack([a[j] for a in ref_arows])
                         for j in range(len(args))]
                carry, args = put_rows((carry, args), ridx, (crows, arows))
                carry, args = dict(carry), list(args)
        self._batch_rows.append((key, served, max(0, W - served),
                                 occ_num / max(occ_den, 1)))

    # -- introspection ------------------------------------------------------
    def cache_info(self) -> CacheInfo:
        parts = []
        for r in self._runners.values():
            if isinstance(r, machine.ResumableMachine):
                parts += [r.init, r.run_slice]
            else:
                parts.append(r)
        distinct = {id(p): p for p in parts}
        compiles = 0
        for p in distinct.values():
            size = getattr(p, "_cache_size", None)
            compiles += int(size()) if callable(size) else 0
        return CacheInfo(hits=self._hits, misses=self._misses,
                         entries=len(self._runners), jit_compiles=compiles)

    def report(self) -> ServeReport:
        per_bucket: dict = {}
        for key in {row[0] for row in self._batch_rows}:
            rows = [r for r in self._req_rows if r[0] == key]
            launches = [row for row in self._batch_rows if row[0] == key]
            per_bucket[key] = BucketStats(
                batches=len(launches),
                requests=len(rows),
                pad_lanes=sum(row[2] for row in launches),
                occupancy=float(np.mean([row[3] for row in launches])),
                mean_wait=float(np.mean([r[2] for r in rows])),
                mean_ttr=float(np.mean([r[3] for r in rows])))
        per_tenant: dict = {}
        for tenant in {r[1] for r in self._req_rows}:
            rows = [r for r in self._req_rows if r[1] == tenant]
            per_tenant[tenant] = TenantStats(
                requests=len(rows),
                mean_wait=float(np.mean([r[2] for r in rows])),
                mean_ttr=float(np.mean([r[3] for r in rows])))
        return ServeReport(requests=len(self._req_rows),
                           batches=len(self._batch_rows),
                           per_bucket=per_bucket, per_tenant=per_tenant)


def serve(spec: Optional[ServeSpec] = None, *, clock=None,
          **overrides) -> Server:
    """Build a :class:`Server` — ``hts.serve()`` is the front door.

    Pass a :class:`ServeSpec`, keyword overrides for its fields, or both
    (overrides win).  ``clock`` injects a time source
    (:class:`ManualClock` in tests; wall time otherwise).  Usable as a
    context manager: ``with hts.serve(...) as srv: ...`` flushes and
    closes on normal exit, aborts (cancels queued futures) on an
    exception.
    """
    if spec is None:
        spec = ServeSpec()
    if overrides:
        spec = dataclasses.replace(spec, **overrides)
    return Server(spec, clock=clock)


__all__ = ["serve", "Server", "ServeSpec", "ServeReport", "BucketStats",
           "TenantStats", "CacheInfo", "QueueFullError", "SystemClock",
           "ManualClock", "AUTO_SLICE_STEPS"]
