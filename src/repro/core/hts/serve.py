"""hts.serve — continuous batching over the population machine.

:func:`api.run_many` answers "simulate this population"; this module
answers "keep simulating whatever arrives".  A :class:`Server` accepts
single scenarios (``submit() -> Future[Result]``), routes each into an
open batch for its *shape bucket*, and launches buckets through the same
compiled population machine everything else uses — so a serving workload
gets batched-throughput economics on an open arrival stream instead of a
pre-packed population.

The pieces, and why each exists:

* **Bucket router** — the compiled machine is shaped by the program-table
  size and the frontend-stream width, so requests are keyed by
  ``(prog_bucket(p_len), prog_bucket(n_streams))`` (the same power-of-two
  ladder as :func:`batch.prog_bucket`).  One open batch per bucket.
* **Launch-on-full / launch-on-deadline** — a batch launches the moment
  it reaches ``max_batch`` (inline, inside ``submit``), or when its
  oldest request has waited ``deadline`` seconds (checked by ``poll()``,
  which ``submit`` also runs on entry).  The clock is injectable
  (:class:`ManualClock`) so deadline behaviour is deterministically
  testable.
* **Stable launch shapes** — partial batches are padded to ``max_batch``
  lanes by replicating the batch's first request, and
  ``pack_population(max_prog=bucket, max_streams=bucket)`` pins the other
  two shape axes, so *every* launch of a bucket presents the identical
  signature to the jitted runner: one XLA compile per bucket, ever.
  :meth:`Server.cache_info` proves it — ``jit_compiles`` reads the
  runners' own compilation-cache sizes (not a guess), so a warmed server
  asserts zero recompilation across arbitrarily many batches.
* **Backpressure** — at most ``max_queue`` requests may be pending across
  all open batches; ``submit`` raises :class:`QueueFullError` beyond
  that, after first flushing any deadline-expired batches.
* **Sharding** — ``ServeSpec(devices=N)`` routes every launch through the
  ``shard_map`` path (:mod:`shard` via ``run_many(devices=N)``), so a
  multi-device host drains each batch across its devices.
* **Service metrics** — every completed request records its queue wait
  and time-to-result; :meth:`Server.report` aggregates per bucket and per
  tenant (batch occupancy included), feeding ``benchmarks/serving.py``.

    >>> from repro.core import hts
    >>> with hts.serve(max_batch=4, deadline=0.01) as srv:
    ...     futs = [srv.submit(p) for p in programs]
    ...     srv.drain()
    ...     cycles = [f.result().cycles for f in futs]

The engine is deliberately single-threaded: launches happen inside
``submit``/``poll``/``drain`` on the caller's thread, and futures are
resolved before those calls return.  That keeps the semantics exactly
reproducible (no scheduler races) while preserving the asynchronous
*interface* — callers hold ``Future`` handles and may submit from
producer code that never looks at results.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import Future
from typing import Optional, Sequence, Union

import numpy as np

from . import api, batch, isa, machine
from .costs import SchedulerCosts
from .golden import HtsParams
from .policy import SchedPolicy


class QueueFullError(RuntimeError):
    """``submit`` refused: ``max_queue`` requests already pending."""


# ---------------------------------------------------------------------------
# clocks (injectable for deterministic deadline tests)
# ---------------------------------------------------------------------------
class SystemClock:
    """Wall time (``time.monotonic``) — the production clock."""

    def now(self) -> float:
        return time.monotonic()


class ManualClock:
    """A clock that only moves when told to — deadline tests advance it
    explicitly, so launch-on-deadline is exact instead of sleep-flaky."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def now(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += float(dt)
        return self.t


# ---------------------------------------------------------------------------
# spec + reports
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """Everything a :class:`Server` is configured by.

    ``n_fu``/``scheduler``/``params``/``policy``/``event_skip``/
    ``max_cycles`` mean what they mean on :func:`api.run_many` and are
    shared by every request (they are compilation-relevant, so per-request
    variation would defeat the bucket cache; per-request *policies* still
    work — attach them to the program, e.g. ``Program.merge(priorities=
    ...)``, and leave ``policy=None``).

    ``max_batch`` — lanes per launch (every launch is padded to exactly
    this, so it is also the bucket's compiled batch shape).
    ``max_queue`` — pending-request bound across all open batches.
    ``deadline`` — seconds an open batch may age before ``poll()``
    launches it partial.  ``devices`` — shard each launch over N devices
    (``None`` = single-device path).
    """
    scheduler: Union[str, SchedulerCosts] = "hts_spec"
    n_fu: Union[int, Sequence[int]] = 2
    params: HtsParams = HtsParams()
    policy: Optional[SchedPolicy] = None
    event_skip: bool = True
    max_cycles: int = 5_000_000
    max_batch: int = 8
    max_queue: int = 64
    deadline: float = 0.050
    devices: Optional[int] = None
    max_fu_per_class: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class CacheInfo:
    """Compilation accounting.  ``hits``/``misses`` count bucket-runner
    lookups at launch time (miss = first launch of a bucket); ``entries``
    is the number of distinct buckets launched; ``jit_compiles`` is the
    *runners' own* compilation-cache population — the honest number, read
    from the jitted callables, not inferred.  A warmed server launches
    batch after batch with ``jit_compiles`` frozen."""
    hits: int
    misses: int
    entries: int
    jit_compiles: int


@dataclasses.dataclass(frozen=True)
class BucketStats:
    batches: int
    requests: int
    pad_lanes: int
    occupancy: float            # mean real-lanes / max_batch per launch
    mean_wait: float            # seconds queued before launch
    mean_ttr: float             # seconds submit -> result


@dataclasses.dataclass(frozen=True)
class TenantStats:
    requests: int
    mean_wait: float
    mean_ttr: float


@dataclasses.dataclass(frozen=True)
class ServeReport:
    """Aggregated service metrics for everything the server completed."""
    requests: int
    batches: int
    per_bucket: dict
    per_tenant: dict

    def table(self) -> str:
        lines = [f"served {self.requests} requests in {self.batches} "
                 f"batches",
                 f"{'bucket':<14} {'batches':>7} {'reqs':>6} {'occ':>6} "
                 f"{'wait(ms)':>9} {'ttr(ms)':>9}"]
        for key, b in sorted(self.per_bucket.items()):
            lines.append(f"{str(key):<14} {b.batches:>7} {b.requests:>6} "
                         f"{b.occupancy:>6.2f} {b.mean_wait * 1e3:>9.3f} "
                         f"{b.mean_ttr * 1e3:>9.3f}")
        if self.per_tenant:
            lines.append(f"{'tenant':<14} {'':>7} {'reqs':>6} {'':>6} "
                         f"{'wait(ms)':>9} {'ttr(ms)':>9}")
            for name, t in sorted(self.per_tenant.items()):
                lines.append(f"{name:<14} {'':>7} {t.requests:>6} {'':>6} "
                             f"{t.mean_wait * 1e3:>9.3f} "
                             f"{t.mean_ttr * 1e3:>9.3f}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _Request:
    prep: batch.Prepared
    tenant: str
    t_submit: float
    future: Future


@dataclasses.dataclass
class _OpenBatch:
    t_open: float
    requests: list


class Server:
    """The continuous-batching engine.  Build via :func:`serve`."""

    def __init__(self, spec: ServeSpec = ServeSpec(), *, clock=None):
        self.spec = spec
        self._clock = clock if clock is not None else SystemClock()
        self._cost = api._norm_costs(spec.scheduler)
        widest = max(batch.norm_n_fu(spec.n_fu))
        self._max_fu = (spec.max_fu_per_class
                        if spec.max_fu_per_class is not None
                        else max(4, widest))
        if widest > self._max_fu:
            raise ValueError(f"n_fu {widest} exceeds max_fu_per_class "
                             f"{self._max_fu}")
        if spec.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if spec.max_queue < spec.max_batch:
            raise ValueError("max_queue must be >= max_batch")
        self._open: dict[tuple[int, int], _OpenBatch] = {}
        self._runners: dict[tuple[int, int], object] = {}
        self._hits = 0
        self._misses = 0
        self._pending = 0
        self._closed = False
        self._req_rows: list = []      # (bucket, tenant, wait, ttr)
        self._batch_rows: list = []    # (bucket, n_real)

    # -- admission ----------------------------------------------------------
    def bucket_of(self, program) -> tuple[int, int]:
        """The shape-bucket key a program routes to:
        ``(prog_bucket(p_len), prog_bucket(n_streams, floor=1))``."""
        prep = batch.prepare(program)
        p_len = len(isa.decode_table(prep.code))
        n_streams = len(prep.streams) if prep.streams is not None else 1
        return (batch.prog_bucket(p_len),
                batch.prog_bucket(n_streams, floor=1))

    def submit(self, program, *, tenant: str = "-") -> Future:
        """Enqueue one scenario; the Future resolves to its
        :class:`~repro.core.hts.api.Result` when its batch launches
        (inline on fill, or on a later ``poll``/``drain``).

        Raises :class:`QueueFullError` when ``max_queue`` requests are
        already pending (after flushing any deadline-expired batches) —
        open-loop producers must shed or retry.
        """
        if self._closed:
            raise RuntimeError("server is closed")
        self.poll()                     # free space deadlines already owe
        if self._pending >= self.spec.max_queue:
            raise QueueFullError(
                f"{self._pending} requests pending >= max_queue "
                f"{self.spec.max_queue}")
        prep = batch.prepare(program)
        key = self.bucket_of(prep)
        req = _Request(prep=prep, tenant=tenant,
                       t_submit=self._clock.now(), future=Future())
        ob = self._open.get(key)
        if ob is None:
            ob = self._open[key] = _OpenBatch(t_open=req.t_submit,
                                              requests=[])
        ob.requests.append(req)
        self._pending += 1
        if len(ob.requests) >= self.spec.max_batch:
            self._launch(key)
        return req.future

    def poll(self) -> int:
        """Launch every open batch whose oldest request has aged past
        ``deadline``.  Returns the number of batches launched."""
        now = self._clock.now()
        due = [k for k, ob in self._open.items()
               if now - ob.t_open >= self.spec.deadline]
        for k in due:
            self._launch(k)
        return len(due)

    def drain(self) -> int:
        """Launch every open batch regardless of age (flush)."""
        keys = list(self._open)
        for k in keys:
            self._launch(k)
        return len(keys)

    def close(self) -> None:
        """Flush and refuse further submissions."""
        self.drain()
        self._closed = True

    def __enter__(self) -> "Server":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def pending(self) -> int:
        """Requests enqueued but not yet launched."""
        return self._pending

    # -- execution ----------------------------------------------------------
    def _machine_spec(self) -> machine.MachineSpec:
        # mirror run_many exactly (policy-stripped params) so the runner
        # fetched here for accounting IS the runner run_many executes
        return machine.MachineSpec(
            params=dataclasses.replace(self.spec.params,
                                       policy=SchedPolicy()),
            costs=self._cost, event_skip=self.spec.event_skip,
            max_cycles=self.spec.max_cycles,
            max_fu_per_class=self._max_fu)

    def _launch(self, key: tuple[int, int]) -> None:
        ob = self._open.pop(key)
        reqs = ob.requests
        if not reqs:
            return
        if key in self._runners:
            self._hits += 1
        else:
            self._runners[key] = api._runner_for(
                self._machine_spec(), key[0], self.spec.devices)
            self._misses += 1
        # pad to the bucket's one-and-only launch shape: max_batch lanes
        # (replicating the first request — pad results are discarded)
        pad = self.spec.max_batch - len(reqs)
        preps = [r.prep for r in reqs] + [reqs[0].prep] * pad
        pop = batch.pack_population(
            preps, params=self.spec.params, n_fu=self.spec.n_fu,
            policy=self.spec.policy, max_prog=key[0], max_streams=key[1])
        t_launch = self._clock.now()
        res = api.run_many(pop, scheduler=self._cost,
                           event_skip=self.spec.event_skip,
                           max_cycles=self.spec.max_cycles,
                           max_fu_per_class=self._max_fu,
                           devices=self.spec.devices, check=False)
        t_done = self._clock.now()
        self._pending -= len(reqs)
        self._batch_rows.append((key, len(reqs)))
        for i, r in enumerate(reqs):
            self._req_rows.append((key, r.tenant, t_launch - r.t_submit,
                                   t_done - r.t_submit))
            if bool(res.halted[i]):
                r.future.set_result(res[i])
            else:
                r.future.set_exception(api.SimulationError(
                    f"request {r.prep.name!r} (tenant {r.tenant!r}) did "
                    f"not halt within {self.spec.max_cycles} cycles"))

    # -- introspection ------------------------------------------------------
    def cache_info(self) -> CacheInfo:
        distinct = {id(r): r for r in self._runners.values()}
        compiles = 0
        for r in distinct.values():
            size = getattr(r, "_cache_size", None)
            compiles += int(size()) if callable(size) else 0
        return CacheInfo(hits=self._hits, misses=self._misses,
                         entries=len(self._runners), jit_compiles=compiles)

    def report(self) -> ServeReport:
        per_bucket: dict = {}
        for key in {k for k, _ in self._batch_rows}:
            rows = [r for r in self._req_rows if r[0] == key]
            launches = [n for k, n in self._batch_rows if k == key]
            per_bucket[key] = BucketStats(
                batches=len(launches), requests=len(rows),
                pad_lanes=sum(self.spec.max_batch - n for n in launches),
                occupancy=float(np.mean(launches)) / self.spec.max_batch,
                mean_wait=float(np.mean([r[2] for r in rows])),
                mean_ttr=float(np.mean([r[3] for r in rows])))
        per_tenant: dict = {}
        for tenant in {r[1] for r in self._req_rows}:
            rows = [r for r in self._req_rows if r[1] == tenant]
            per_tenant[tenant] = TenantStats(
                requests=len(rows),
                mean_wait=float(np.mean([r[2] for r in rows])),
                mean_ttr=float(np.mean([r[3] for r in rows])))
        return ServeReport(requests=len(self._req_rows),
                           batches=len(self._batch_rows),
                           per_bucket=per_bucket, per_tenant=per_tenant)


def serve(spec: Optional[ServeSpec] = None, *, clock=None,
          **overrides) -> Server:
    """Build a :class:`Server` — ``hts.serve()`` is the front door.

    Pass a :class:`ServeSpec`, keyword overrides for its fields, or both
    (overrides win).  ``clock`` injects a time source
    (:class:`ManualClock` in tests; wall time otherwise).  Usable as a
    context manager: ``with hts.serve(...) as srv: ...`` flushes and
    closes on exit.
    """
    if spec is None:
        spec = ServeSpec()
    if overrides:
        spec = dataclasses.replace(spec, **overrides)
    return Server(spec, clock=clock)


__all__ = ["serve", "Server", "ServeSpec", "ServeReport", "BucketStats",
           "TenantStats", "CacheInfo", "QueueFullError", "SystemClock",
           "ManualClock"]
