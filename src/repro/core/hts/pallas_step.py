"""Pallas kernel driver for the population machine's step phases.

The scatter/select-heavy phases of the step body (CDB enqueue, CDB
grant/wakeup, RS issue, trace writes) run here as fused ``pl.pallas_call``
kernels with a **lane-per-program grid**: program ``i`` owns scenario lane
``i`` and sees that lane's state rows as unbatched blocks.  That inverts
the cost structure of the vmapped XLA step — inside a kernel there is no
batch axis, so a uid-indexed trace write is a plain cheap scatter again
instead of a (lanes × table)-wide batched scatter, and the per-lane
selects fuse into one pass over the lane's rows.

Like every kernel in ``src/repro/kernels/``, the machine kernels are
written for TPU and validated on CPU in ``interpret=True`` mode — the
kernel body executes traceably, so bit-identity against the XLA step is
provable on the bench box (``tests/test_hts_step_impl.py``).  On CPU the
interpreter overhead loses to compiled XLA; the honest numbers live in
``BENCH_stepwidth.json`` and the XLA restructure carries the CPU headline.

The one structural constraint this module exists to absorb:
``pl.pallas_call`` cannot sit under ``jax.vmap``, so the kernels take the
*population* arrays directly (lane = grid axis) and ``machine.py`` builds
a population-level step around them rather than vmapping a per-lane one.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: interpret mode: True everywhere except a real TPU backend (same idiom
#: as :mod:`repro.kernels.common`).
INTERPRET = jax.default_backend() != "tpu"


def _lane_spec(arr):
    """BlockSpec selecting one lane's row of ``arr`` per grid step."""
    blk = (1,) + arr.shape[1:]
    nd = len(blk)
    return pl.BlockSpec(blk, lambda i, _nd=nd: (i,) + (0,) * (_nd - 1))


def lane_phase(fn, ins, outs, *, interpret=INTERPRET):
    """Run ``fn`` once per lane as a fused pallas kernel.

    ``ins`` maps names to population arrays (leading axis = lanes); ``fn``
    receives a dict of ONE lane's values with the lane axis dropped and
    returns a dict containing at least every name in ``outs``.  Each out
    name must also be an in name (the kernel updates state in place
    semantically; shapes/dtypes are taken from the input).  Returns the
    updated population arrays as ``{name: array}``.
    """
    names = list(ins)
    for k in outs:
        if k not in ins:
            raise ValueError(f"output {k!r} has no matching input")
    n = ins[names[0]].shape[0]

    # A pallas kernel body may not capture traced constants (the machine
    # closes over iotas and class tables) — hoist them into explicit
    # arguments and ship each one as a lane-broadcast input.  The copies
    # are a few KB per lane; on TPU these become loop-invariant VMEM
    # blocks.  (``jax.closure_convert`` only hoists *differentiable*
    # consts, and the machine's are all integer — so hoist by hand:
    # trace once, split the jaxpr consts out, re-evaluate inside.)
    example = {k: jax.ShapeDtypeStruct(ins[k].shape[1:], ins[k].dtype)
               for k in names}
    closed, out_shape = jax.make_jaxpr(fn, return_shape=True)(example)
    out_tree = jax.tree_util.tree_structure(out_shape)
    consts = closed.consts
    cnames = [f"_const{i}" for i in range(len(consts))]
    full = dict(ins)
    for cname, cval in zip(cnames, consts):
        cval = jnp.asarray(cval)
        full[cname] = jnp.broadcast_to(cval, (n,) + cval.shape)
    allnames = names + cnames

    def kernel(*refs):
        vals = {k: refs[i][...][0] for i, k in enumerate(allnames)}
        flat, _ = jax.tree_util.tree_flatten({k: vals[k] for k in names})
        out_flat = jax.core.eval_jaxpr(closed.jaxpr,
                                       [vals[k] for k in cnames], *flat)
        res = jax.tree_util.tree_unflatten(out_tree, out_flat)
        for j, k in enumerate(outs):
            out_ref = refs[len(allnames) + j]
            out_ref[...] = jnp.asarray(res[k], out_ref.dtype)[None]

    out = pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=[_lane_spec(full[k]) for k in allnames],
        out_specs=[_lane_spec(full[k]) for k in outs],
        out_shape=[jax.ShapeDtypeStruct(full[k].shape, full[k].dtype)
                   for k in outs],
        interpret=interpret,
    )(*[full[k] for k in allnames])
    if len(outs) == 1:
        out = [out] if not isinstance(out, (list, tuple)) else out
    return dict(zip(outs, out))
