"""Typed Program Builder for the HTS dataflow ISA (paper §V, Table I).

Assembly vs builder
-------------------
The paper describes programs the way its compiler would emit them — one
128-bit instruction per line, eight hex operand fields in Table-I order
(``assembler.py`` accepts exactly that text).  Hand-writing those lines means
hand-managing three machine resources at once:

* **memory regions** — every task's ``<in_region> <in_size> <out_region>
  <out_size>`` operands are raw addresses, so callers must do the
  ``OUT_BASE + i * RSTRIDE`` arithmetic themselves and nothing checks two
  live regions against overlapping by accident;
* **GPRs** — loops and indirect addressing need scratch registers, picked
  by hand and silently clobbered on reuse;
* **control-flow offsets** — ``if`` takes a *forward PC delta* and ``lend``
  a *backward body length* (Table I / Fig 6), which go stale on every edit.

This module is the embedded-Python front-end that owns those resources:

* :class:`Program` records a structured instruction stream;
* :meth:`Program.region` bump-allocates non-overlapping memory regions
  (:class:`Region`), with ``mem_init``/``effects`` images attached via
  :meth:`Region.init` / :meth:`Region.effect`;
* :meth:`Program.task` emits a typed task call (``p.task("fft_256",
  in_=x, out=4)``) and returns a handle whose output region feeds the next
  task — the dataflow graph reads like a dataflow graph;
* ``with p.loop(n):`` / ``p.branch(...)`` / ``with p.process(pid):`` are
  structured contexts lowered to ``lbeg``/``lend``/``if``/``jump`` with the
  offsets computed for you; :class:`Walker` reproduces the paper's
  walking-pointer idiom (a base register advanced by a stride each
  iteration, §V-B's loop example);
* registers are symbolic (:class:`Reg`) and numbered only at
  :meth:`Program.build`, so two programs can be merged
  (:meth:`Program.interleave`) without clobbering each other's GPRs;
* :meth:`Program.merge` is the N-way tenant merge (region/register/pid
  isolation checked up front) and the natural place to decide QoS:
  ``merge(priorities={pid: weight}, quotas={pid: cap})`` attaches a
  :class:`~repro.core.hts.policy.SchedPolicy` that ``hts.run`` /
  ``hts.compare`` then apply by default.

``build()`` lowers to the exact 128-bit encoding of ``isa.py`` and can also
emit paper-style assembly text (``BuiltProgram.asm`` — byte-for-byte
reassemblable, used by the round-trip property tests), so paper-fidelity
assembly listings remain available for inspection and tests.
"""
from __future__ import annotations

import dataclasses
import itertools
from contextlib import contextmanager
from typing import Iterator, Optional, Sequence, Union

import numpy as np

from . import isa
from .costs import FUNC_IDS
from .policy import SchedPolicy

#: default start of the auto-allocated output-region space (matches the old
#: hand-written ``OUT_BASE``) and its default alignment (old ``RSTRIDE``).
REGION_BASE = 0x100
REGION_ALIGN = 0x8

_CONDS = {"==": isa.CND_EQ, "!=": isa.CND_NEQ, ">=": isa.CND_GE,
          "<=": isa.CND_LE}
_KINDS = {"reg": isa.BR_RR, "mem": isa.BR_MR, "bus": isa.BR_BR}


class BuilderError(ValueError):
    """Raised on malformed Program-Builder usage (bad operand, overlap...)."""


# ---------------------------------------------------------------------------
# operands
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Region:
    """A contiguous span of task memory: ``[addr, addr + size)``."""
    addr: int
    size: int
    name: str = ""
    _prog: Optional["Program"] = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def end(self) -> int:
        return self.addr + self.size

    def sub(self, offset: int, size: int, name: str = "") -> "Region":
        """A sub-region view (no new reservation)."""
        if offset < 0 or offset + size > self.size:
            raise BuilderError(
                f"sub-region [{offset}, {offset + size}) outside region "
                f"{self.name or hex(self.addr)} of size {self.size}")
        return Region(self.addr + offset, size, name or self.name,
                      self._prog)

    def init(self, values: Union[int, Sequence[int]], offset: int = 0):
        """Attach initial memory contents (``mem_init`` image) to the region."""
        self._attach("mem_init", values, offset)
        return self

    def effect(self, values: Union[int, Sequence[int]], offset: int = 0):
        """Attach the values a producer task writes here on completion
        (the simulator's ``effects`` image, golden.py docstring)."""
        self._attach("effects", values, offset)
        return self

    def _attach(self, which: str, values, offset: int) -> None:
        if self._prog is None:
            raise BuilderError("region is not attached to a Program")
        vals = [values] if isinstance(values, int) else list(values)
        if offset + len(vals) > self.size:
            raise BuilderError(
                f"{which} image of {len(vals)} words at +{offset} exceeds "
                f"region size {self.size}")
        img = getattr(self._prog, which)
        for i, v in enumerate(vals):
            img[self.addr + offset + i] = int(v)


@dataclasses.dataclass(eq=False)
class Reg:
    """A symbolic GPR; numbered at :meth:`Program.build` in first-use order."""
    name: str = ""

    def __repr__(self) -> str:
        return f"Reg({self.name or hex(id(self))})"


@dataclasses.dataclass(frozen=True)
class TaskHandle:
    """Returned by :meth:`Program.task`; chains the task's output region."""
    index: int
    func: str
    out: Optional[Region]


# ---------------------------------------------------------------------------
# recorded nodes (internal)
# ---------------------------------------------------------------------------
def _walk_nodes(nodes):
    """Yield every node in a block list, descending into loops/branches."""
    for node in nodes:
        yield node
        if isinstance(node, _Loop):
            yield from _walk_nodes(node.body)
        elif isinstance(node, _Branch):
            yield from _walk_nodes(node.taken)
            yield from _walk_nodes(node.not_taken)


def _collect_regs(nodes) -> set:
    """All symbolic Reg objects referenced anywhere in a block tree."""
    regs: set = set()
    for node in _walk_nodes(nodes):
        if isinstance(node, _Op):
            for field in (node.a, node.asz, node.b):
                if isinstance(field, Reg):
                    regs.add(field)
        elif isinstance(node, _Loop):
            regs.add(node.counter)
            if isinstance(node.count, Reg):
                regs.add(node.count)
        elif isinstance(node, _Branch):
            regs.add(node.thr)
            if isinstance(node.on, Reg):
                regs.add(node.on)
    return regs


def _collect_pids(nodes) -> set:
    """Process ids of every task emitted in a block tree."""
    return {node.pid for node in _walk_nodes(nodes)
            if isinstance(node, _Op) and node.op == isa.OP_TASK}



@dataclasses.dataclass
class _Op:
    """One flat instruction with possibly-symbolic (Reg) operands."""
    op: int
    acc: int = 0
    a: object = 0            # int | Reg
    asz: object = 0          # int | Reg
    b: object = 0            # int | Reg
    bsz: int = 0
    tid: int = 0
    pid: int = 0
    ctl: int = 0
    meta: int = 0


@dataclasses.dataclass
class _Loop:
    count: object            # int | Reg
    counter: Reg
    body: list


@dataclasses.dataclass
class _Branch:
    kind: int
    cond: int
    on: object               # int address | Reg
    thr: Reg
    taken: list
    not_taken: list


# ---------------------------------------------------------------------------
# walker
# ---------------------------------------------------------------------------
class Walker:
    """The paper's loop idiom: a base register stepped by a stride register.

    ``w.offset(k)`` materialises a register holding ``base + k`` at the
    current point in the instruction stream; ``w.advance()`` steps the base.
    Used as a task operand, a Walker is its (indirect) base register.
    """

    def __init__(self, prog: "Program", start: int, stride: int,
                 name: str = "walker"):
        self._prog = prog
        self.start = start
        self.stride = stride
        self.name = name
        self.base = Reg(f"{name}.base")
        self._stride_reg = Reg(f"{name}.stride")
        prog.mov(self.base, start)
        prog.mov(self._stride_reg, stride)

    def offset(self, k: int, name: str = "") -> Reg:
        r = self._prog.mov(Reg(name or f"{self.name}+{k:#x}"), self.base)
        if k:
            scratch = self._prog._scratch_reg()
            self._prog.mov(scratch, k)
            self._prog.add(r, r, scratch)
        return r

    def advance(self) -> None:
        self._prog.add(self.base, self.base, self._stride_reg)


# ---------------------------------------------------------------------------
# the builder
# ---------------------------------------------------------------------------
class Program:
    """An HTS dataflow program under construction.

    >>> p = Program("quickstart")
    >>> x = p.input(0x10, 4)
    >>> fft = p.task("fft_256", in_=x, out=4)
    >>> dot = p.task("vector_dot", in_=fft, out=1)
    >>> built = p.build()          # .code (P,4) uint32, .asm, .mem_init, ...
    """

    def __init__(self, name: str = "program", *,
                 keynames: Optional[dict[str, int]] = None,
                 region_base: int = REGION_BASE,
                 region_align: int = REGION_ALIGN,
                 num_regs: int = 32,
                 policy: Optional[SchedPolicy] = None):
        self.name = name
        self.keynames = dict(FUNC_IDS if keynames is None else keynames)
        self.num_regs = num_regs
        #: scheduling policy attached to the program (``hts.run`` applies it
        #: by default; see :meth:`merge`'s ``priorities``/``quotas``)
        self.policy: Optional[SchedPolicy] = policy
        self.mem_init: dict[int, int] = {}
        self.effects: dict[int, int] = {}
        self._nodes: list = []
        self._blocks: list[list] = [self._nodes]
        self._pids: list[int] = [0]
        # (start, end, name, written): written=False marks external inputs,
        # which two interleaved programs may legitimately share
        self._reserved: list[tuple[int, int, str, bool]] = []
        self._alloc_ptr = region_base
        self._align = region_align
        self._scratch: Optional[Reg] = None
        self._n_tasks = 0
        self._in_loop_or_branch = 0

    # -------------------------------------------------------------- regions
    def _overlap(self, s: int, e: int):
        for entry in self._reserved:
            if entry[0] < e and s < entry[1]:
                return entry
        return None

    def _reserve(self, s: int, e: int, name: str, written: bool = True) -> None:
        hit = self._overlap(s, e)
        if hit is not None:
            raise BuilderError(
                f"region {name!r} [{s:#x}, {e:#x}) overlaps live region "
                f"{hit[2]!r} [{hit[0]:#x}, {hit[1]:#x})")
        self._reserved.append((s, e, name, written))

    def region(self, size: int, *, at: Optional[int] = None,
               align: Optional[int] = None, name: str = "") -> Region:
        """Reserve a ``size``-word region; auto-placed unless ``at`` given."""
        if size <= 0:
            raise BuilderError(f"region size must be positive, got {size}")
        if at is not None:
            self._reserve(at, at + size, name or f"r@{at:#x}")
            return Region(at, size, name, self)
        align = self._align if align is None else align
        addr = -(-self._alloc_ptr // align) * align
        while True:
            hit = self._overlap(addr, addr + size)
            if hit is None:
                break
            addr = -(-hit[1] // align) * align
        self._reserve(addr, addr + size, name or f"r{len(self._reserved)}")
        self._alloc_ptr = addr + size
        return Region(addr, size, name, self)

    def input(self, addr: int, size: int, name: str = "") -> Region:
        """Name an externally-provided input span (reserved like any region;
        interleaved programs may share an identical input span)."""
        self._reserve(addr, addr + size, name or f"in@{addr:#x}",
                      written=False)
        return Region(addr, size, name, self)

    # ------------------------------------------------------------ registers
    def reg(self, name: str = "") -> Reg:
        return Reg(name)

    def _scratch_reg(self) -> Reg:
        if self._scratch is None:
            self._scratch = Reg("scratch")
        return self._scratch

    # -------------------------------------------------------------- low-level
    def _emit(self, node) -> None:
        self._blocks[-1].append(node)

    def mov(self, dst: Reg, src: Union[int, Reg]) -> Reg:
        """``dst = src`` (immediate or register copy)."""
        if isinstance(src, Reg):
            self._emit(_Op(isa.OP_MOV, a=src, b=dst))
        else:
            self._emit(_Op(isa.OP_MOV, a=int(src), b=dst, ctl=isa.CTL_IMM))
        return dst

    def add(self, dst: Reg, x: Reg, y: Reg) -> Reg:
        """``dst = x + y`` (register-register)."""
        self._emit(_Op(isa.OP_ADD, a=x, asz=y, b=dst))
        return dst

    def mul(self, dst: Reg, x: Reg, y: Reg) -> Reg:
        self._emit(_Op(isa.OP_MUL, a=x, asz=y, b=dst))
        return dst

    def let(self, value: int, name: str = "") -> Reg:
        """Allocate a register and load an immediate into it."""
        return self.mov(Reg(name or f"#{value:#x}"), value)

    def nop(self) -> None:
        self._emit(_Op(isa.OP_NOP))

    # ----------------------------------------------------------------- tasks
    def _in_operand(self, x, size) -> tuple[object, int, int]:
        """→ (a_field, asz, ctl_bits) for a task input."""
        if isinstance(x, TaskHandle):
            if x.out is None:
                raise BuilderError(
                    f"task {x.func!r} has an indirect output; pass the "
                    "region or register explicitly")
            x = x.out
        if isinstance(x, Region):
            return x.addr, int(size if size is not None else x.size), 0
        if isinstance(x, Walker):
            x = x.base
        if isinstance(x, Reg):
            if size is None:
                raise BuilderError(
                    "indirect (register) operands need an explicit size")
            return x, int(size), isa.CTL_IN_INDIRECT
        raise BuilderError(f"bad task input operand: {x!r}")

    def _out_operand(self, x, size) -> tuple[object, int, int, Optional[Region]]:
        if isinstance(x, int):
            x = self.region(x)
        if isinstance(x, Region):
            return x.addr, int(size if size is not None else x.size), 0, x
        if isinstance(x, Walker):
            x = x.base
        if isinstance(x, Reg):
            if size is None:
                raise BuilderError(
                    "indirect (register) outputs need an explicit size")
            return x, int(size), isa.CTL_OUT_INDIRECT, None
        raise BuilderError(f"bad task output operand: {x!r}")

    def task(self, func: str, *, in_, out, in_size: Optional[int] = None,
             out_size: Optional[int] = None, tid: int = 0,
             pid: Optional[int] = None, meta: int = 0) -> TaskHandle:
        """Emit a task call on accelerator ``func``.

        ``in_``/``out`` accept a :class:`Region`, a :class:`TaskHandle`
        (its output region — dataflow chaining), a :class:`Reg`/:class:`Walker`
        (indirect addressing, ``in_size``/``out_size`` then required), or for
        ``out`` an ``int`` size to auto-allocate a fresh region.
        """
        if func not in self.keynames:
            raise BuilderError(f"unknown accelerator keyname {func!r} "
                               f"(known: {sorted(self.keynames)})")
        a, asz, ctl_in = self._in_operand(in_, in_size)
        b, bsz, ctl_out, out_region = self._out_operand(out, out_size)
        self._emit(_Op(isa.OP_TASK, acc=self.keynames[func], a=a, asz=asz,
                       b=b, bsz=bsz, tid=tid & 0xF,
                       pid=(self._pids[-1] if pid is None else pid) & 0xF,
                       ctl=ctl_in | ctl_out, meta=meta))
        if not self._in_loop_or_branch:
            self._n_tasks += 1
        return TaskHandle(len(self._blocks[-1]) - 1, func, out_region)

    # ------------------------------------------------------- structured flow
    @contextmanager
    def loop(self, count: Union[int, Reg], counter: Optional[Reg] = None
             ) -> Iterator[Reg]:
        """``with p.loop(n):`` — body repeats ``n`` times (lbeg/lend)."""
        counter = counter or Reg("loopctr")
        body: list = []
        self._blocks.append(body)
        self._in_loop_or_branch += 1
        try:
            yield counter
        finally:
            self._in_loop_or_branch -= 1
            self._blocks.pop()
            self._emit(_Loop(count, counter, body))

    def walker(self, *, stride: int, start: Optional[int] = None,
               count: Optional[int] = None, name: str = "walker") -> Walker:
        """A walking output pointer.  Auto-reserves ``count * stride`` words
        when ``start`` is omitted; an explicit ``start`` reserves nothing
        (e.g. both arms of a branch walking the same shared span)."""
        if start is None:
            if count is None:
                raise BuilderError("walker needs either start= or count=")
            start = self.region(count * stride, name=name).addr
        return Walker(self, start, stride, name)

    def branch(self, *, on: Union[Region, Reg], cond: str,
               thr: Union[int, Reg], kind: str = "mem") -> "BranchCtx":
        """Emit an ``if`` (paper §IV-C3).  ``kind``: ``"reg"`` (RR, inline),
        ``"mem"`` (MR, spawned memory read), ``"bus"`` (BR, waits on the CDB
        broadcast of the in-flight producer of ``on``).  The fall-through
        block (``.not_taken()``) is the speculated path."""
        if cond not in _CONDS:
            raise BuilderError(f"bad condition {cond!r}; one of {list(_CONDS)}")
        if kind not in _KINDS:
            raise BuilderError(f"bad branch kind {kind!r}; one of {list(_KINDS)}")
        k = _KINDS[kind]
        if isinstance(on, Region):
            if k == isa.BR_RR:
                raise BuilderError('kind="reg" branches test a Reg, not a Region')
            addr: object = on.addr
        elif isinstance(on, Reg):
            if k != isa.BR_RR:
                raise BuilderError(f'kind={kind!r} branches test a Region')
            addr = on
        else:
            raise BuilderError(f"bad branch operand: {on!r}")
        if not isinstance(thr, Reg):
            thr = self.let(int(thr), "thr")
        node = _Branch(kind=k, cond=_CONDS[cond], on=addr, thr=thr,
                       taken=[], not_taken=[])
        self._emit(node)
        return BranchCtx(self, node)

    @contextmanager
    def process(self, pid: int) -> Iterator[None]:
        """Tag tasks emitted inside with process id ``pid`` (multi-app)."""
        self._pids.append(pid & 0xF)
        try:
            yield
        finally:
            self._pids.pop()

    # -------------------------------------------------------------- lowering
    def _resolve_regs(self, flat_ops: list[_Op]) -> dict[Reg, int]:
        """Number symbolic registers 1..num_regs-1 in first-use order."""
        mapping: dict[Reg, int] = {}
        ids = itertools.count(1)
        for op in flat_ops:
            for field in (op.a, op.asz, op.b):
                if isinstance(field, Reg) and field not in mapping:
                    mapping[field] = next(ids)
        if mapping and max(mapping.values()) >= self.num_regs:
            raise BuilderError(
                f"program uses {len(mapping)} registers; only "
                f"{self.num_regs - 1} available")
        return mapping

    def _flatten(self, nodes: list, out: list[_Op]) -> None:
        for node in nodes:
            if isinstance(node, _Op):
                out.append(node)
            elif isinstance(node, _Loop):
                if isinstance(node.count, Reg):
                    out.append(_Op(isa.OP_LBEG, a=node.count,
                                   asz=node.counter, ctl=1))
                else:
                    out.append(_Op(isa.OP_LBEG, a=int(node.count),
                                   asz=node.counter))
                start = len(out)
                self._flatten(node.body, out)
                out.append(_Op(isa.OP_LEND, asz=node.counter,
                               b=len(out) - start))
            elif isinstance(node, _Branch):
                if_op = _Op(isa.OP_IF, a=node.on, asz=node.thr,
                            ctl=node.kind | (node.cond << 2))
                out.append(if_op)
                if_pc = len(out) - 1
                self._flatten(node.not_taken, out)
                if node.taken:
                    jump_op = _Op(isa.OP_JUMP)
                    out.append(jump_op)
                    if_op.b = len(out) - if_pc
                    self._flatten(node.taken, out)
                    jump_op.a = len(out)
                else:
                    if_op.b = len(out) - if_pc
            else:  # pragma: no cover - defensive
                raise BuilderError(f"unknown node {node!r}")

    def build(self) -> "BuiltProgram":
        if len(self._blocks) != 1:
            raise BuilderError("build() inside an open loop/branch/process "
                               "context")
        flat: list[_Op] = []
        self._flatten(self._nodes, flat)
        regmap = self._resolve_regs(flat)

        def rr(x):
            return regmap[x] if isinstance(x, Reg) else int(x)

        instrs = [isa.Instr(op=o.op, acc=o.acc, a=rr(o.a), asz=rr(o.asz),
                            b=rr(o.b), bsz=o.bsz, tid=o.tid, pid=o.pid,
                            ctl=o.ctl, meta=o.meta) for o in flat]
        return BuiltProgram(
            name=self.name,
            instrs=tuple(instrs),
            code=isa.encode_program(instrs),
            mem_init=dict(self.mem_init),
            effects=dict(self.effects),
            keynames=dict(self.keynames),
            n_tasks_hint=self._n_tasks if self._n_tasks == sum(
                1 for i in instrs if i.op == isa.OP_TASK) else 0,
            policy=self.policy,
        )

    # --------------------------------------------------------------- merge
    @classmethod
    def merge(cls, programs: Sequence["Program"], name: str = "shared", *,
              require_distinct_pids: bool = False,
              priorities: Optional[dict[int, int]] = None,
              quotas: Optional[dict[int, int]] = None,
              rs_caps: Optional[dict[int, int]] = None,
              frontends: bool = False,
              arrivals: Optional[Sequence[int]] = None,
              fe_mode: Optional[str] = None):
        """N-way graph-level round-robin merge: N CPUs pushing their task
        streams into the one Task Queue (pids mark the owners) — the paper's
        multi-application sharing scenario, for any tenant count.

        With ``frontends=True`` the tenants' instruction streams stay
        **separate** — the paper's actual system model, N CPUs each pushing
        independently — and the result is a
        :class:`~repro.core.hts.frontend.MultiProgram`: one code image with
        a per-tenant dispatch stream each (own program counter, decode
        window and optional ``arrivals`` offset), arbitrated per cycle into
        the shared reservation station (see ``frontend.py``).  ``fe_mode``
        ("rr"/"weighted") selects that arbitration on the attached policy.
        ``arrivals``/``fe_mode`` are only meaningful with
        ``frontends=True``.

        ``priorities`` (``{pid: weight}``), ``quotas`` (``{pid: max
        in-flight units per accelerator class}``) and ``rs_caps`` (``{pid:
        max reservation-station entries}`` — RS admission control) attach a
        :class:`~repro.core.hts.policy.SchedPolicy` to the merged program;
        ``hts.run``/``hts.compare`` apply it by default, so a merge-time QoS
        decision follows the program everywhere.  When omitted, the source
        programs' own policies are unioned (conflicting entries for a pid
        are a :class:`BuilderError`).

        Structured nodes (a whole loop or branch) interleave atomically, so
        labels/offsets can never be torn apart — unlike merging assembly
        text line-by-line.  Three per-process isolation properties are
        checked up front:

        * **memory regions** — every pair of written regions must be
          disjoint; only *identical read-only input spans* (``Program.input``)
          may be shared between tenants;
        * **register spaces** — registers are symbolic until ``build()``, so
          they cannot clobber each other; a :class:`Reg` object appearing in
          two source programs (a truly shared register) is rejected, and the
          combined register demand is checked against the GPR bank here
          instead of failing late at ``build()``;
        * **process ids** — with ``require_distinct_pids=True``, two tenants
          emitting tasks under the same pid is an error (multi-tenant
          accounting would silently merge their schedules).
        """
        if frontends:
            from .frontend import build_frontends
            return build_frontends(
                programs, name, arrivals=arrivals,
                require_distinct_pids=require_distinct_pids,
                priorities=priorities, quotas=quotas, rs_caps=rs_caps,
                fe_mode=fe_mode)
        if arrivals is not None or fe_mode is not None:
            raise BuilderError("arrivals=/fe_mode= require frontends=True "
                               "(a merged single stream has no per-tenant "
                               "frontends)")
        programs = list(programs)
        if not programs:
            raise BuilderError("merge needs at least one program")
        keynames: dict[str, int] = {}
        for p in programs:
            keynames.update(p.keynames)
        merged = cls(name, keynames=keynames,
                     num_regs=max(p.num_regs for p in programs))

        # --- region isolation (identical read-only inputs may be shared)
        for p in programs:
            for (s, e, rn, wr) in p._reserved:
                hit = merged._overlap(s, e)
                shared_input = (hit is not None and not wr and not hit[3]
                                and (hit[0], hit[1]) == (s, e))
                if hit is not None and not shared_input:
                    raise BuilderError(
                        f"merge: region {rn!r} [{s:#x}, {e:#x}) of program "
                        f"{p.name!r} overlaps {hit[2]!r} "
                        f"[{hit[0]:#x}, {hit[1]:#x}) of another tenant")
                if hit is None:
                    merged._reserved.append((s, e, rn, wr))

        # --- register isolation: no Reg object may span two tenants, and
        # the union must fit the GPR bank (fail here, not at build())
        seen: dict = {}
        total_regs = 0
        for p in programs:
            regs = _collect_regs(p._nodes)
            for r in regs:
                if r in seen and seen[r] is not p:
                    raise BuilderError(
                        f"merge: register {r!r} is used by both "
                        f"{seen[r].name!r} and {p.name!r} — tenants must "
                        "own disjoint register sets")
                seen[r] = p
            total_regs += len(regs)
        if total_regs >= merged.num_regs:
            raise BuilderError(
                f"merge: tenants need {total_regs} registers combined; only "
                f"{merged.num_regs - 1} available")

        # --- pid isolation (optional: multi-tenant accounting)
        if require_distinct_pids:
            owner: dict[int, "Program"] = {}
            for p in programs:
                for pid in _collect_pids(p._nodes):
                    if pid in owner and owner[pid] is not p:
                        raise BuilderError(
                            f"merge: pid {pid} is used by both "
                            f"{owner[pid].name!r} and {p.name!r}")
                    owner[pid] = p

        # --- round-robin splice of top-level nodes (structured nodes atomic)
        streams = [p._nodes for p in programs]
        for i in range(max(len(s) for s in streams)):
            for s in streams:
                if i < len(s):
                    merged._nodes.append(s[i])
        # image union: regions are disjoint except identical shared inputs,
        # so a key conflict means two tenants seeded the shared span with
        # different data — reject instead of silent last-writer-wins
        for p in programs:
            for which in ("mem_init", "effects"):
                dst = getattr(merged, which)
                for k, v in getattr(p, which).items():
                    if k in dst and dst[k] != v:
                        raise BuilderError(
                            f"merge: conflicting {which} values at address "
                            f"{k:#x} ({dst[k]} vs {v}, program {p.name!r}) "
                            "— tenants sharing an input span must agree on "
                            "its contents")
                    dst[k] = v
        merged._n_tasks = sum(p._n_tasks for p in programs)
        merged._scratch = None   # distinct Reg objects per source program

        # --- scheduling policy: explicit args win; else union the tenants'
        if priorities is not None or quotas is not None or rs_caps is not None:
            merged.policy = SchedPolicy.of(weights=priorities, quotas=quotas,
                                           rs_caps=rs_caps)
        else:
            pol: Optional[SchedPolicy] = None
            for p in programs:
                if p.policy is None:
                    continue
                try:
                    pol = p.policy if pol is None else pol.merge_with(p.policy)
                except ValueError as e:
                    raise BuilderError(f"merge: {e} (program {p.name!r})")
            merged.policy = pol
        return merged

    def interleave(self, other: "Program", name: str = "shared") -> "Program":
        """Two-way :meth:`merge` (kept for the original pairwise API)."""
        return Program.merge([self, other], name)


class BranchCtx:
    """Handle returned by :meth:`Program.branch`; records the two arms."""

    def __init__(self, prog: Program, node: _Branch):
        self._prog = prog
        self._node = node

    @contextmanager
    def _arm(self, block: list) -> Iterator[None]:
        self._prog._blocks.append(block)
        self._prog._in_loop_or_branch += 1
        try:
            yield
        finally:
            self._prog._in_loop_or_branch -= 1
            self._prog._blocks.pop()

    def taken(self):
        """The branch-taken arm (jumped to; *not* speculated)."""
        return self._arm(self._node.taken)

    def not_taken(self):
        """The fall-through arm — the path HTS speculates down (§IV-C3)."""
        return self._arm(self._node.not_taken)


@dataclasses.dataclass(frozen=True)
class BuiltProgram:
    """Immutable lowering result: machine code + images + asm text."""
    name: str
    instrs: tuple
    code: np.ndarray
    mem_init: dict[int, int]
    effects: dict[int, int]
    keynames: dict[str, int]
    n_tasks_hint: int = 0
    policy: Optional[SchedPolicy] = None    # scheduling policy (hts.run default)

    @property
    def asm(self) -> str:
        """Paper-style assembly text; reassembles to exactly ``self.code``."""
        names = {v: k for k, v in self.keynames.items()}
        return isa.disassemble(self.code, names)

    def __len__(self) -> int:
        return len(self.instrs)
