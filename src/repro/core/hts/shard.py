"""Scenario-axis sharding: one :class:`~repro.core.hts.batch.PackedPopulation`
across devices.

The scenario axis is embarrassingly parallel — every lane of a packed
population is an independent machine instance — so sharding it is pure
data placement: split the 11 batched machine arguments over a 1-D device
mesh and run the **population machine** (``machine.make_machine(...,
population=True)``) per shard.  Each device executes its own while loop
over its own lanes (there are no collectives in the step body), so a
shard drains as fast as *its* slowest lane, not the global one —
work-homogeneous shards (``batch.plan_chunks``) compose with sharding
exactly as they do with batching.

Two pieces of shape bookkeeping make the SPMD program identical on every
device:

* :func:`pad_lanes` pads the lane count to a multiple of the device count
  by replicating the population's *lightest* lane (smallest ``p_len`` —
  pad lanes halt early and become fixed points of the alive-gated step).
  Padding is semantics-free: real lanes keep their indices and callers
  drop the tail.
* :func:`sharded_runner` compiles one ``shard_map``-wrapped population
  machine per ``(MachineSpec, max_prog, devices)`` — the same bucketing
  discipline as the single-device ``api._population_runner``, with the
  device count one more static key.

``api.run_many(devices=N)`` is the front door; ``api.compare_population``
accepts the same ``devices=`` so the sharded path is differentially
verified lane-for-lane against the single-device golden loop
(tests/test_multidevice.py drives it under a forced multi-device host
pool).  ``shard_map`` itself resolves through :mod:`repro.core.compat`
(the ``jax.shard_map`` vs ``jax.experimental.shard_map`` spelling shim
shared with ``sched/pipeline.py``).
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from . import machine
from .batch import PackedPopulation


def device_count() -> int:
    """Devices visible to this process (the ``devices=`` upper bound)."""
    import jax
    return len(jax.devices())


def pad_lanes(pop: PackedPopulation, multiple: int) -> PackedPopulation:
    """Pad ``pop`` to a lane count divisible by ``multiple``.

    Pad lanes replicate the lightest real lane (smallest ``p_len``), so
    they halt first and idle as fixed points of the alive-gated step
    while their shard's real lanes finish.  Real lanes keep indices
    ``0..len(pop)-1``; callers slice the results back to that prefix.
    """
    if multiple <= 0:
        raise ValueError(f"multiple must be positive, got {multiple}")
    n = len(pop)
    total = -(-n // multiple) * multiple
    if total == n:
        return pop
    src = int(np.argmin(pop.p_len))
    k = total - n

    def rep(a: np.ndarray) -> np.ndarray:
        return np.concatenate([a, np.repeat(a[src:src + 1], k, axis=0)],
                              axis=0)

    return dataclasses.replace(
        pop,
        names=pop.names + (f"<pad:{pop.names[src]}>",) * k,
        preps=pop.preps + (pop.preps[src],) * k,
        policies=pop.policies + (pop.policies[src],) * k,
        ftab=rep(pop.ftab), p_len=rep(pop.p_len),
        mem=rep(pop.mem), eff=rep(pop.eff), n_fu=rep(pop.n_fu),
        prio=rep(pop.prio), quota=rep(pop.quota), rs_cap=rep(pop.rs_cap),
        fu_cost=rep(pop.fu_cost), eft=rep(pop.eft),
        streams=rep(pop.streams))


@functools.lru_cache(maxsize=32)
def sharded_runner(spec: machine.MachineSpec, max_prog: int, devices: int):
    """One jitted, device-sharded population machine per
    ``(spec, max_prog, devices)`` static bucket.

    The scenario axis is split over a 1-D ``("scenario",)`` mesh; each
    device runs the population machine's while loop on its own lane
    shard (no collectives — per-shard trip counts are independent, which
    is the whole point).  Lane counts must divide ``devices``
    (:func:`pad_lanes`).

    ``spec.step_impl`` flows through untouched — the sharded machine is
    just ``make_machine(spec, ...)`` under a ``shard_map``, so the
    pallas-kernel step runs per shard with a lanes/devices grid.
    """
    import jax
    from jax.sharding import PartitionSpec as P

    from ..compat import shard_map

    avail = device_count()
    if not 1 <= devices <= avail:
        raise ValueError(f"devices={devices} requested but this process "
                         f"sees {avail} device(s)")
    mesh = jax.make_mesh((devices,), ("scenario",))
    fn = machine.make_machine(spec, max_prog, population=True)
    return jax.jit(shard_map(fn, mesh=mesh, in_specs=P("scenario"),
                             out_specs=P("scenario")))


@functools.lru_cache(maxsize=32)
def sharded_slicer(spec: machine.MachineSpec, max_prog: int,
                   devices: int) -> machine.ResumableMachine:
    """The resumable population machine, device-sharded: ``init`` and
    ``run_slice`` each wrapped in one ``shard_map`` over the same 1-D
    ``("scenario",)`` mesh as :func:`sharded_runner`.

    The carry and all 11 machine arguments split over the scenario axis;
    the slice ``budget`` is replicated (every device pauses its own lanes
    at the same per-lane cycle ceiling).  Lane counts must divide
    ``devices`` (:func:`pad_lanes`) — the serving engine rounds its lane
    width up to a device multiple once, so every slice of every launch
    presents the identical sharded signature.
    """
    import jax
    from jax.sharding import PartitionSpec as P

    from ..compat import shard_map

    avail = device_count()
    if not 1 <= devices <= avail:
        raise ValueError(f"devices={devices} requested but this process "
                         f"sees {avail} device(s)")
    mesh = jax.make_mesh((devices,), ("scenario",))
    rm = machine.make_machine(spec, max_prog, population=True,
                              resumable=True)
    init = jax.jit(shard_map(rm.init, mesh=mesh, in_specs=P("scenario"),
                             out_specs=P("scenario")))
    run_slice = jax.jit(shard_map(
        rm.run_slice, mesh=mesh,
        in_specs=(P("scenario"),) * 12 + (P(),),
        out_specs=P("scenario")))
    return machine.ResumableMachine(init=init, run_slice=run_slice,
                                    collect=rm.collect)
