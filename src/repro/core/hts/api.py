"""Unified HTS simulation facade: ``hts.run`` and ``hts.sweep``.

One entry point for every caller of the reproduction — benchmarks, examples
and tests no longer thread ``assembler.assemble → machine.simulate(...)`` /
``golden.run(...)`` by hand (each with a different signature):

    >>> from repro.core import hts
    >>> p = hts.Program("demo")
    >>> x = p.input(0x10, 4)
    >>> fft = p.task("fft_256", in_=x, out=4)
    >>> r = hts.run(p, scheduler="hts_spec", n_fu=2)
    >>> r.cycles, r.utilization, r.schedule[0].func_name
    >>> r.speedup_vs(hts.run(p, scheduler="naive", n_fu=2))

``run`` accepts a :class:`~repro.core.hts.builder.Program`, a built program,
a ``Bench``, raw assembly text, or a (P, 4) machine-code array, and executes
it on either backend:

* ``backend="jax"``    — the compiled ``lax.while_loop`` machine
  (:mod:`machine`), event-skip by default;
* ``backend="golden"`` — the pure-Python cycle-accurate oracle
  (:mod:`golden`).

Both return the same :class:`Result` with identical per-task schedule rows
(the two simulators are schedule-equivalence-tested).

``sweep`` wraps the machine's ``vmap`` path: one compiled machine per
scheduler, the FU-configuration axis batched — the Fig-10 strong-scaling
experiment as a single call.

``compare`` is the differential runner: golden oracle vs the compiled
machine with event-skip on *and* off, per scheduler, schedule-tuple
equality asserted — the workhorse behind the seeded multi-tenant fuzzer
(``workloads.py`` / tests/test_hts_multitenant.py).

Multi-tenant metrics live on :class:`Result`: ``by_pid()`` /
``schedule_for`` slice the schedule by owning process, ``app_makespan``
is one tenant's finish cycle, and ``fairness`` reports per-tenant
slowdown vs solo runs (max slowdown = the fairness figure of merit),
annotated with each pid's priority weight (``FairnessReport.by_weight``
is the slowdown-vs-priority curve).

QoS scheduling: ``run``/``sweep``/``compare`` all take a
``policy=``:class:`~repro.core.hts.policy.SchedPolicy` (per-pid priority
weights + per-class FU quotas for the RS arbiter).  Resolution order:
explicit argument > policy attached to the program (e.g. by
``Program.merge(priorities=...)``) > ``params.policy``.  Policies are
runtime data to the compiled machine — sweeping them never recompiles.

See docs/API.md for a runnable tour of this module.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Optional, Sequence, Union

import numpy as np

from . import golden, isa, machine
from .builder import BuiltProgram, Program
from .costs import (ALL_SCHEDULERS, FUNC_NAMES, NUM_FUNCS, SchedulerCosts,
                    costs_by_name)
from .golden import HtsParams
from .policy import SchedPolicy


class SimulationError(RuntimeError):
    """A simulation did not halt (hit ``max_cycles``) or overflowed."""


# ---------------------------------------------------------------------------
# program normalisation
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class _Prepared:
    name: str
    code: np.ndarray
    mem_init: dict[int, int]
    effects: dict[int, int]
    policy: Optional[SchedPolicy] = None    # attached by builder/merge


def _prepare(program) -> _Prepared:
    """Accept Program | BuiltProgram | Bench-like | asm text | code array."""
    if isinstance(program, _Prepared):
        return program
    if isinstance(program, Program):
        program = program.build()
    if isinstance(program, BuiltProgram):
        return _Prepared(program.name, program.code, program.mem_init,
                         program.effects, program.policy)
    if isinstance(program, str):                      # assembly text
        from . import assembler
        return _Prepared("<asm>", assembler.assemble(program), {}, {})
    if isinstance(program, np.ndarray):               # raw machine code
        return _Prepared("<code>", program, {}, {})
    if hasattr(program, "asm"):                       # programs.Bench (duck)
        from . import assembler
        return _Prepared(getattr(program, "name", "<bench>"),
                         assembler.assemble(program.asm),
                         dict(getattr(program, "mem_init", {}) or {}),
                         dict(getattr(program, "effects", {}) or {}),
                         getattr(program, "policy", None))
    raise TypeError(f"cannot interpret {type(program).__name__} as an HTS "
                    "program")


def _norm_policy(policy: Optional[SchedPolicy], prep: _Prepared,
                 params: HtsParams) -> SchedPolicy:
    """Effective policy: explicit arg > program-attached > params default."""
    if policy is not None:
        return policy
    if prep.policy is not None:
        return prep.policy
    return params.policy


def _norm_n_fu(n_fu) -> tuple[int, ...]:
    if isinstance(n_fu, (int, np.integer)):
        return (int(n_fu),) * NUM_FUNCS
    t = tuple(int(k) for k in n_fu)
    if len(t) != NUM_FUNCS:
        raise ValueError(f"n_fu must be an int or {NUM_FUNCS} per-class "
                         f"counts, got {len(t)}")
    return t


def _norm_costs(scheduler) -> SchedulerCosts:
    return (costs_by_name(scheduler) if isinstance(scheduler, str)
            else scheduler)


# ---------------------------------------------------------------------------
# results
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TaskRow:
    """One scheduled task: dispatch/issue/complete/broadcast cycles."""
    uid: int
    func: int
    dispatch: int
    issue: int
    complete: int
    broadcast: int
    aborted: bool
    pid: int = 0                 # owning process (multi-tenant accounting)

    @property
    def func_name(self) -> str:
        return FUNC_NAMES.get(self.func, f"acc_{self.func:x}")

    def astuple(self) -> tuple:
        return (self.uid, self.func, self.dispatch, self.issue,
                self.complete, self.broadcast, self.aborted, self.pid)


@dataclasses.dataclass(frozen=True)
class Result:
    """Uniform simulation outcome (either backend)."""
    program: str
    scheduler: str
    backend: str
    n_fu: tuple[int, ...]
    cycles: int
    halted: bool
    schedule: tuple[TaskRow, ...]
    spec_aborted: int
    stall_cycles: int
    fu_busy_cycles: tuple[int, ...]     # per existing unit, class-major order
    wall_us: float
    raw: Any = dataclasses.field(repr=False, compare=False, default=None)
    policy: Optional[SchedPolicy] = dataclasses.field(
        default=None, compare=False)    # arbitration policy this run used

    @property
    def n_tasks(self) -> int:
        return len(self.schedule)

    @property
    def utilization(self) -> float:
        """Mean busy fraction across the accelerator units that exist."""
        units = sum(self.n_fu)
        if units == 0 or self.cycles == 0:
            return 0.0
        return float(sum(self.fu_busy_cycles)) / (units * self.cycles)

    def speedup_vs(self, other: "Result") -> float:
        """How much faster this run is than ``other`` (>1 ⇒ faster)."""
        return other.cycles / self.cycles

    def schedule_tuple(self) -> list[tuple]:
        """Canonical rows, comparable across backends."""
        return [row.astuple() for row in self.schedule]

    # ------------------------------------------------- multi-tenant metrics
    @property
    def pids(self) -> tuple[int, ...]:
        """Process ids present in the schedule, ascending."""
        return tuple(sorted({row.pid for row in self.schedule}))

    def by_pid(self) -> dict[int, tuple[TaskRow, ...]]:
        """Per-process schedule slices (each app's rows, uid order)."""
        out: dict[int, list[TaskRow]] = {}
        for row in self.schedule:
            out.setdefault(row.pid, []).append(row)
        return {pid: tuple(rows) for pid, rows in sorted(out.items())}

    def schedule_for(self, pid: int) -> tuple[TaskRow, ...]:
        """The schedule rows owned by process ``pid``."""
        return tuple(row for row in self.schedule if row.pid == pid)

    def app_makespan(self, pid: int) -> int:
        """Completion cycle of ``pid``'s last non-aborted task (0 if none).

        The per-application makespan under sharing: how long *this tenant*
        waited, regardless of when the other tenants drained.
        """
        done = [row.complete for row in self.schedule
                if row.pid == pid and not row.aborted and row.complete >= 0]
        return max(done, default=0)

    def fairness(self, solo: "dict[int, Result]") -> "FairnessReport":
        """Slowdown of each tenant vs its solo run on the same pool.

        ``solo`` maps pid → the tenant's standalone :class:`Result`.
        Slowdown(pid) = shared app makespan / solo makespan (≥ ~1.0; large
        values mean the scheduler starves that tenant).  ``max_slowdown`` is
        the fairness figure of merit (Fusco et al. 2022 use the same metric
        for hardware-HEFT workloads).
        """
        slowdowns = {}
        for pid, solo_res in sorted(solo.items()):
            base = solo_res.app_makespan(pid) or solo_res.cycles
            shared = self.app_makespan(pid)
            slowdowns[pid] = shared / base if base else float("inf")
        pol = self.policy or SchedPolicy()
        return FairnessReport(
            slowdowns=slowdowns,
            max_slowdown=max(slowdowns.values(), default=0.0),
            mean_slowdown=(sum(slowdowns.values()) / len(slowdowns)
                           if slowdowns else 0.0),
            weights={pid: pol.weight_of(pid) for pid in slowdowns})

    def table(self) -> str:
        """Human-readable per-task schedule."""
        lines = [f"{self.program} · {self.scheduler} · {self.backend} · "
                 f"{self.cycles} cycles · utilization "
                 f"{self.utilization:.1%}",
                 f"{'uid':>4} {'pid':>3} {'function':<13} {'dispatch':>8} "
                 f"{'issue':>8} {'complete':>9} {'broadcast':>9}"]
        for t in self.schedule:
            flag = "  (aborted)" if t.aborted else ""
            lines.append(f"{t.uid:>4} {t.pid:>3} {t.func_name:<13} "
                         f"{t.dispatch:>8} {t.issue:>8} {t.complete:>9} "
                         f"{t.broadcast:>9}{flag}")
        return "\n".join(lines)


@dataclasses.dataclass(frozen=True)
class FairnessReport:
    """Per-tenant slowdown of a shared run vs each tenant's solo run.

    ``weights`` carries each pid's priority weight under the run's
    :class:`SchedPolicy` so slowdown-vs-priority is one report: a working
    priority scheduler shows high-weight pids near slowdown 1.0 while
    low-weight pids absorb the queueing delay (:meth:`by_weight`).
    """
    slowdowns: dict[int, float]         # pid → shared/solo makespan ratio
    max_slowdown: float                 # fairness figure of merit
    mean_slowdown: float
    weights: dict[int, int] = dataclasses.field(default_factory=dict)

    def by_weight(self) -> dict[int, float]:
        """Mean slowdown per priority weight (descending weight order)."""
        acc: dict[int, list[float]] = {}
        for pid, s in self.slowdowns.items():
            acc.setdefault(self.weights.get(pid, 0), []).append(s)
        return {w: sum(v) / len(v)
                for w, v in sorted(acc.items(), reverse=True)}

    def table(self) -> str:
        lines = [f"{'pid':>4} {'weight':>7} {'slowdown':>9}"]
        for pid, s in sorted(self.slowdowns.items()):
            lines.append(f"{pid:>4} {self.weights.get(pid, 0):>7} {s:>9.3f}")
        lines.append(f" max {'':>7} {self.max_slowdown:>9.3f}")
        return "\n".join(lines)


def _machine_rows(out: dict[str, Any]) -> tuple[TaskRow, ...]:
    return tuple(TaskRow(*row) for row in machine.schedule_tuple(out))


def _golden_rows(res: golden.Result) -> tuple[TaskRow, ...]:
    return tuple(TaskRow(*row) for row in res.schedule_tuple())


# ---------------------------------------------------------------------------
# run
# ---------------------------------------------------------------------------
def run(program, *, scheduler: Union[str, SchedulerCosts] = "hts_spec",
        n_fu: Union[int, Sequence[int]] = 2, backend: str = "jax",
        params: HtsParams = HtsParams(), event_skip: bool = True,
        max_cycles: int = 5_000_000, max_prog: int = 256,
        max_fu_per_class: int = 16, check: bool = True,
        policy: Optional[SchedPolicy] = None) -> Result:
    """Simulate ``program`` under one scheduler cost model.

    ``policy`` selects the RS arbitration (per-pid priority weights + FU
    quotas); when omitted, a policy attached to the program (e.g. by
    ``Program.merge(priorities=...)``) applies, then ``params.policy``.

    Raises :class:`SimulationError` (naming the program and scheduler) if the
    machine fails to drain within ``max_cycles`` — pass ``check=False`` to
    get the partial Result instead.
    """
    prep = _prepare(program)
    cost = _norm_costs(scheduler)
    fu = _norm_n_fu(n_fu)
    pol = _norm_policy(policy, prep, params)

    t0 = time.perf_counter()
    if backend == "jax":
        out = machine.simulate(prep.code, cost, params,
                               n_fu=np.asarray(fu, np.int32),
                               mem_init=prep.mem_init, effects=prep.effects,
                               event_skip=event_skip, max_cycles=max_cycles,
                               max_fu_per_class=max_fu_per_class,
                               max_prog=max_prog, policy=pol)
        wall = (time.perf_counter() - t0) * 1e6
        halted = bool(out["halted"]) and not bool(out["overflow"])
        # keep only units that exist under fu (class-major, like golden)
        busy = np.asarray(out["fu_busy_cycles"]).reshape(NUM_FUNCS,
                                                         max_fu_per_class)
        busy_exist = tuple(int(busy[c, u]) for c in range(NUM_FUNCS)
                           for u in range(fu[c]))
        result = Result(
            program=prep.name, scheduler=cost.name, backend=backend,
            n_fu=fu, cycles=int(out["cycles"]), halted=halted,
            schedule=_machine_rows(out),
            spec_aborted=int(out["spec_aborted"]),
            stall_cycles=int(out["stall_cycles"]),
            fu_busy_cycles=busy_exist, wall_us=wall, raw=out, policy=pol)
    elif backend == "golden":
        g = golden.run(prep.code, cost,
                       dataclasses.replace(params, n_fu=fu, policy=pol),
                       prep.mem_init, prep.effects, max_cycles=max_cycles)
        wall = (time.perf_counter() - t0) * 1e6
        result = Result(
            program=prep.name, scheduler=cost.name, backend=backend,
            n_fu=fu, cycles=int(g.cycles), halted=bool(g.halted),
            schedule=_golden_rows(g), spec_aborted=int(g.spec_aborted),
            stall_cycles=int(g.stall_cycles),
            fu_busy_cycles=tuple(int(x) for x in g.fu_busy_cycles),
            wall_us=wall, raw=g, policy=pol)
    else:
        raise ValueError(f'backend must be "jax" or "golden", got {backend!r}')

    if check and not result.halted:
        raise SimulationError(
            f"program {prep.name!r} under scheduler {cost.name!r} "
            f"(backend={backend}, n_fu={fu}) did not halt within "
            f"{max_cycles} cycles — livelock, structural overflow, or "
            "max_cycles too small")
    return result


# ---------------------------------------------------------------------------
# sweep
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Strong-scaling sweep: cycles[scheduler][i] for n_fu_list[i]."""
    program: str
    n_fu_list: tuple[tuple[int, ...], ...]
    schedulers: tuple[str, ...]
    cycles: dict[str, np.ndarray]
    wall_us: dict[str, float]           # total per scheduler (all FU points)

    def speedup(self, scheduler: str, baseline: str) -> np.ndarray:
        """Per-FU-point speedup of ``scheduler`` over ``baseline``."""
        return self.cycles[baseline] / self.cycles[scheduler]

    def table(self) -> str:
        head = "n_fu       " + " ".join(f"{s:>12}" for s in self.schedulers)
        lines = [f"{self.program} · strong scaling", head]
        for i, fu in enumerate(self.n_fu_list):
            k = fu[0] if len(set(fu)) == 1 else fu
            lines.append(f"{str(k):<10} " + " ".join(
                f"{int(self.cycles[s][i]):>12}" for s in self.schedulers))
        return "\n".join(lines)


@functools.lru_cache(maxsize=16)
def _vmapped(spec: machine.MachineSpec, max_prog: int):
    """One jitted machine per (spec, max_prog), FU axis vmapped (the
    policy tables ride along unbatched — they are traced runtime args)."""
    import jax
    return jax.jit(jax.vmap(machine.make_machine(spec, max_prog),
                            in_axes=(None, None, 0, None, None, None, None)))


def sweep(program, *, n_fu=(1, 2, 4), schedulers=("naive", "hts_spec"),
          params: HtsParams = HtsParams(), event_skip: bool = True,
          max_cycles: int = 50_000_000, max_prog: int = 64,
          max_fu_per_class: Optional[int] = None,
          policy: Optional[SchedPolicy] = None) -> SweepResult:
    """Simulate ``program`` across FU configurations in one compiled,
    ``vmap``-batched machine per scheduler (the Fig-10 machinery).

    ``n_fu`` is a sequence of points; each point is an int (uniform per
    class) or a per-class tuple.  ``schedulers`` accepts names from
    ``costs.ALL_SCHEDULERS`` or :class:`SchedulerCosts` objects.
    ``policy`` applies one :class:`SchedPolicy` to every FU point (it is
    runtime data to the compiled machine, so changing it never recompiles).
    """
    import jax.numpy as jnp

    prep = _prepare(program)
    points = tuple(_norm_n_fu(k) for k in n_fu)
    pol = _norm_policy(policy, prep, params)
    widest = max(max(p) for p in points)
    if max_fu_per_class is None:
        max_fu_per_class = max(16, widest)
    elif widest > max_fu_per_class:
        raise ValueError(f"n_fu point {widest} exceeds max_fu_per_class "
                         f"{max_fu_per_class}")

    ftab, p_len = machine.pack_program(prep.code, max_prog)
    mem, eff = machine.images(params, prep.mem_init, prep.effects)
    n_fu_arr = jnp.asarray(points, jnp.int32)
    prio = jnp.asarray(pol.weight_array(), jnp.int32)
    quota = jnp.asarray(pol.quota_array(), jnp.int32)
    # the policy is runtime data — keep it out of the compilation cache key
    params_c = dataclasses.replace(params, policy=SchedPolicy())

    cost_objs = [_norm_costs(s) for s in schedulers]
    cycles: dict[str, np.ndarray] = {}
    wall: dict[str, float] = {}
    for cost in cost_objs:
        spec = machine.MachineSpec(params=params_c, costs=cost,
                                   event_skip=event_skip,
                                   max_cycles=max_cycles,
                                   max_fu_per_class=max_fu_per_class)
        runner = _vmapped(spec, max_prog)
        t0 = time.perf_counter()
        out = runner(jnp.asarray(ftab), p_len, n_fu_arr,
                     jnp.asarray(mem), jnp.asarray(eff), prio, quota)
        cyc = np.asarray(out["cycles"])
        wall[cost.name] = (time.perf_counter() - t0) * 1e6
        ok = np.asarray(out["halted"]) & ~np.asarray(out["overflow"])
        if not ok.all():
            bad = [points[i] for i in np.nonzero(~ok)[0]]
            raise SimulationError(
                f"sweep of {prep.name!r} under {cost.name!r}: FU points "
                f"{bad} did not halt within {max_cycles} cycles")
        cycles[cost.name] = cyc
    return SweepResult(program=prep.name, n_fu_list=points,
                       schedulers=tuple(c.name for c in cost_objs),
                       cycles=cycles, wall_us=wall)


# ---------------------------------------------------------------------------
# compare: differential runner (golden vs machine, event-skip on and off)
# ---------------------------------------------------------------------------
class MismatchError(AssertionError):
    """Two backends produced different schedules for the same program."""


@dataclasses.dataclass(frozen=True)
class CompareReport:
    """Outcome of :func:`compare`: per-scheduler agreed-upon results.

    ``results[scheduler]`` is the golden-backend :class:`Result` (the oracle;
    the JAX machine runs — event-skip on *and* off — were verified
    schedule-identical to it).  ``n_modes`` counts the executions per
    scheduler (3: golden, jax+skip, jax-noskip).
    """
    program: str
    schedulers: tuple[str, ...]
    results: dict[str, Result]
    n_modes: int = 3

    def cycles(self, scheduler: str) -> int:
        return self.results[scheduler].cycles


def _first_diff(a: list[tuple], b: list[tuple]) -> str:
    if len(a) != len(b):
        return f"row counts differ: {len(a)} vs {len(b)}"
    for i, (ra, rb) in enumerate(zip(a, b)):
        if ra != rb:
            return f"first differing row {i}: {ra} vs {rb}"
    return "schedules equal"


def compare(program, *,
            schedulers: Sequence[Union[str, SchedulerCosts]] =
            ("naive", "hts_nospec", "hts_spec"),
            n_fu: Union[int, Sequence[int]] = 2,
            params: HtsParams = HtsParams(),
            max_cycles: int = 5_000_000, max_prog: int = 256,
            max_fu_per_class: Optional[int] = None,
            policy: Optional[SchedPolicy] = None) -> CompareReport:
    """Differential execution: golden oracle vs the compiled JAX machine with
    event-skip **on and off**, for every scheduler cost model.

    ``policy`` applies one :class:`SchedPolicy` to every execution (defaults
    to the program-attached policy, e.g. from ``Program.merge(priorities=
    ...)``) — so priority/quota arbitration is differentially verified by
    the same machinery as the baseline age-order arbiter.

    Raises :class:`MismatchError` (naming program, scheduler and mode) on the
    first schedule-tuple or cycle-count disagreement; returns a
    :class:`CompareReport` of the agreed results otherwise.  This is the
    fuzzing workhorse: any scheduling-semantics divergence between the two
    simulators — or between the event-skip fast path and the cycle-by-cycle
    reference — surfaces as a mismatch on some generated scenario.
    """
    prep = _prepare(program)
    fu = _norm_n_fu(n_fu)
    if max_fu_per_class is None:
        # size the compiled FU pool to the request: the no-event-skip runs
        # tick every cycle, and per-cycle cost scales with the pool width
        max_fu_per_class = max(4, max(fu))
    results: dict[str, Result] = {}
    names = []
    for scheduler in schedulers:
        cost = _norm_costs(scheduler)
        names.append(cost.name)
        g = run(prep, scheduler=cost, n_fu=fu, backend="golden",
                params=params, max_cycles=max_cycles, max_prog=max_prog,
                policy=policy)
        gold_rows = g.schedule_tuple()
        for event_skip in (True, False):
            m = run(prep, scheduler=cost, n_fu=fu, backend="jax",
                    params=params, event_skip=event_skip,
                    max_cycles=max_cycles, max_prog=max_prog,
                    max_fu_per_class=max_fu_per_class, policy=policy)
            mode = f"jax event_skip={'on' if event_skip else 'off'}"
            if m.cycles != g.cycles:
                raise MismatchError(
                    f"{prep.name!r} under {cost.name!r}: {mode} ran "
                    f"{m.cycles} cycles, golden ran {g.cycles}")
            if m.schedule_tuple() != gold_rows:
                raise MismatchError(
                    f"{prep.name!r} under {cost.name!r}: {mode} schedule "
                    f"differs from golden — "
                    f"{_first_diff(m.schedule_tuple(), gold_rows)}")
        results[cost.name] = g
    return CompareReport(program=prep.name, schedulers=tuple(names),
                         results=results)


__all__ = ["run", "sweep", "compare", "Result", "SweepResult", "TaskRow",
           "FairnessReport", "CompareReport", "MismatchError",
           "SimulationError", "SchedPolicy", "ALL_SCHEDULERS"]
