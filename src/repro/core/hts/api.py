"""Unified HTS simulation facade: ``hts.run``, ``hts.run_many``, ``hts.sweep``.

One entry point for every caller of the reproduction — benchmarks, examples
and tests no longer thread ``assembler.assemble → machine.simulate(...)`` /
``golden.run(...)`` by hand (each with a different signature):

    >>> from repro.core import hts
    >>> p = hts.Program("demo")
    >>> x = p.input(0x10, 4)
    >>> fft = p.task("fft_256", in_=x, out=4)
    >>> r = hts.run(p, scheduler="hts_spec", n_fu=2)
    >>> r.cycles, r.utilization, r.schedule[0].func_name
    >>> r.speedup_vs(hts.run(p, scheduler="naive", n_fu=2))

``run`` accepts a :class:`~repro.core.hts.builder.Program`, a built program,
a ``Bench``, raw assembly text, or a (P, 4) machine-code array, and executes
it on either backend:

* ``backend="jax"``    — the compiled ``lax.while_loop`` machine
  (:mod:`machine`), event-skip by default;
* ``backend="golden"`` — the pure-Python cycle-accurate oracle
  (:mod:`golden`).

Both return the same :class:`Result` with identical per-task schedule rows
(the two simulators are schedule-equivalence-tested).

The axes model
--------------
Every argument of the compiled machine is a runtime input, so batching is
a choice of ``vmap`` axes over its 11-argument signature (the 9th/10th
are the heterogeneous FU cost table and the eft-arbiter flag, the 11th
the per-tenant frontend stream table, ``frontend.py``).  Three named
axes compose (``_vmapped`` stacks them outermost-first):

* the **scenario** axis — everything batched: a *population* of programs,
  each with its own images, FU counts, policy tables and stream tables.
  ``run_many``
  drives it and returns a :class:`PopulationResult`; ``batch.py`` packs
  programs of one shape bucket into the common-shape arrays.
* the **n_fu** axis — only the FU configuration batched (the Fig-10
  strong-scaling machinery).  ``sweep`` drives it; handed a population it
  composes scenario × n_fu in one call.
* the **policy** axis — only the ``prio``/``quota``/``rs_cap`` tables
  batched (weights are runtime data, so policy sweeps never recompile).

One compilation is cached per ``(MachineSpec, max_prog, axes)`` — i.e. per
static shape bucket — no matter how many scenarios, FU points or policies
ride through it.

``compare`` is the differential runner: golden oracle vs the compiled
machine with event-skip on *and* off, per scheduler, schedule-tuple
equality asserted — the workhorse behind the seeded multi-tenant fuzzer
(``workloads.py`` / tests/test_hts_multitenant.py).  Handed a sequence of
programs it verifies a whole population: one vmapped machine batch per
(scheduler, event-skip mode), checked scenario-by-scenario against a
golden loop.

Multi-tenant metrics live on :class:`Result`: ``by_pid()`` /
``schedule_for`` slice the schedule by owning process, ``app_makespan``
is one tenant's finish cycle, and ``fairness`` reports per-tenant
slowdown vs solo runs (max slowdown = the fairness figure of merit),
annotated with each pid's priority weight (``FairnessReport.by_weight``
is the slowdown-vs-priority curve).

QoS scheduling: ``run``/``sweep``/``compare`` all take a
``policy=``:class:`~repro.core.hts.policy.SchedPolicy` (per-pid priority
weights + per-class FU quotas for the RS arbiter).  Resolution order:
explicit argument > policy attached to the program (e.g. by
``Program.merge(priorities=...)``) > ``params.policy``.  Policies are
runtime data to the compiled machine — sweeping them never recompiles.

See docs/API.md for a runnable tour of this module.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Optional, Sequence, Union

import numpy as np

from . import batch, golden, machine
from .batch import PackedPopulation
from .machine import STEP_IMPLS
from .costs import (ALL_SCHEDULERS, FUNC_NAMES, NUM_FUNCS, SchedulerCosts,
                    costs_by_name, fu_cost_tuple, norm_fu_cost)
from .frontend import StreamSet
from .golden import HtsParams
from .policy import SchedPolicy


class SimulationError(RuntimeError):
    """A simulation did not halt (hit ``max_cycles``) or overflowed."""


# program normalisation lives in batch.py (packing needs it too); the
# private names remain importable here for callers of the old layout.
_Prepared = batch.Prepared
_prepare = batch.prepare
_norm_n_fu = batch.norm_n_fu
_norm_policy = batch.norm_policy


def _norm_costs(scheduler) -> SchedulerCosts:
    return (costs_by_name(scheduler) if isinstance(scheduler, str)
            else scheduler)


def _is_population(program) -> bool:
    """A sequence of programs (or a packed batch) vs one program.

    Strings (assembly) and ndarrays (machine code) are single programs;
    lists/tuples of program-ish objects are populations.
    """
    return isinstance(program, (PackedPopulation, list, tuple))


# ---------------------------------------------------------------------------
# results
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class TaskRow:
    """One scheduled task: dispatch/issue/complete/broadcast cycles."""
    uid: int
    func: int
    dispatch: int
    issue: int
    complete: int
    broadcast: int
    aborted: bool
    pid: int = 0                 # owning process (multi-tenant accounting)

    @property
    def func_name(self) -> str:
        return FUNC_NAMES.get(self.func, f"acc_{self.func:x}")

    def astuple(self) -> tuple:
        return (self.uid, self.func, self.dispatch, self.issue,
                self.complete, self.broadcast, self.aborted, self.pid)


@dataclasses.dataclass(frozen=True)
class Result:
    """Uniform simulation outcome (either backend)."""
    program: str
    scheduler: str
    backend: str
    n_fu: tuple[int, ...]
    cycles: int
    halted: bool
    schedule: tuple[TaskRow, ...]
    spec_aborted: int
    stall_cycles: int
    fu_busy_cycles: tuple[int, ...]     # per existing unit, class-major order
    wall_us: float
    raw: Any = dataclasses.field(repr=False, compare=False, default=None)
    policy: Optional[SchedPolicy] = dataclasses.field(
        default=None, compare=False)    # arbitration policy this run used
    #: per-stream dispatch-stall cycles (one entry for merged-frontend runs)
    fe_stall: tuple[int, ...] = dataclasses.field(default=(), compare=False)
    #: the per-tenant frontends this run dispatched through (None = the
    #: historical one merged in-order stream)
    streams: Optional[StreamSet] = dataclasses.field(
        default=None, compare=False)

    @property
    def n_tasks(self) -> int:
        return len(self.schedule)

    @property
    def utilization(self) -> float:
        """Mean busy fraction across the accelerator units that exist."""
        units = sum(self.n_fu)
        if units == 0 or self.cycles == 0:
            return 0.0
        return float(sum(self.fu_busy_cycles)) / (units * self.cycles)

    def speedup_vs(self, other: "Result") -> float:
        """How much faster this run is than ``other`` (>1 ⇒ faster)."""
        return other.cycles / self.cycles

    def schedule_tuple(self) -> list[tuple]:
        """Canonical rows, comparable across backends."""
        return [row.astuple() for row in self.schedule]

    # ------------------------------------------------- multi-tenant metrics
    @property
    def pids(self) -> tuple[int, ...]:
        """Process ids present in the schedule, ascending."""
        return tuple(sorted({row.pid for row in self.schedule}))

    def by_pid(self) -> dict[int, tuple[TaskRow, ...]]:
        """Per-process schedule slices (each app's rows, uid order)."""
        out: dict[int, list[TaskRow]] = {}
        for row in self.schedule:
            out.setdefault(row.pid, []).append(row)
        return {pid: tuple(rows) for pid, rows in sorted(out.items())}

    def schedule_for(self, pid: int) -> tuple[TaskRow, ...]:
        """The schedule rows owned by process ``pid``."""
        return tuple(row for row in self.schedule if row.pid == pid)

    def app_makespan(self, pid: int) -> int:
        """Completion cycle of ``pid``'s last non-aborted task (0 if none).

        The per-application makespan under sharing: how long *this tenant*
        waited, regardless of when the other tenants drained.
        """
        done = [row.complete for row in self.schedule
                if row.pid == pid and not row.aborted and row.complete >= 0]
        return max(done, default=0)

    def fairness(self, solo: "dict[int, Result]") -> "FairnessReport":
        """Slowdown of each tenant vs its solo run on the same pool.

        ``solo`` maps pid → the tenant's standalone :class:`Result`.
        Slowdown(pid) = shared app makespan / solo makespan (≥ ~1.0; large
        values mean the scheduler starves that tenant).  ``max_slowdown`` is
        the fairness figure of merit (Fusco et al. 2022 use the same metric
        for hardware-HEFT workloads).
        """
        slowdowns = {}
        for pid, solo_res in sorted(solo.items()):
            base = solo_res.app_makespan(pid) or solo_res.cycles
            shared = self.app_makespan(pid)
            slowdowns[pid] = shared / base if base else float("inf")
        pol = self.policy or SchedPolicy()
        return FairnessReport(
            slowdowns=slowdowns,
            max_slowdown=max(slowdowns.values(), default=0.0),
            mean_slowdown=(sum(slowdowns.values()) / len(slowdowns)
                           if slowdowns else 0.0),
            weights={pid: pol.weight_of(pid) for pid in slowdowns},
            frontend=({pid: self.frontend_metrics(pid) for pid in slowdowns}
                      if self.streams is not None else {}))

    # ------------------------------------------------- frontend metrics
    def dispatch_stall_cycles(self, pid: Optional[int] = None):
        """Cycles a tenant's frontend stream had arrived and still held
        undispatched instructions but was not granted dispatch — the
        per-tenant head-of-line metric.  ``pid=None`` returns the per-pid
        dict; a merged-frontend run charges everything to its one stream
        (keyed by pid 0).
        """
        pids = (self.streams.pids if self.streams is not None
                else (0,) * len(self.fe_stall))
        if pid is None:
            out: dict[int, int] = {}
            for p, s in zip(pids, self.fe_stall):
                out[p] = out.get(p, 0) + int(s)
            return out
        return sum(int(s) for p, s in zip(pids, self.fe_stall) if p == pid)

    def time_to_first_issue(self, pid: int) -> Optional[int]:
        """Cycles from ``pid``'s stream arrival to its first task issue
        (``None`` if the tenant never issued) — how long a late tenant
        waited before the scheduler actually started serving it.
        """
        issues = [row.issue for row in self.schedule
                  if row.pid == pid and row.issue >= 0]
        if not issues:
            return None
        arrival = (self.streams.arrival_of(pid)
                   if self.streams is not None else 0)
        return min(issues) - arrival

    def rs_occupancy_at_dispatch(self, pid: int) -> float:
        """Mean count of ``pid``'s own reservation-station-resident tasks
        at each of its dispatches (including the new one) — how deeply a
        tenant's stream queued behind itself inside the shared window.
        """
        rows = [(r.dispatch, r.issue) for r in self.schedule
                if r.pid == pid and not r.aborted and r.dispatch >= 0]
        if not rows:
            return 0.0
        # resident at cycle d: dispatched by d, not yet issued (RS issue
        # precedes dispatch within a cycle, so the earliest issue is d+1)
        occ = [sum(1 for d2, i2 in rows if d2 <= d and (i2 < 0 or i2 > d))
               for d, _ in rows]
        return sum(occ) / len(occ)

    def frontend_metrics(self, pid: int) -> dict:
        """The per-tenant frontend triple (dispatch-stall cycles, RS
        occupancy at dispatch, time-to-first-issue) as one dict."""
        return {
            "dispatch_stall_cycles": self.dispatch_stall_cycles(pid),
            "rs_occupancy_at_dispatch": self.rs_occupancy_at_dispatch(pid),
            "time_to_first_issue": self.time_to_first_issue(pid),
        }

    def table(self) -> str:
        """Human-readable per-task schedule."""
        lines = [f"{self.program} · {self.scheduler} · {self.backend} · "
                 f"{self.cycles} cycles · utilization "
                 f"{self.utilization:.1%}",
                 f"{'uid':>4} {'pid':>3} {'function':<13} {'dispatch':>8} "
                 f"{'issue':>8} {'complete':>9} {'broadcast':>9}"]
        for t in self.schedule:
            flag = "  (aborted)" if t.aborted else ""
            lines.append(f"{t.uid:>4} {t.pid:>3} {t.func_name:<13} "
                         f"{t.dispatch:>8} {t.issue:>8} {t.complete:>9} "
                         f"{t.broadcast:>9}{flag}")
        return "\n".join(lines)


@dataclasses.dataclass(frozen=True)
class FairnessReport:
    """Per-tenant slowdown of a shared run vs each tenant's solo run.

    ``weights`` carries each pid's priority weight under the run's
    :class:`SchedPolicy` so slowdown-vs-priority is one report: a working
    priority scheduler shows high-weight pids near slowdown 1.0 while
    low-weight pids absorb the queueing delay (:meth:`by_weight`).
    """
    slowdowns: dict[int, float]         # pid → shared/solo makespan ratio
    max_slowdown: float                 # fairness figure of merit
    mean_slowdown: float
    weights: dict[int, int] = dataclasses.field(default_factory=dict)
    #: per-pid frontend metrics (``Result.frontend_metrics``) when the
    #: shared run dispatched through per-tenant frontends; {} otherwise
    frontend: dict[int, dict] = dataclasses.field(default_factory=dict)

    def by_weight(self) -> dict[int, float]:
        """Mean slowdown per priority weight (descending weight order)."""
        acc: dict[int, list[float]] = {}
        for pid, s in self.slowdowns.items():
            acc.setdefault(self.weights.get(pid, 0), []).append(s)
        return {w: sum(v) / len(v)
                for w, v in sorted(acc.items(), reverse=True)}

    def table(self) -> str:
        lines = [f"{'pid':>4} {'weight':>7} {'slowdown':>9}"]
        for pid, s in sorted(self.slowdowns.items()):
            lines.append(f"{pid:>4} {self.weights.get(pid, 0):>7} {s:>9.3f}")
        lines.append(f" max {'':>7} {self.max_slowdown:>9.3f}")
        return "\n".join(lines)


def scenarios_per_second(n: int, wall_us: float) -> float:
    """Throughput of a measured run: ``n`` scenarios over ``wall_us`` host
    microseconds (0.0 for an unmeasured/zero wall) — the one scenarios/sec
    formula every benchmark and report shares."""
    return float(n) / (wall_us * 1e-6) if wall_us else 0.0


def _machine_rows(out: dict[str, Any]) -> tuple[TaskRow, ...]:
    return tuple(TaskRow(*row) for row in machine.schedule_tuple(out))


def _golden_rows(res: golden.Result) -> tuple[TaskRow, ...]:
    return tuple(TaskRow(*row) for row in res.schedule_tuple())


def _machine_result(name: str, scheduler: str, fu: tuple[int, ...],
                    out: dict[str, Any], wall_us: float,
                    pol: SchedPolicy, max_fu_per_class: int,
                    streams: Optional[StreamSet] = None) -> Result:
    """A :class:`Result` from one machine output dict (single scenario)."""
    halted = bool(out["halted"]) and not bool(out["overflow"])
    # keep only units that exist under fu (class-major, like golden)
    busy = np.asarray(out["fu_busy_cycles"]).reshape(NUM_FUNCS,
                                                     max_fu_per_class)
    busy_exist = tuple(int(busy[c, u]) for c in range(NUM_FUNCS)
                       for u in range(fu[c]))
    n_streams = len(streams) if streams is not None else 1
    fe_stall = tuple(int(x) for x in
                     np.asarray(out["fe_stall"]).ravel()[:n_streams])
    return Result(
        program=name, scheduler=scheduler, backend="jax", n_fu=fu,
        cycles=int(out["cycles"]), halted=halted,
        schedule=_machine_rows(out), spec_aborted=int(out["spec_aborted"]),
        stall_cycles=int(out["stall_cycles"]), fu_busy_cycles=busy_exist,
        wall_us=wall_us, raw=out, policy=pol, fe_stall=fe_stall,
        streams=streams)


# ---------------------------------------------------------------------------
# run
# ---------------------------------------------------------------------------
def run(program, *, scheduler: Union[str, SchedulerCosts] = "hts_spec",
        n_fu: Union[int, Sequence[int]] = 2, backend: str = "jax",
        params: HtsParams = HtsParams(), event_skip: bool = True,
        max_cycles: int = 5_000_000, max_prog: int = 256,
        max_fu_per_class: int = 16, check: bool = True,
        policy: Optional[SchedPolicy] = None, fu_cost=None,
        step_impl: str = "xla") -> Result:
    """Simulate ``program`` under one scheduler cost model.

    ``policy`` selects the RS arbitration (per-pid priority weights + FU
    quotas); when omitted, a policy attached to the program (e.g. by
    ``Program.merge(priorities=...)``) applies, then ``params.policy``.

    ``fu_cost`` gives FU instances heterogeneous latency: any form
    :func:`~repro.core.hts.costs.norm_fu_cost` accepts (a
    ``{class: row_or_scalar}`` mapping or full per-class table of integer
    multipliers — unit ``u`` of class ``c`` executes in
    ``FUNC_CYCLES[c] * fu_cost[c, u]`` cycles).  Resolution: explicit
    argument > ``params.fu_cost``.  Cost tables are runtime data to the
    compiled machine — sweeping them never recompiles.

    Raises :class:`SimulationError` (naming the program and scheduler) if the
    machine fails to drain within ``max_cycles`` — pass ``check=False`` to
    get the partial Result instead.
    """
    prep = _prepare(program)
    cost = _norm_costs(scheduler)
    fu = _norm_n_fu(n_fu)
    pol = _norm_policy(policy, prep, params)
    # per-tenant frontends: the stream table is runtime data, with the
    # frontend arbitration weights resolved from the effective policy
    stream_tab = (prep.streams.table(pol) if prep.streams is not None
                  else None)
    eff_cost = fu_cost if fu_cost is not None else params.fu_cost

    t0 = time.perf_counter()
    if backend == "jax":
        out = machine.simulate(prep.code, cost, params,
                               n_fu=np.asarray(fu, np.int32),
                               mem_init=prep.mem_init, effects=prep.effects,
                               event_skip=event_skip, max_cycles=max_cycles,
                               max_fu_per_class=max_fu_per_class,
                               max_prog=max_prog, policy=pol,
                               fu_cost=eff_cost, streams=stream_tab,
                               step_impl=step_impl)
        wall = (time.perf_counter() - t0) * 1e6
        result = _machine_result(prep.name, cost.name, fu, out, wall, pol,
                                 max_fu_per_class, prep.streams)
    elif backend == "golden":
        g = golden.run(prep.code, cost,
                       dataclasses.replace(params, n_fu=fu, policy=pol,
                                           fu_cost=fu_cost_tuple(eff_cost)),
                       prep.mem_init, prep.effects, max_cycles=max_cycles,
                       streams=stream_tab)
        wall = (time.perf_counter() - t0) * 1e6
        result = Result(
            program=prep.name, scheduler=cost.name, backend=backend,
            n_fu=fu, cycles=int(g.cycles), halted=bool(g.halted),
            schedule=_golden_rows(g), spec_aborted=int(g.spec_aborted),
            stall_cycles=int(g.stall_cycles),
            fu_busy_cycles=tuple(int(x) for x in g.fu_busy_cycles),
            wall_us=wall, raw=g, policy=pol,
            fe_stall=tuple(int(x) for x in g.fe_stall),
            streams=prep.streams)
    else:
        raise ValueError(f'backend must be "jax" or "golden", got {backend!r}')

    if check and not result.halted:
        raise SimulationError(
            f"program {prep.name!r} under scheduler {cost.name!r} "
            f"(backend={backend}, n_fu={fu}) did not halt within "
            f"{max_cycles} cycles — livelock, structural overflow, or "
            "max_cycles too small")
    return result


# ---------------------------------------------------------------------------
# run_many: the scenario axis — a population in one vmapped machine call
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True, eq=False)
class PopulationResult:
    """Stacked outcome of one batched population run.

    Array fields are scenario-major (``cycles[i]`` is scenario ``i``);
    ``self[i]`` materialises scenario ``i`` as an ordinary :class:`Result`
    (slicing the stacked trace arrays), so every per-scenario metric —
    ``schedule``, ``by_pid``, ``app_makespan``, ``fairness`` — works
    unchanged on population runs.
    """
    scheduler: str
    backend: str
    names: tuple[str, ...]
    n_fu: np.ndarray                   # (N, NUM_FUNCS)
    cycles: np.ndarray                 # (N,)
    halted: np.ndarray                 # (N,) bool (and not overflowed)
    wall_us: float                     # the one batched call, all scenarios
    max_fu_per_class: int
    policies: tuple[SchedPolicy, ...]
    raw: Any = dataclasses.field(repr=False, default=None)
    _results: Optional[tuple] = dataclasses.field(repr=False, default=None)
    #: per-scenario frontend stream sets (None entries = merged frontend)
    stream_sets: tuple = ()
    # the compiled-machine identity of this run (spec + shape bucket +
    # machine args), stashed by ``run_many`` so :meth:`trip_cost_us` can
    # re-enter the same compile bucket; None on the golden backend
    _spec: Any = dataclasses.field(repr=False, default=None)
    _max_prog: Optional[int] = dataclasses.field(repr=False, default=None)
    _margs: Any = dataclasses.field(repr=False, default=None)

    def __len__(self) -> int:
        return len(self.names)

    def __getitem__(self, i: int) -> Result:
        if self._results is not None:           # golden loop backend
            return self._results[i]
        out = {k: v[i] for k, v in self.raw.items()}
        fu = tuple(int(x) for x in self.n_fu[i])
        return _machine_result(self.names[i], self.scheduler, fu, out,
                               self.wall_us / max(len(self), 1),
                               self.policies[i], self.max_fu_per_class,
                               (self.stream_sets[i] if self.stream_sets
                                else None))

    def __iter__(self):
        return (self[i] for i in range(len(self)))

    @property
    def all_halted(self) -> bool:
        return bool(np.asarray(self.halted).all())

    @property
    def steps(self) -> Optional[np.ndarray]:
        """Per-scenario while-loop step counts — the *measured* batching
        cost of each lane (a batch runs as long as its slowest lane's
        step count).  Feed this to ``batch.plan_chunks(profile=...)`` to
        re-chunk a long sweep from real costs instead of the
        instruction-count proxy.  ``None`` on the golden backend (the
        oracle has no step counter)."""
        if self.raw is None or "steps" not in self.raw:
            return None
        return np.asarray(self.raw["steps"])

    def scenarios_per_second(self, wall_us: Optional[float] = None) -> float:
        """Batched throughput (scenarios per host second).  ``wall_us``
        overrides this call's own wall — benchmarks pass their measured
        median so one formula serves every reported number."""
        return scenarios_per_second(
            len(self), self.wall_us if wall_us is None else wall_us)

    def trip_cost_us(self, budget: int = 128, reps: int = 5) -> float:
        """Median wall-clock per while-loop trip of this population's
        compiled machine (microseconds).

        The measurement re-enters the run's own compile bucket through
        the *resumable* machine: a fresh carry is advanced by exactly
        ``budget`` steps per lane (`run_slice` with a fixed step budget,
        ``block_until_ready`` around each call), ``reps`` times after one
        untimed warm-up, and the median wall divides by the trips the
        slice actually executed.  Because every lane runs the same
        budget from a fresh carry, trips = ``budget`` until a lane halts
        earlier — the returned figure is the population step body's
        per-trip cost at this lane width, the number
        ``benchmarks/stepwidth.py`` sweeps.  Requires the jax backend
        (raises on golden results).
        """
        import jax
        import jax.numpy as jnp
        if self._spec is None:
            raise ValueError("trip_cost_us requires a jax-backend "
                             "population run")
        rm = _population_slicer(self._spec, self._max_prog)
        args = [jnp.asarray(a) for a in self._margs]
        b = jnp.asarray(budget, jnp.int32)
        carry0 = jax.block_until_ready(rm.init(*args))
        jax.block_until_ready(rm.run_slice(carry0, *args, b))  # warm-up
        walls = []
        for _ in range(reps):
            t0 = time.perf_counter()
            out = jax.block_until_ready(rm.run_slice(carry0, *args, b))
            walls.append((time.perf_counter() - t0) * 1e6)
        trips = int(np.max(np.asarray(out["steps"])))
        return float(np.median(walls)) / max(trips, 1)

    def scenarios_per_sec(self) -> float:
        """Batched throughput of this call (scenarios per host second)."""
        return self.scenarios_per_second()

    def table(self) -> str:
        lines = [f"population · {self.scheduler} · {self.backend} · "
                 f"{len(self)} scenarios · {self.wall_us:.0f} us",
                 f"{'scenario':<28} {'cycles':>10} {'halted':>7}"]
        for i, nm in enumerate(self.names):
            lines.append(f"{nm:<28} {int(self.cycles[i]):>10} "
                         f"{str(bool(self.halted[i])):>7}")
        return "\n".join(lines)


def run_many(programs, *,
             scheduler: Union[str, SchedulerCosts] = "hts_spec",
             n_fu: Union[int, Sequence] = 2, backend: str = "jax",
             params: HtsParams = HtsParams(), event_skip: bool = True,
             max_cycles: int = 5_000_000, max_prog: Optional[int] = None,
             max_fu_per_class: Optional[int] = None,
             policy=None, check: bool = True,
             devices: Optional[int] = None, fu_cost=None,
             step_impl: str = "xla") -> PopulationResult:
    """Simulate a population of programs as **one vmapped machine call**.

    ``programs`` is a sequence of anything :func:`run` accepts (or an
    already-packed :class:`~repro.core.hts.batch.PackedPopulation`, in
    which case ``n_fu``/``policy``/``max_prog``/``fu_cost`` come from the
    pack).  ``n_fu``, ``policy`` and ``fu_cost`` accept either one shared
    value or one entry per scenario — they are per-scenario arrays on the
    scenario axis (heterogeneous cost tables ride the same vmap axis as
    FU counts, so a cost sweep shares one compilation).

    One compilation serves every population of the same shape bucket
    (``batch.prog_bucket``); the batched call's wall-clock is the whole
    population's, which is what ``benchmarks/population.py`` measures
    against a Python loop of :func:`run`.

    ``devices=N`` shards the scenario axis across N devices
    (:mod:`~repro.core.hts.shard`): lanes are padded to a multiple of N
    (pad results dropped), each device runs the population machine's
    while loop on its own shard, and the results are lane-for-lane
    identical to the single-device path (``devices=None``, the default,
    which skips ``shard_map`` entirely; ``devices=1`` exercises the
    sharded code path on one device).  JAX backend only.

    ``backend="golden"`` runs the pure-Python oracle in a loop instead —
    same :class:`PopulationResult` surface, no batching (the differential
    baseline).

    ``step_impl`` selects the step-body lowering
    (:data:`~repro.core.hts.machine.STEP_IMPLS`): the restructured XLA
    form (default), the pre-restructure baseline, or the fused pallas
    kernels — all bit-identical, differentially pinned.  It is part of
    the compile key; the default value keeps the default path in the
    pre-existing compile bucket.
    """
    import jax
    import jax.numpy as jnp

    pop = (programs if isinstance(programs, PackedPopulation)
           else batch.pack_population(programs, params=params, n_fu=n_fu,
                                      policy=policy, fu_cost=fu_cost,
                                      max_prog=max_prog))
    cost = _norm_costs(scheduler)

    if devices is not None and backend != "jax":
        raise ValueError(f'devices= requires backend="jax", got {backend!r}')
    if backend == "golden":
        t0 = time.perf_counter()
        results = tuple(
            run(prep, scheduler=cost, n_fu=tuple(int(x) for x in pop.n_fu[i]),
                backend="golden", params=pop.params, max_cycles=max_cycles,
                policy=pop.policies[i], fu_cost=pop.fu_cost[i], check=check)
            for i, prep in enumerate(pop.preps))
        wall = (time.perf_counter() - t0) * 1e6
        return PopulationResult(
            scheduler=cost.name, backend="golden", names=pop.names,
            n_fu=pop.n_fu, cycles=np.asarray([r.cycles for r in results]),
            halted=np.asarray([r.halted for r in results]), wall_us=wall,
            max_fu_per_class=pop.widest_fu, policies=pop.policies,
            _results=results,
            stream_sets=tuple(p.streams for p in pop.preps))
    if backend != "jax":
        raise ValueError(f'backend must be "jax" or "golden", got {backend!r}')

    widest = pop.widest_fu
    if max_fu_per_class is None:
        # favour narrow compiled pools: population batches multiply every
        # per-unit state array by N scenarios
        max_fu_per_class = max(4, widest)
    elif widest > max_fu_per_class:
        raise ValueError(f"population n_fu {widest} exceeds "
                         f"max_fu_per_class {max_fu_per_class}")

    spec = machine.MachineSpec(params=pop.params, costs=cost,
                               event_skip=event_skip, max_cycles=max_cycles,
                               max_fu_per_class=max_fu_per_class,
                               step_impl=step_impl)
    runner = _runner_for(spec, pop.max_prog, devices)
    if devices is not None:
        from . import shard
        run_pop = shard.pad_lanes(pop, devices)
    else:
        run_pop = pop
    t0 = time.perf_counter()
    out = runner(*(jnp.asarray(a) for a in run_pop.machine_args()))
    out = jax.tree.map(np.asarray, out)      # forces device completion
    wall = (time.perf_counter() - t0) * 1e6
    if len(run_pop) > len(pop):              # drop the shard-padding lanes
        out = {k: v[:len(pop)] for k, v in out.items()}

    halted = out["halted"] & ~out["overflow"]
    result = PopulationResult(
        scheduler=cost.name, backend="jax", names=pop.names, n_fu=pop.n_fu,
        cycles=out["cycles"], halted=halted, wall_us=wall,
        max_fu_per_class=max_fu_per_class, policies=pop.policies, raw=out,
        stream_sets=tuple(p.streams for p in pop.preps),
        _spec=spec, _max_prog=pop.max_prog,
        _margs=tuple(run_pop.machine_args()))
    if check and not result.all_halted:
        bad = [pop.names[i] for i in np.nonzero(~halted)[0]]
        raise SimulationError(
            f"population run under scheduler {cost.name!r}: scenarios "
            f"{bad} did not halt within {max_cycles} cycles — livelock, "
            "structural overflow, or max_cycles too small")
    return result


# ---------------------------------------------------------------------------
# sweep
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Strong-scaling sweep: ``cycles[scheduler][i]`` for ``n_fu_list[i]``.

    Population sweeps (``sweep`` over a sequence of programs) stack one
    more leading axis: ``cycles[scheduler][s, i]`` is scenario ``s``
    (named ``programs[s]``) at FU point ``i``.
    """
    program: str
    n_fu_list: tuple[tuple[int, ...], ...]
    schedulers: tuple[str, ...]
    cycles: dict[str, np.ndarray]
    wall_us: dict[str, float]           # total per scheduler (all FU points)
    programs: tuple[str, ...] = ()      # per-scenario names (population mode)

    @property
    def is_population(self) -> bool:
        return bool(self.programs)

    def speedup(self, scheduler: str, baseline: str) -> np.ndarray:
        """Per-point speedup of ``scheduler`` over ``baseline`` (same shape
        as ``cycles[...]`` — per (scenario, FU point) in population mode)."""
        return self.cycles[baseline] / self.cycles[scheduler]

    def table(self) -> str:
        head = "n_fu       " + " ".join(f"{s:>12}" for s in self.schedulers)
        lines = [f"{self.program} · strong scaling", head]
        for i, fu in enumerate(self.n_fu_list):
            k = fu[0] if len(set(fu)) == 1 else fu
            if self.is_population:      # summarise the scenario axis
                cells = [f"{float(self.cycles[s][:, i].mean()):>12.0f}"
                         for s in self.schedulers]
                lines.append(f"{str(k):<10} " + " ".join(cells))
            else:
                lines.append(f"{str(k):<10} " + " ".join(
                    f"{int(self.cycles[s][i]):>12}"
                    for s in self.schedulers))
        if self.is_population:
            lines.append(f"({len(self.programs)} scenarios; cells are "
                         "scenario means)")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# the axes model: named vmap axes over the machine's 11-argument signature
# (ftab, p_len, n_fu, mem, eff, prio, quota, rs_cap, fu_cost, eft, streams)
# — see module docstring
# ---------------------------------------------------------------------------
SCENARIO_AXIS = (0,) * 11                             # a population, batched
SCENARIO_SHARED_FU_AXIS = (0, 0, None, 0, 0, 0, 0, 0, 0, 0, 0)  # pop × FU
N_FU_AXIS = (None, None, 0) + (None,) * 8            # Fig-10 FU scaling
POLICY_AXIS = (None, None, None, None, None, 0, 0, 0,
               None, 0, None)                        # policy sweep (incl. eft)


@functools.lru_cache(maxsize=32)
def _vmapped(spec: machine.MachineSpec, max_prog: int,
             axes: tuple = (N_FU_AXIS,)):
    """One jitted machine per ``(spec, max_prog, axes)`` static-shape bucket.

    ``axes`` is a stack of in_axes tuples, outermost first — e.g.
    ``(SCENARIO_SHARED_FU_AXIS, N_FU_AXIS)`` maps scenario-major over an
    inner FU grid.  Axes that stay ``None`` everywhere (like the policy
    tables in a plain FU sweep) still ride along as traced runtime data,
    so re-running with different policies never recompiles.
    """
    import jax
    fn = machine.make_machine(spec, max_prog)
    for in_axes in reversed(axes):
        fn = jax.vmap(fn, in_axes=in_axes)
    return jax.jit(fn)


@functools.lru_cache(maxsize=32)
def _population_runner(spec: machine.MachineSpec, max_prog: int):
    """The native scenario-axis machine (``machine.make_machine(...,
    population=True)``): one while loop for the whole batch, no per-lane
    carry select — strictly faster than ``_vmapped`` with SCENARIO_AXIS."""
    import jax
    return jax.jit(machine.make_machine(spec, max_prog, population=True))


def _runner_for(spec: machine.MachineSpec, max_prog: int,
                devices: Optional[int] = None):
    """The compiled population runner for one ``(spec, bucket, devices)``
    key — single-device native machine, or the ``shard_map``-sharded one.
    Both are module-cached, so the returned callable is the *same object*
    for every batch of the bucket; the serving engine (``serve.py``)
    leans on that for its recompilation accounting."""
    if devices is None:
        return _population_runner(spec, max_prog)
    from . import shard
    return shard.sharded_runner(spec, max_prog, devices)


@functools.lru_cache(maxsize=32)
def _population_slicer(spec: machine.MachineSpec, max_prog: int):
    """The resumable population machine for one ``(spec, bucket)``:
    ``init`` and ``run_slice`` jitted (``budget`` traced — slice-size
    sweeps never recompile), ``collect`` left as the plain host-friendly
    dict mapping (it also works row-wise on numpy snapshots of the
    carry, which is how ``serve.py`` harvests individual lanes)."""
    import jax
    rm = machine.make_machine(spec, max_prog, population=True,
                              resumable=True)
    return machine.ResumableMachine(init=jax.jit(rm.init),
                                    run_slice=jax.jit(rm.run_slice),
                                    collect=rm.collect)


def _slicer_for(spec: machine.MachineSpec, max_prog: int,
                devices: Optional[int] = None) -> machine.ResumableMachine:
    """The cached :class:`~repro.core.hts.machine.ResumableMachine` for a
    ``(spec, bucket, devices)`` key — the slice-and-refill counterpart of
    :func:`_runner_for` (same bucket discipline, same module-level
    caching, so serve's recompilation accounting covers it too)."""
    if devices is None:
        return _population_slicer(spec, max_prog)
    from . import shard
    return shard.sharded_slicer(spec, max_prog, devices)


def sweep(program, *, n_fu=(1, 2, 4), schedulers=("naive", "hts_spec"),
          params: HtsParams = HtsParams(), event_skip: bool = True,
          max_cycles: int = 50_000_000, max_prog: Optional[int] = None,
          max_fu_per_class: Optional[int] = None,
          policy: Optional[SchedPolicy] = None, fu_cost=None) -> SweepResult:
    """Simulate ``program`` across FU configurations in one compiled,
    ``vmap``-batched machine per scheduler (the Fig-10 machinery).

    ``n_fu`` is a sequence of points; each point is an int (uniform per
    class) or a per-class tuple.  ``schedulers`` accepts names from
    ``costs.ALL_SCHEDULERS`` or :class:`SchedulerCosts` objects.
    ``policy`` applies one :class:`SchedPolicy` to every FU point (it is
    runtime data to the compiled machine, so changing it never recompiles);
    ``fu_cost`` likewise applies one heterogeneous cost table to every
    point — also runtime data, so a design-space explorer can sweep cost
    tables and FU mixes through one compilation.

    **Population mode**: handed a sequence of programs (or a
    :class:`~repro.core.hts.batch.PackedPopulation`), the scenario axis
    composes with the FU axis — one compiled machine evaluates the whole
    scenario × FU grid, and ``cycles[scheduler]`` has shape
    ``(n_scenarios, n_points)``.
    """
    import jax.numpy as jnp

    points = tuple(_norm_n_fu(k) for k in n_fu)
    widest = max(max(p) for p in points)
    n_fu_arr = jnp.asarray(points, jnp.int32)

    if _is_population(program):
        pop = (program if isinstance(program, PackedPopulation)
               else batch.pack_population(program, params=params,
                                          policy=policy, fu_cost=fu_cost,
                                          max_prog=max_prog))
        name = f"<population of {len(pop)}>"
        # per-scenario n_fu from the pack is overridden by the swept axis;
        # everything else (images, policies) is per-scenario
        args = [jnp.asarray(a) for a in pop.machine_args()]
        args[2] = n_fu_arr
        axes: tuple = (SCENARIO_SHARED_FU_AXIS, N_FU_AXIS)
        run_prog = pop.max_prog
        params_c = pop.params
        point_names = [f"{pop.names[s]} @ {points[i]}"
                       for s in range(len(pop)) for i in range(len(points))]
    else:
        prep = _prepare(program)
        pol = _norm_policy(policy, prep, params)
        name = prep.name
        run_prog = 64 if max_prog is None else max_prog
        ftab, p_len = machine.pack_program(prep.code, run_prog)
        mem, eff = machine.images(params, prep.mem_init, prep.effects)
        stream_tab = (prep.streams.table(pol) if prep.streams is not None
                      else batch.StreamSet.single(p_len).table())
        eff_cost = fu_cost if fu_cost is not None else params.fu_cost
        args = [jnp.asarray(ftab), jnp.asarray(p_len, jnp.int32), n_fu_arr,
                jnp.asarray(mem), jnp.asarray(eff),
                jnp.asarray(pol.weight_array(), jnp.int32),
                jnp.asarray(pol.quota_array(), jnp.int32),
                jnp.asarray(pol.rs_cap_array(), jnp.int32),
                jnp.asarray(norm_fu_cost(eff_cost), jnp.int32),
                jnp.asarray(1 if pol.issue_mode == "eft" else 0, jnp.int32),
                jnp.asarray(stream_tab, jnp.int32)]
        axes = (N_FU_AXIS,)
        # policy + cost tables are runtime data — keep them out of the
        # compilation key
        params_c = dataclasses.replace(params, policy=SchedPolicy(),
                                       fu_cost=None)
        point_names = [f"{name} @ {p}" for p in points]

    if max_fu_per_class is None:
        max_fu_per_class = max(16, widest)
    elif widest > max_fu_per_class:
        raise ValueError(f"n_fu point {widest} exceeds max_fu_per_class "
                         f"{max_fu_per_class}")

    cost_objs = [_norm_costs(s) for s in schedulers]
    cycles: dict[str, np.ndarray] = {}
    wall: dict[str, float] = {}
    for cost in cost_objs:
        spec = machine.MachineSpec(params=params_c, costs=cost,
                                   event_skip=event_skip,
                                   max_cycles=max_cycles,
                                   max_fu_per_class=max_fu_per_class)
        runner = _vmapped(spec, run_prog, axes)
        t0 = time.perf_counter()
        out = runner(*args)
        cyc = np.asarray(out["cycles"])
        wall[cost.name] = (time.perf_counter() - t0) * 1e6
        ok = np.asarray(out["halted"]) & ~np.asarray(out["overflow"])
        if not ok.all():
            bad = [point_names[i] for i in np.nonzero(~ok.ravel())[0]]
            raise SimulationError(
                f"sweep of {name!r} under {cost.name!r}: points "
                f"{bad} did not halt within {max_cycles} cycles")
        cycles[cost.name] = cyc
    return SweepResult(program=name, n_fu_list=points,
                       schedulers=tuple(c.name for c in cost_objs),
                       cycles=cycles, wall_us=wall,
                       programs=(pop.names if _is_population(program)
                                 else ()))


# ---------------------------------------------------------------------------
# compare: differential runner (golden vs machine, event-skip on and off)
# ---------------------------------------------------------------------------
class MismatchError(AssertionError):
    """Two backends produced different schedules for the same program."""


@dataclasses.dataclass(frozen=True)
class CompareReport:
    """Outcome of :func:`compare`: per-scheduler agreed-upon results.

    ``results[scheduler]`` is the golden-backend :class:`Result` (the oracle;
    the JAX machine runs — event-skip on *and* off — were verified
    schedule-identical to it).  ``n_modes`` counts the executions per
    scheduler (3: golden, jax+skip, jax-noskip).
    """
    program: str
    schedulers: tuple[str, ...]
    results: dict[str, Result]
    n_modes: int = 3

    def cycles(self, scheduler: str) -> int:
        return self.results[scheduler].cycles


def _first_diff(a: list[tuple], b: list[tuple]) -> str:
    if len(a) != len(b):
        return f"row counts differ: {len(a)} vs {len(b)}"
    for i, (ra, rb) in enumerate(zip(a, b)):
        if ra != rb:
            return f"first differing row {i}: {ra} vs {rb}"
    return "schedules equal"


@dataclasses.dataclass(frozen=True, eq=False)
class PopulationCompareReport:
    """Outcome of a population :func:`compare`: every scenario agreed.

    For each scheduler, the whole population ran as one vmapped machine
    batch per event-skip mode and was checked scenario-by-scenario against
    a golden loop; ``cycles[scheduler]`` holds the agreed per-scenario
    cycle counts.
    """
    names: tuple[str, ...]
    schedulers: tuple[str, ...]
    cycles: dict[str, np.ndarray]       # scheduler -> (N,) agreed cycles
    n_modes: int = 3

    def __len__(self) -> int:
        return len(self.names)


def compare_population(programs, *,
                       schedulers: Sequence[Union[str, SchedulerCosts]] =
                       ("naive", "hts_nospec", "hts_spec"),
                       n_fu: Union[int, Sequence] = 2,
                       params: HtsParams = HtsParams(),
                       max_cycles: int = 5_000_000,
                       max_prog: Optional[int] = None,
                       max_fu_per_class: Optional[int] = None,
                       policy=None, fu_cost=None,
                       devices: Optional[int] = None,
                       step_impl: str = "xla") -> PopulationCompareReport:
    """Differential verification of a whole population: one vmapped machine
    batch per (scheduler, event-skip mode), checked scenario-by-scenario
    against a golden loop.  Raises :class:`MismatchError` naming the
    scenario, scheduler and mode on the first divergence.

    ``devices=N`` routes the *machine-side* runs through the sharded
    ``shard_map`` path (the golden loop stays host-side and unsharded),
    so device sharding is differentially verified lane-for-lane by the
    same oracle as everything else.
    """
    pop = (programs if isinstance(programs, PackedPopulation)
           else batch.pack_population(programs, params=params, n_fu=n_fu,
                                      policy=policy, fu_cost=fu_cost,
                                      max_prog=max_prog))
    if max_fu_per_class is None:
        max_fu_per_class = max(4, pop.widest_fu)
    cycles: dict[str, np.ndarray] = {}
    names = []
    for scheduler in schedulers:
        cost = _norm_costs(scheduler)
        names.append(cost.name)
        gold = run_many(pop, scheduler=cost, backend="golden",
                        max_cycles=max_cycles)
        gold_rows = [g.schedule_tuple() for g in gold]
        for event_skip in (True, False):
            m = run_many(pop, scheduler=cost, event_skip=event_skip,
                         max_cycles=max_cycles,
                         max_fu_per_class=max_fu_per_class, devices=devices,
                         step_impl=step_impl)
            mode = f"jax event_skip={'on' if event_skip else 'off'}"
            for i in range(len(pop)):
                if int(m.cycles[i]) != int(gold.cycles[i]):
                    raise MismatchError(
                        f"scenario {i} ({pop.names[i]!r}) under "
                        f"{cost.name!r}: {mode} ran {int(m.cycles[i])} "
                        f"cycles, golden ran {int(gold.cycles[i])}")
                mi = m[i].schedule_tuple()
                if mi != gold_rows[i]:
                    raise MismatchError(
                        f"scenario {i} ({pop.names[i]!r}) under "
                        f"{cost.name!r}: {mode} schedule differs from "
                        f"golden — {_first_diff(mi, gold_rows[i])}")
        cycles[cost.name] = np.asarray(gold.cycles)
    return PopulationCompareReport(names=pop.names,
                                   schedulers=tuple(names), cycles=cycles)


def compare(program, *,
            schedulers: Sequence[Union[str, SchedulerCosts]] =
            ("naive", "hts_nospec", "hts_spec"),
            n_fu: Union[int, Sequence[int]] = 2,
            params: HtsParams = HtsParams(),
            max_cycles: int = 5_000_000, max_prog: Optional[int] = None,
            max_fu_per_class: Optional[int] = None,
            policy: Optional[SchedPolicy] = None, fu_cost=None,
            step_impl: str = "xla"):
    """Differential execution: golden oracle vs the compiled JAX machine with
    event-skip **on and off**, for every scheduler cost model.

    ``fu_cost`` threads a heterogeneous per-(class, unit) cost table through
    every execution, so heterogeneous latency and the ``eft`` arbiter are
    differentially verified by the same machinery as everything else.

    ``policy`` applies one :class:`SchedPolicy` to every execution (defaults
    to the program-attached policy, e.g. from ``Program.merge(priorities=
    ...)``) — so priority/quota arbitration is differentially verified by
    the same machinery as the baseline age-order arbiter.

    Raises :class:`MismatchError` (naming program, scheduler and mode) on the
    first schedule-tuple or cycle-count disagreement; returns a
    :class:`CompareReport` of the agreed results otherwise.  This is the
    fuzzing workhorse: any scheduling-semantics divergence between the two
    simulators — or between the event-skip fast path and the cycle-by-cycle
    reference — surfaces as a mismatch on some generated scenario.

    **Population mode**: handed a sequence of programs (or a
    :class:`~repro.core.hts.batch.PackedPopulation`), delegates to
    :func:`compare_population` — the machine side then runs as one vmapped
    batch per mode and a :class:`PopulationCompareReport` is returned.
    """
    if _is_population(program):
        return compare_population(
            program, schedulers=schedulers, n_fu=n_fu, params=params,
            max_cycles=max_cycles, max_prog=max_prog,
            max_fu_per_class=max_fu_per_class, policy=policy,
            fu_cost=fu_cost, step_impl=step_impl)
    prep = _prepare(program)
    if max_prog is None:
        max_prog = 256
    fu = _norm_n_fu(n_fu)
    if max_fu_per_class is None:
        # size the compiled FU pool to the request: the no-event-skip runs
        # tick every cycle, and per-cycle cost scales with the pool width
        max_fu_per_class = max(4, max(fu))
    results: dict[str, Result] = {}
    names = []
    for scheduler in schedulers:
        cost = _norm_costs(scheduler)
        names.append(cost.name)
        g = run(prep, scheduler=cost, n_fu=fu, backend="golden",
                params=params, max_cycles=max_cycles, max_prog=max_prog,
                policy=policy, fu_cost=fu_cost)
        gold_rows = g.schedule_tuple()
        for event_skip in (True, False):
            m = run(prep, scheduler=cost, n_fu=fu, backend="jax",
                    params=params, event_skip=event_skip,
                    max_cycles=max_cycles, max_prog=max_prog,
                    max_fu_per_class=max_fu_per_class, policy=policy,
                    fu_cost=fu_cost, step_impl=step_impl)
            mode = f"jax event_skip={'on' if event_skip else 'off'}"
            if m.cycles != g.cycles:
                raise MismatchError(
                    f"{prep.name!r} under {cost.name!r}: {mode} ran "
                    f"{m.cycles} cycles, golden ran {g.cycles}")
            if m.schedule_tuple() != gold_rows:
                raise MismatchError(
                    f"{prep.name!r} under {cost.name!r}: {mode} schedule "
                    f"differs from golden — "
                    f"{_first_diff(m.schedule_tuple(), gold_rows)}")
        results[cost.name] = g
    return CompareReport(program=prep.name, schedulers=tuple(names),
                         results=results)


__all__ = ["run", "run_many", "sweep", "compare", "compare_population",
           "Result", "PopulationResult", "SweepResult", "TaskRow",
           "FairnessReport", "CompareReport", "PopulationCompareReport",
           "MismatchError", "SimulationError", "SchedPolicy",
           "PackedPopulation", "ALL_SCHEDULERS", "STEP_IMPLS",
           "scenarios_per_second"]
