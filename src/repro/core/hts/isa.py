"""Instruction Set Architecture of the Hardware Task Scheduler (paper §V, Table I).

Instructions are 128 bits wide. Field breakdown (Table I of the paper):

    [7:0]     accelerator id            (``acc``)
    [23:8]    input memory region       (``a``)
    [31:24]   input memory size         (``asz``)
    [47:32]   output memory region      (``b``)
    [55:48]   output memory size        (``bsz``)
    [59:56]   task id                   (``tid``)
    [63:60]   process id                (``pid``)
    [67:64]   control                   (``ctl``)
    [127:68]  metadata (accelerator)    (``meta`` — we keep the low 32 bits)

Accelerator ids below ``CTRL_BASE`` (0xF0) name *task* instructions (the function
accelerator to run).  Ids at/above ``CTRL_BASE`` encode the control instructions of
Figure 6 (``add``/``mul``/``mov``/``jump``/``if``/``lbeg``/``lend``).

Operand conventions (the paper's examples fix most of these; where the text is
ambiguous our choice is documented in DESIGN.md §3):

``task``   in-region = [a, a+asz), out-region = [b, b+bsz).
           ctl bit0: input region is *indirect* — taken from register R[a]
           ctl bit1: output region is indirect — taken from register R[b]
``add``    R[b] = R[a] + R[asz]
``mul``    R[b] = R[a] * R[asz]
``mov``    ctl bit0 ? R[b] = a (immediate) : R[b] = R[a]
``jump``   PC = a (absolute index into the dataflow program)
``if``     branch.  ctl bits [1:0]: 0 = RR, 1 = MR, 2 = BR   (paper §IV-C3)
           ctl bits [3:2]: condition 0 = EQ, 1 = NEQ, 2 = GE, 3 = LE
           value source: RR → R[a]; MR → mem[a]; BR → mem[a] once the in-flight
           producer of region ``a`` completes.  Compared against R[asz].
           Taken → PC += b (forward jump by ``b``), else fall through.
``lbeg``   R[asz] = (ctl bit0 ? R[a] : a)   — loop counter into register R[asz]
``lend``   R[asz] -= 1 ; if R[asz] > 0: PC -= b  (jump back over the loop body)
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

# ---------------------------------------------------------------------------
# Opcodes
# ---------------------------------------------------------------------------
CTRL_BASE = 0xF0

OP_TASK = 0
OP_ADD = 1
OP_MUL = 2
OP_MOV = 3
OP_JUMP = 4
OP_IF = 5
OP_LBEG = 6
OP_LEND = 7
OP_NOP = 8

_CTRL_OPS = {
    0xF1: OP_ADD,
    0xF2: OP_MUL,
    0xF3: OP_MOV,
    0xF4: OP_JUMP,
    0xF5: OP_IF,
    0xF6: OP_LBEG,
    0xF7: OP_LEND,
    0xF0: OP_NOP,
}
_CTRL_ACC = {v: k for k, v in _CTRL_OPS.items()}

OP_NAMES = {
    OP_TASK: "task", OP_ADD: "add", OP_MUL: "mul", OP_MOV: "mov",
    OP_JUMP: "jump", OP_IF: "if", OP_LBEG: "lbeg", OP_LEND: "lend",
    OP_NOP: "nop",
}

# Branch kinds (paper §IV-C3)
BR_RR = 0   # register-read: resolved inline, 1-cycle bubble, never speculated
BR_MR = 1   # memory-read: resolved by a spawned memory-read, speculated
BR_BR = 2   # bus-read: resolved by a pending task's CDB broadcast, speculated

# Branch conditions
CND_EQ, CND_NEQ, CND_GE, CND_LE = 0, 1, 2, 3

# Control-field bits for task instructions
CTL_IN_INDIRECT = 1   # input region index comes from a register
CTL_OUT_INDIRECT = 2  # output region index comes from a register
CTL_IMM = 1           # for mov/lbeg: operand ``a`` is an immediate


@dataclasses.dataclass(frozen=True)
class Instr:
    """One decoded 128-bit HTS instruction."""
    op: int
    acc: int = 0      # accelerator/function id for OP_TASK
    a: int = 0        # input memory region / src1 reg / immediate
    asz: int = 0      # input size / src2 reg / loop reg / threshold reg
    b: int = 0        # output memory region / dst reg / branch offset
    bsz: int = 0      # output size
    tid: int = 0      # task id (4 bits, program-level tag)
    pid: int = 0      # process id
    ctl: int = 0      # control nibble
    meta: int = 0     # accelerator metadata (low 32 bits retained)

    def __str__(self) -> str:
        """One assembler-compatible source line (see :func:`format_instr`)."""
        return format_instr(self)

    def encode(self) -> np.ndarray:
        """Pack into 4 little-endian uint32 lanes (128 bits)."""
        acc = self.acc if self.op == OP_TASK else _CTRL_ACC[self.op]
        w = int(acc) & 0xFF
        w |= (int(self.a) & 0xFFFF) << 8
        w |= (int(self.asz) & 0xFF) << 24
        w1 = int(self.b) & 0xFFFF
        w1 |= (int(self.bsz) & 0xFF) << 16
        w1 |= (int(self.tid) & 0xF) << 24
        w1 |= (int(self.pid) & 0xF) << 28
        w2 = int(self.ctl) & 0xF
        w2 |= (int(self.meta) & 0x0FFFFFFF) << 4
        w3 = (int(self.meta) >> 28) & 0xFFFFFFFF
        return np.array([w, w1, w2, w3], dtype=np.uint32)


def decode_word(words: Sequence[int]) -> Instr:
    """Inverse of :meth:`Instr.encode`."""
    w0, w1, w2, w3 = (int(w) for w in words)
    acc = w0 & 0xFF
    op = _CTRL_OPS.get(acc, OP_TASK)
    return Instr(
        op=op,
        acc=acc if op == OP_TASK else 0,
        a=(w0 >> 8) & 0xFFFF,
        asz=(w0 >> 24) & 0xFF,
        b=w1 & 0xFFFF,
        bsz=(w1 >> 16) & 0xFF,
        tid=(w1 >> 24) & 0xF,
        pid=(w1 >> 28) & 0xF,
        ctl=w2 & 0xF,
        meta=((w2 >> 4) & 0x0FFFFFFF) | ((w3 & 0xFFFFFFFF) << 28),
    )


def encode_program(instrs: Sequence[Instr]) -> np.ndarray:
    """Program → (P, 4) uint32 machine-code array."""
    if not instrs:
        return np.zeros((0, 4), dtype=np.uint32)
    return np.stack([i.encode() for i in instrs])


def decode_program(code: np.ndarray) -> list[Instr]:
    return [decode_word(row) for row in np.asarray(code)]


def format_instr(ins: Instr, names: dict[int, str] | None = None) -> str:
    """Disassemble one instruction to an assembler-compatible source line.

    ``names`` maps accelerator id → keyname; defaults to the Table-II DSP
    function set.  Unknown accelerator ids render as ``acc_<hex>`` (which
    does *not* reassemble — pass the right ``names`` for round-trips).
    """
    if names is None:
        from .costs import FUNC_NAMES
        names = FUNC_NAMES
    mnem = (names.get(ins.acc, f"acc_{ins.acc:x}") if ins.op == OP_TASK
            else OP_NAMES[ins.op])
    return (f"{mnem} {ins.a:x} {ins.asz:x} {ins.b:x} {ins.bsz:x} "
            f"{ins.tid:x} {ins.pid:x} {ins.ctl:x} {ins.meta:04x}")


def disassemble(code: np.ndarray, names: dict[int, str] | None = None) -> str:
    """Machine code → assembly text, one line per instruction.

    Inverse of ``assembler.assemble`` (for label-free numeric form):
    ``assemble(disassemble(code))`` is the identity, property-tested in
    tests/test_hts_builder.py.
    """
    return "\n".join(format_instr(i, names) for i in decode_program(code))


#: Column layout of the pre-decoded field table used by both simulators.
FIELDS = ("op", "acc", "a", "asz", "b", "bsz", "tid", "pid", "ctl", "meta")


def decode_table(code: np.ndarray) -> np.ndarray:
    """Pre-decode machine code into a dense (P, len(FIELDS)) int32 table.

    This is the "Task Decode" stage of the HTS pipeline (paper Fig. 5) —
    performed once up front because the program is static.
    """
    instrs = decode_program(code)
    tbl = np.zeros((len(instrs), len(FIELDS)), dtype=np.int32)
    for i, ins in enumerate(instrs):
        tbl[i] = [ins.op, ins.acc, ins.a, ins.asz, ins.b, ins.bsz,
                  ins.tid, ins.pid, ins.ctl, ins.meta & 0x7FFFFFFF]
    return tbl
