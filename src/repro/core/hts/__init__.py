"""HTS: the paper's Hardware Task Scheduler as a typed, simulatable system.

Public surface (the rest of the repo goes through this):

* :class:`Program` / :class:`Region` / :class:`Reg` — the typed
  Program-Builder front-end (``builder.py``): tasks, regions, loops,
  branches, processes, lowered to the 128-bit Table-I ISA.
* :func:`run` / :func:`run_many` / :func:`sweep` / :func:`compare` — the
  unified simulation facade (``api.py``) over the compiled JAX machine
  (``machine.py``) and the pure-Python golden oracle (``golden.py``);
  ``compare`` is the differential runner (golden ≡ machine, event-skip on
  and off, per scheduler).
* population-scale batching: the *scenario* is a ``vmap`` axis —
  ``batch.pack_population`` pads N programs to one shape bucket,
  :func:`run_many` simulates them in one compiled machine call
  (:class:`PopulationResult` slices back to per-scenario :class:`Result`),
  :func:`sweep` composes scenario × FU grids, and ``compare`` on a
  sequence verifies the whole batch against a golden loop.
* multi-tenant: :meth:`Program.merge` (N-way graph merge with isolation
  checks), ``workloads.py`` (seeded scenario generator), per-pid
  :class:`Result` metrics (``by_pid``/``app_makespan``/``fairness``).
* QoS scheduling: :class:`SchedPolicy` (``policy.py``) — per-pid priority
  weights and per-class FU quotas for the RS arbiter, attachable at
  ``Program.merge(priorities=..., quotas=...)`` and accepted by
  ``run``/``sweep``/``compare``; all-default degrades to the paper's pure
  age-order arbitration.
* per-tenant frontends (``frontend.py``): ``Program.merge(frontends=True,
  arrivals=...)`` keeps the tenants' instruction streams separate — the
  paper's N CPUs each pushing independently — with per-stream program
  counters, arrival offsets and a round-robin/weighted frontend arbiter;
  closes the merged-stream head-of-line bound the ``rs_admission`` study
  measured (``BENCH_frontend.json``).
* serving + sharding (``serve.py`` / ``shard.py``): :func:`serve` builds
  a continuously-batched :class:`Server` — ``submit(scenario) ->
  Future[Result]``, shape-bucket routing, launch-on-full/deadline, a
  per-bucket compilation cache (:meth:`Server.cache_info` proves a
  warmed server never recompiles), bounded-queue backpressure and
  per-bucket/per-tenant service metrics; ``run_many(devices=N)`` and
  ``ServeSpec(devices=N)`` shard the scenario axis across devices via
  ``shard_map`` (differentially verified by ``compare_population(
  devices=N)``).

    >>> from repro.core import hts
    >>> p = hts.Program("demo")
    >>> x = p.input(0x10, 4)
    >>> fft = p.task("fft_256", in_=x, out=4)
    >>> dot = p.task("vector_dot", in_=fft, out=1)
    >>> print(hts.run(p, scheduler="hts_spec", n_fu=2).table())

Lower layers remain importable directly (``isa``, ``assembler``, ``costs``,
``golden``, ``machine``, ``batch``, ``programs``, ``workloads``) for
tests and tools.
"""
from .api import (ALL_SCHEDULERS, STEP_IMPLS, CompareReport, FairnessReport,
                  MismatchError, PopulationCompareReport, PopulationResult,
                  Result, SimulationError, SweepResult, TaskRow, compare,
                  compare_population, run, run_many, scenarios_per_second,
                  sweep)
from .batch import PackedPopulation, pack_population, prog_bucket
from .builder import (BuilderError, BuiltProgram, Program, Reg, Region,
                      TaskHandle, Walker)
from .costs import SchedulerCosts, costs_by_name
from .frontend import MultiProgram, Stream, StreamSet, build_frontends
from .golden import HtsParams
from .policy import SchedPolicy
from .serve import (CacheInfo, ManualClock, QueueFullError, Server,
                    ServeReport, ServeSpec, SystemClock, serve)

__all__ = [
    "ALL_SCHEDULERS", "BuilderError", "BuiltProgram", "CacheInfo",
    "CompareReport", "FairnessReport", "HtsParams", "ManualClock",
    "MismatchError", "MultiProgram", "PackedPopulation",
    "PopulationCompareReport", "PopulationResult", "Program",
    "QueueFullError", "Reg", "Region", "Result", "SchedPolicy",
    "SchedulerCosts", "Server", "ServeReport", "ServeSpec",
    "STEP_IMPLS", "SimulationError", "Stream", "StreamSet", "SweepResult",
    "SystemClock",
    "TaskHandle", "TaskRow", "Walker", "build_frontends", "compare",
    "compare_population", "costs_by_name", "pack_population", "prog_bucket",
    "run", "run_many", "scenarios_per_second", "serve", "sweep",
]
