"""Assembler for the HTS dataflow-graph assembly language (paper §V-B).

Programs are described exactly as in the paper: one instruction per line, the
mnemonic is either a control instruction (``add``/``mul``/``mov``/``jump``/
``if``/``lbeg``/``lend``) or an accelerator *keyname* (e.g. ``fft_256``) which
the assembler resolves to an accelerator id at "compile" time.  The eight
operand fields are hexadecimal, in Table-I order::

    <mnemonic> <in_region> <in_size> <out_region> <out_size> <tid> <pid> <ctl> <meta>

e.g. (from the paper)::

    real_fir 10 2 13 2 0 0 0 0000
    if 93 a 12 0 1 0 d 0000

Extensions kept deliberately small (documented, not paper-visible):
  * ``#`` / ``;`` comments and blank lines;
  * trailing fields may be omitted (default 0);
  * ``@label`` definitions and ``jump @label`` / ``if ... @label`` targets,
    which the assembler lowers to the numeric PC/offset form above.
"""
from __future__ import annotations

import numpy as np

from . import isa
from .costs import FUNC_IDS

_CTRL_MNEMONICS = {
    "add": isa.OP_ADD, "mul": isa.OP_MUL, "mov": isa.OP_MOV,
    "jump": isa.OP_JUMP, "if": isa.OP_IF, "lbeg": isa.OP_LBEG,
    "lend": isa.OP_LEND, "nop": isa.OP_NOP,
}


class AsmError(ValueError):
    pass


def _strip(line: str) -> str:
    for marker in ("#", ";"):
        if marker in line:
            line = line[: line.index(marker)]
    return line.strip()


def assemble(text: str, keynames: dict[str, int] | None = None) -> np.ndarray:
    """Assemble ``text`` to a (P, 4) uint32 machine-code array.

    ``keynames`` maps accelerator keynames → accelerator ids; defaults to the
    Table-II DSP function set.
    """
    keynames = dict(FUNC_IDS if keynames is None else keynames)

    # Pass 1: collect labels and raw instruction tuples.
    raw: list[tuple[str, list[str], int]] = []   # (mnemonic, operands, line_no)
    labels: dict[str, int] = {}
    for ln, line in enumerate(text.splitlines(), start=1):
        line = _strip(line)
        if not line:
            continue
        if line.startswith("@"):
            label = line[1:].rstrip(":")
            if label in labels:
                raise AsmError(f"line {ln}: duplicate label @{label}")
            labels[label] = len(raw)
            continue
        parts = line.split()
        raw.append((parts[0], parts[1:], ln))

    # Pass 2: encode.
    instrs: list[isa.Instr] = []
    for pc, (mnem, ops, ln) in enumerate(raw):
        fields = [0] * 8  # a asz b bsz tid pid ctl meta
        label_slot = None
        for i, tok in enumerate(ops):
            if tok.startswith("@"):
                label = tok[1:]
                if label not in labels:
                    raise AsmError(f"line {ln}: unknown label @{label}")
                target = labels[label]
                # ``jump`` takes an absolute PC in field a; ``if`` takes a
                # forward offset in field b (paper: "PC jump by 18 if taken").
                if mnem == "jump":
                    fields[0] = target
                elif mnem == "if":
                    off = target - pc
                    if off < 0:
                        raise AsmError(f"line {ln}: if targets must be forward")
                    fields[2] = off
                else:
                    raise AsmError(f"line {ln}: labels only valid on jump/if")
                label_slot = i
                continue
            try:
                fields[i] = int(tok, 16)
            except ValueError as e:
                raise AsmError(f"line {ln}: bad hex operand {tok!r}") from e
        del label_slot

        a, asz, b, bsz, tid, pid, ctl, meta = fields
        if mnem in _CTRL_MNEMONICS:
            op = _CTRL_MNEMONICS[mnem]
            instrs.append(isa.Instr(op=op, a=a, asz=asz, b=b, bsz=bsz,
                                    tid=tid, pid=pid, ctl=ctl, meta=meta))
        else:
            if mnem not in keynames:
                raise AsmError(f"line {ln}: unknown accelerator keyname {mnem!r}")
            instrs.append(isa.Instr(op=isa.OP_TASK, acc=keynames[mnem], a=a,
                                    asz=asz, b=b, bsz=bsz, tid=tid, pid=pid,
                                    ctl=ctl, meta=meta))
    return isa.encode_program(instrs)


def disassemble(code: np.ndarray, keynames: dict[str, int] | None = None) -> str:
    """Machine code → assembly text (delegates to :func:`isa.disassemble`)."""
    keynames = dict(FUNC_IDS if keynames is None else keynames)
    return isa.disassemble(code, {v: k for k, v in keynames.items()})
