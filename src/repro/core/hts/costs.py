"""Cost model constants: Table II accelerator cycles + scheduler cost models.

Table II of the paper enumerates the DSP Functions modeled as accelerators and
their calibrated cycle counts (benchmarked on a DSP by Lennartsson et al. [28]).

The Naive / Runtime(software) / HTS scheduling cost models follow §VI-C:

* Naive           — CPU schedules one task at a time, in-order; each task pays its
                    execution cycles plus one interrupt latency.
* Runtime (SW)    — the HTS design "manifested in software": out-of-order, but every
                    scheduling structure access is a memory access (assumed L2 hit)
                    and completions arrive via interrupts.
* HTS             — hardware scheduler: single-cycle dispatch, completion via a
                    physical signal on the CDB (no interrupt), optional speculation.

The paper cites ARM Cortex-A interrupt latency [29] and Cortex-A9 L2 hit
latency [30] without printing the numbers; we use 400 cycles and 20 cycles
respectively (worst-case order-of-magnitude from those sources) and treat the
number of scheduler-structure accesses per task (6: tracker lookup + insert, RS
alloc + wakeup, ASR check, CDB arbitration) as the software-overhead multiplier.
EXPERIMENTS.md §Paper-claims records the reproduced speedups under these
constants.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional

import numpy as np

# ---------------------------------------------------------------------------
# Table II — DSP Functions modeled as accelerators
# ---------------------------------------------------------------------------
#: function keyname -> (accelerator id, input dataframe size, execution cycles)
FUNCTIONS: dict[str, tuple[int, int, int]] = {
    "real_fir":     (0, 40, 921),
    "complex_fir":  (1, 40, 3696),
    "adaptive_fir": (2, 40, 4384),
    "iir":          (3, 40, 2450),
    "vector_dot":   (4, 40, 53),
    "vector_add":   (5, 40, 131),
    "vector_max":   (6, 40, 55),
    "fft_256":      (7, 256, 18673),
    "dct":          (8, 64, 874),
    "correlation":  (9, 40, 753),
}

NUM_FUNCS = len(FUNCTIONS)
FUNC_IDS = {name: fid for name, (fid, _, _) in FUNCTIONS.items()}
FUNC_NAMES = {fid: name for name, (fid, _, _) in FUNCTIONS.items()}
FUNC_CYCLES = [0] * NUM_FUNCS
FUNC_FRAME = [0] * NUM_FUNCS
for _name, (_fid, _frame, _cyc) in FUNCTIONS.items():
    FUNC_CYCLES[_fid] = _cyc
    FUNC_FRAME[_fid] = _frame

# Pseudo function used to model an MR branch's spawned memory read (§IV-C3:
# "requires spawning a new task to read memory which can potentially take a
# large number of cycles").  DRAM-read order of magnitude.
MEM_READ_CYCLES = 200

# Cited latencies (see module docstring).
INTERRUPT_LATENCY = 400      # ARM Cortex-A interrupt round-trip, cycles [29]
L2_HIT_LATENCY = 20          # ARM Cortex-A9 L2 hit, cycles [30]
SW_ACCESSES_PER_TASK = 6     # scheduler-structure touches per task in software


@dataclasses.dataclass(frozen=True)
class SchedulerCosts:
    """Per-scheduler cost parameters (one instance per §VI-C algorithm)."""
    name: str
    in_order: bool                 # naive: single outstanding task, program order
    dispatch_serial_cost: int      # extra frontend cycles consumed per *task* dispatch
    completion_extra: int          # latency between task finish and dep-clear broadcast
    speculation: bool              # speculate MR/BR branches (HTS w/ spec only)
    issue_width: int = 4           # RS → accelerator issues per cycle ("superscalar")
    cdb_width: int = 1             # completion broadcasts per cycle (ticket arbiter)


def naive_costs() -> SchedulerCosts:
    return SchedulerCosts(
        name="naive", in_order=True, dispatch_serial_cost=1,
        completion_extra=INTERRUPT_LATENCY, speculation=False, issue_width=1)


def software_costs() -> SchedulerCosts:
    return SchedulerCosts(
        name="software", in_order=False,
        dispatch_serial_cost=L2_HIT_LATENCY * SW_ACCESSES_PER_TASK,
        completion_extra=INTERRUPT_LATENCY, speculation=False)


def hts_costs(speculation: bool = True) -> SchedulerCosts:
    return SchedulerCosts(
        name="hts_spec" if speculation else "hts_nospec", in_order=False,
        dispatch_serial_cost=1, completion_extra=0, speculation=speculation)


ALL_SCHEDULERS = ("naive", "software", "hts_nospec", "hts_spec")


# ---------------------------------------------------------------------------
# Heterogeneous FU cost tables
# ---------------------------------------------------------------------------
#: canonical per-class width of a packed cost table — matches the widest
#: ``max_fu_per_class`` any machine variant uses; narrower machines slice,
#: and unit indices ≥ ``n_fu[c]`` are never granted so the padding is inert.
FU_COST_WIDTH = 16
#: cost multipliers live in [1, FU_COST_CAP]: a unit's execution latency is
#: ``FUNC_CYCLES[c] * fu_cost[c, u]``.  The cap keeps the machine's combined
#: free-unit ranking key and the cycle counter comfortably inside int32.
FU_COST_CAP = 1 << 10


def norm_fu_cost(fu_cost, width: int = FU_COST_WIDTH) -> np.ndarray:
    """Normalize a cost-table spec to a ``(NUM_FUNCS, width)`` int32 array.

    Accepts ``None`` (all ones — every unit identical, the paper's machine),
    a ``{class_id_or_keyname: row_or_scalar}`` mapping (unlisted classes stay
    uniform), or a full array-like of per-class rows.  Rows shorter than
    ``width`` are padded with 1 (extra units are vanilla); a scalar row means
    "every unit of that class costs this much".
    """
    out = np.ones((NUM_FUNCS, width), np.int32)
    if fu_cost is None:
        return out
    if isinstance(fu_cost, Mapping):
        items = []
        for key, row in fu_cost.items():
            fid = FUNC_IDS[key] if isinstance(key, str) else int(key)
            if not 0 <= fid < NUM_FUNCS:
                raise ValueError(f"unknown function class {key!r}")
            items.append((fid, row))
    else:
        rows = list(fu_cost)
        if len(rows) != NUM_FUNCS:
            raise ValueError(f"fu_cost must have {NUM_FUNCS} per-class rows, "
                             f"got {len(rows)}")
        items = list(enumerate(rows))
    for fid, row in items:
        vals = [int(row)] * width if np.ndim(row) == 0 else \
            [int(v) for v in row]
        if len(vals) > width:
            vals = vals[:width]
        for u, v in enumerate(vals):
            if not 1 <= v <= FU_COST_CAP:
                raise ValueError(f"fu_cost[{fid}][{u}] must be in "
                                 f"[1, {FU_COST_CAP}], got {v}")
            out[fid, u] = v
    return out


def fu_cost_tuple(fu_cost) -> Optional[tuple]:
    """Hashable tuple-of-rows form for ``HtsParams.fu_cost`` (None if the
    table is uniformly 1, so a vanilla machine keeps a vanilla params key)."""
    if fu_cost is None:
        return None
    arr = norm_fu_cost(fu_cost)
    if (arr == 1).all():
        return None
    return tuple(tuple(int(v) for v in row) for row in arr)


def costs_by_name(name: str) -> SchedulerCosts:
    return {
        "naive": naive_costs(),
        "software": software_costs(),
        "hts_nospec": hts_costs(False),
        "hts_spec": hts_costs(True),
    }[name]
