"""Benchmark program generators (paper §VI-C).

Each generator emits HTS assembly text (assembled by ``assembler.assemble``)
plus the memory image (``mem_init``/``effects``) that steers branch outcomes.

The nine custom benchmarks match the paper's list:
  1. no_dependency           5. loop_no_dependency    8. branch_not_taken_no_dep
  2. same_dependency         6. loop_dependency       9. branch_taken_dependency
  3. diff_dependency         7. branch_taken_no_dep
  4. random_dependency

plus the real application: audio compression (Algorithm 1), with
time-domain (branch-taken) / frequency-domain (branch-not-taken) variants and a
``bands`` hyper-parameter for the Fig-10 strong-scaling sweep.

Region map convention: inputs live at 0x10+, each task ``i`` writes its own
region at ``OUT_BASE + i * RSTRIDE`` unless the benchmark dictates sharing.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import isa
from .costs import FUNC_IDS

OUT_BASE = 0x100
RSTRIDE = 0x8

#: the paper's task mix (Table II keynames) used round-robin by the synthetic
#: benchmarks — mirrors the §V-B example listing.
MIX = ("real_fir", "complex_fir", "adaptive_fir", "vector_dot", "iir",
       "vector_add", "vector_max", "fft_256", "dct", "correlation")


@dataclasses.dataclass
class Bench:
    name: str
    asm: str
    mem_init: dict[int, int]
    effects: dict[int, int]
    n_tasks_hint: int = 0   # static task count (0 if loop/branch dependent)


def _task(func: str, in_s: int, in_sz: int, out_s: int, out_sz: int,
          tid: int = 0, ctl: int = 0) -> str:
    return f"{func} {in_s:x} {in_sz:x} {out_s:x} {out_sz:x} {tid:x} 0 {ctl:x} 0"


def no_dependency(n: int = 20) -> Bench:
    """Independent tasks: every task reads the shared input, writes its own region."""
    lines = [_task(MIX[i % len(MIX)], 0x10, 4, OUT_BASE + i * RSTRIDE, 4,
                   tid=i & 0xF) for i in range(n)]
    return Bench("no_dependency", "\n".join(lines), {}, {}, n)


def same_dependency(chains: int = 4, depth: int = 5) -> Bench:
    """Chains of RAW-dependent tasks, every task mapped to the SAME function."""
    lines = []
    for c in range(chains):
        func = MIX[c % len(MIX)]
        prev = 0x10
        for d in range(depth):
            out = OUT_BASE + (c * depth + d) * RSTRIDE
            lines.append(_task(func, prev, 4, out, 4, tid=d & 0xF))
            prev = out
    return Bench("same_dependency", "\n".join(lines), {}, {}, chains * depth)


def diff_dependency(chains: int = 4, depth: int = 5) -> Bench:
    """Chains of RAW-dependent tasks mapped to DIFFERENT functions."""
    lines = []
    k = 0
    for c in range(chains):
        prev = 0x10
        for d in range(depth):
            out = OUT_BASE + (c * depth + d) * RSTRIDE
            lines.append(_task(MIX[k % len(MIX)], prev, 4, out, 4, tid=d & 0xF))
            prev = out
            k += 1
    return Bench("diff_dependency", "\n".join(lines), {}, {}, chains * depth)


def random_dependency(n: int = 24, seed: int = 0, p_dep: float = 0.5) -> Bench:
    """Random DAG: each task reads a random earlier task's output w.p. ``p_dep``."""
    rng = np.random.default_rng(seed)
    lines = []
    for i in range(n):
        if i > 0 and rng.random() < p_dep:
            src = OUT_BASE + int(rng.integers(0, i)) * RSTRIDE
        else:
            src = 0x10
        func = MIX[int(rng.integers(0, len(MIX)))]
        lines.append(_task(func, src, 4, OUT_BASE + i * RSTRIDE, 4, tid=i & 0xF))
    return Bench("random_dependency", "\n".join(lines), {}, {}, n)


def loop_no_dependency(iters: int = 8, body: int = 3) -> Bench:
    """One loop; iterations write disjoint regions via indirect addressing."""
    # r1 = walking output base, r2 = stride, r4 = loop counter
    stride = body * RSTRIDE
    lines = [
        f"mov {OUT_BASE:x} 0 1 0 0 0 1 0",       # r1 = OUT_BASE   (imm)
        f"mov {stride:x} 0 2 0 0 0 1 0",         # r2 = stride     (imm)
        f"lbeg {iters:x} 4 0 0 0 0 0 0",         # r4 = iters
    ]
    body_lines = []
    for j in range(body):
        # input: shared region; output: indirect base r1 (+ j handled by
        # distinct registers r5+j preloaded each iteration)
        body_lines.append(f"mov 1 0 {5 + j:x} 0 0 0 0 0")          # r(5+j) = r1
        if j:
            body_lines.append(f"mov {j * RSTRIDE:x} 0 3 0 0 0 1 0")  # r3 = j*RSTRIDE
            body_lines.append(f"add {5 + j:x} 3 {5 + j:x} 0 0 0 0 0")
        body_lines.append(
            f"{MIX[j % len(MIX)]} 10 4 {5 + j:x} 4 {j:x} 0 "
            f"{isa.CTL_OUT_INDIRECT:x} 0")
    body_lines.append("add 1 2 1 0 0 0 0 0")                        # r1 += r2
    lines += body_lines
    lines.append(f"lend 0 4 {len(body_lines):x} 0 0 0 0 0")
    return Bench("loop_no_dependency", "\n".join(lines), {}, {})


def loop_dependency(iters: int = 8) -> Bench:
    """A pre-loop task produces data every iteration consumes (paper: 'dependency
    of the loop iteration on one or more outside tasks')."""
    pre_out = 0x20
    lines = [
        _task("fft_256", 0x10, 4, pre_out, 4, tid=0),      # long-latency producer
        f"mov {OUT_BASE:x} 0 1 0 0 0 1 0",
        f"mov {RSTRIDE:x} 0 2 0 0 0 1 0",
        f"lbeg {iters:x} 4 0 0 0 0 0 0",
    ]
    body = [
        _task("iir", pre_out, 4, 1, 4, tid=1, ctl=isa.CTL_OUT_INDIRECT),
        "add 1 2 1 0 0 0 0 0",
    ]
    lines += body
    lines.append(f"lend 0 4 {len(body):x} 0 0 0 0 0")
    return Bench("loop_dependency", "\n".join(lines), {}, {})


def _branch_bench(name: str, taken: bool, kind: int, n_each: int = 6) -> Bench:
    """Shared skeleton for the three branch benchmarks.

    Layout:   [optional producer task]
              if <region> → @taken_block
              <not-taken block: n_each tasks>     (speculated path)
              jump @end
              @taken_block: <n_each tasks>
              @end: vector_max join
    """
    cond_region = 0x30
    thr_reg = 2
    ctl = kind | (isa.CND_GE << 2)         # taken iff mem[region] >= R[thr]
    lines = [f"mov 5 0 {thr_reg:x} 0 0 0 1 0"]   # threshold = 5
    effects: dict[int, int] = {}
    mem_init: dict[int, int] = {}
    if kind == isa.BR_BR:
        # producer the branch waits on (Bus-Read)
        lines.append(_task("correlation", 0x10, 4, cond_region, 1, tid=0))
        effects[cond_region] = 9 if taken else 1
    else:
        mem_init[cond_region] = 9 if taken else 1
    lines.append(f"if {cond_region:x} {thr_reg:x} @taken 0 0 0 {ctl:x} 0")
    for i in range(n_each):           # not-taken (fall-through, speculated) path
        lines.append(_task(MIX[i % len(MIX)], 0x10, 4,
                           OUT_BASE + i * RSTRIDE, 4, tid=i & 0xF))
    lines.append("jump @end 0 0 0 0 0 0 0")
    lines.append("@taken")
    for i in range(n_each):           # taken path
        lines.append(_task(MIX[(i + 3) % len(MIX)], 0x10, 4,
                           OUT_BASE + (n_each + i) * RSTRIDE, 4, tid=i & 0xF))
    lines.append("@end")
    lines.append(_task("vector_max", 0x10, 4, 0x60, 1, tid=0xF))
    return Bench(name, "\n".join(lines), mem_init, effects)


def branch_taken_no_dep(n_each: int = 6) -> Bench:
    return _branch_bench("branch_taken_no_dep", True, isa.BR_MR, n_each)


def branch_not_taken_no_dep(n_each: int = 6) -> Bench:
    return _branch_bench("branch_not_taken_no_dep", False, isa.BR_MR, n_each)


def branch_taken_dependency(n_each: int = 6) -> Bench:
    return _branch_bench("branch_taken_dependency", True, isa.BR_BR, n_each)


def audio_compression(bands: int = 8, time_domain: bool = False) -> Bench:
    """Algorithm 1: correlate; if correlated ≥ threshold → per-band FIR×3
    (time domain) else per-band FFT→VecDot×3→iFFT (frequency domain).

    Branch kind: BR (the condition value is produced by the correlation task).
    Speculation predicts not-taken = frequency domain, so ``time_domain=True``
    is the mis-speculated variant (paper Fig 9 'BT').
    """
    corr_out = 0x20
    thr_reg = 2
    ctl = isa.BR_BR | (isa.CND_GE << 2)
    lines = [
        _task("correlation", 0x10, 4, corr_out, 1, tid=0),   # "Correlate audio"
        f"mov 5 0 {thr_reg:x} 0 0 0 1 0",                    # threshold
        f"if {corr_out:x} {thr_reg:x} @time 0 0 0 {ctl:x} 0",
        # ---- frequency domain (fall-through / speculated path) ----
        f"mov {OUT_BASE:x} 0 1 0 0 0 1 0",     # r1: band base
        f"mov 20 0 3 0 0 0 1 0",               # r3: band stride (0x20)
        f"lbeg {bands:x} 4 0 0 0 0 0 0",
    ]
    freq_body = [
        # r5 = fft out = r1+8 ; r6 = dot out = r1+16 ; r7 = ifft out = r1+24
        "mov 1 0 5 0 0 0 0 0", "mov 8 0 8 0 0 0 1 0", "add 5 8 5 0 0 0 0 0",
        "mov 1 0 6 0 0 0 0 0", "mov 10 0 8 0 0 0 1 0", "add 6 8 6 0 0 0 0 0",
        "mov 1 0 7 0 0 0 0 0", "mov 18 0 8 0 0 0 1 0", "add 7 8 7 0 0 0 0 0",
        f"fft_256 1 4 5 4 1 0 {isa.CTL_IN_INDIRECT | isa.CTL_OUT_INDIRECT:x} 0",
        f"vector_dot 5 4 6 1 2 0 {isa.CTL_IN_INDIRECT | isa.CTL_OUT_INDIRECT:x} 0",
        f"vector_dot 5 4 6 1 3 0 {isa.CTL_IN_INDIRECT | isa.CTL_OUT_INDIRECT:x} 0",
        f"vector_dot 5 4 6 1 4 0 {isa.CTL_IN_INDIRECT | isa.CTL_OUT_INDIRECT:x} 0",
        f"fft_256 6 4 7 4 5 0 {isa.CTL_IN_INDIRECT | isa.CTL_OUT_INDIRECT:x} 0",
        "add 1 3 1 0 0 0 0 0",
    ]
    lines += freq_body
    lines.append(f"lend 0 4 {len(freq_body):x} 0 0 0 0 0")
    lines.append("jump @end 0 0 0 0 0 0 0")
    # ---- time domain (taken path) ----
    lines.append("@time")
    lines += [
        f"mov {OUT_BASE:x} 0 1 0 0 0 1 0",
        f"mov 20 0 3 0 0 0 1 0",
        f"lbeg {bands:x} 4 0 0 0 0 0 0",
    ]
    time_body = [
        "mov 1 0 5 0 0 0 0 0", "mov 8 0 8 0 0 0 1 0", "add 5 8 5 0 0 0 0 0",
        "mov 1 0 6 0 0 0 0 0", "mov 10 0 8 0 0 0 1 0", "add 6 8 6 0 0 0 0 0",
        "mov 1 0 7 0 0 0 0 0", "mov 18 0 8 0 0 0 1 0", "add 7 8 7 0 0 0 0 0",
        f"real_fir 1 4 5 4 1 0 {isa.CTL_IN_INDIRECT | isa.CTL_OUT_INDIRECT:x} 0",
        f"real_fir 1 4 6 4 2 0 {isa.CTL_IN_INDIRECT | isa.CTL_OUT_INDIRECT:x} 0",
        f"real_fir 1 4 7 4 3 0 {isa.CTL_IN_INDIRECT | isa.CTL_OUT_INDIRECT:x} 0",
        "add 1 3 1 0 0 0 0 0",
    ]
    lines += time_body
    lines.append(f"lend 0 4 {len(time_body):x} 0 0 0 0 0")
    lines.append("@end")
    effects = {corr_out: 9 if time_domain else 1}
    name = f"audio_compression_{'bt' if time_domain else 'bnt'}"
    return Bench(name, "\n".join(lines), {}, effects)


SYNTHETIC_NO_BRANCH = (no_dependency, same_dependency, diff_dependency,
                       random_dependency, loop_no_dependency, loop_dependency)
SYNTHETIC_BRANCH = (branch_taken_no_dep, branch_not_taken_no_dep,
                    branch_taken_dependency)
ALL_SYNTHETIC = SYNTHETIC_NO_BRANCH + SYNTHETIC_BRANCH


def all_benches() -> list[Bench]:
    return [g() for g in ALL_SYNTHETIC] + [
        audio_compression(8, False), audio_compression(8, True)]
