"""Benchmark program library (paper §VI-C), written against the
Program Builder (:mod:`builder`) — no hand-assembled hex anywhere.

Each generator constructs a :class:`builder.Program` (tasks, regions,
structured loops/branches) and wraps its lowering in a :class:`Bench` for
the benchmark drivers; region placement and branch steering memory images
(``mem_init``/``effects``) come from the builder's region allocator instead
of manual ``OUT_BASE + i * RSTRIDE`` arithmetic.

The nine custom benchmarks match the paper's list:
  1. no_dependency           5. loop_no_dependency    8. branch_not_taken_no_dep
  2. same_dependency         6. loop_dependency       9. branch_taken_dependency
  3. diff_dependency         7. branch_taken_no_dep
  4. random_dependency

plus the real application: audio compression (Algorithm 1), with
time-domain (branch-taken) / frequency-domain (branch-not-taken) variants and
a ``bands`` hyper-parameter for the Fig-10 strong-scaling sweep.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .builder import Program

OUT_BASE = 0x100      # historical region-space origin (builder default)
RSTRIDE = 0x8         # historical region stride (builder default alignment)
INPUT = 0x10          # shared input frame address
INPUT_WORDS = 4

#: the paper's task mix (Table II keynames) used round-robin by the synthetic
#: benchmarks — mirrors the §V-B example listing.
MIX = ("real_fir", "complex_fir", "adaptive_fir", "vector_dot", "iir",
       "vector_add", "vector_max", "fft_256", "dct", "correlation")


@dataclasses.dataclass
class Bench:
    """A built benchmark: assembly text + memory images, plus the source
    :class:`Program` for graph-level operations (e.g. interleaving)."""
    name: str
    asm: str
    mem_init: dict[int, int]
    effects: dict[int, int]
    n_tasks_hint: int = 0   # static task count (0 if loop/branch dependent)
    program: Program | None = None
    policy: object | None = None   # SchedPolicy riding along (hts.run default)

    @classmethod
    def of(cls, p: Program) -> "Bench":
        built = p.build()
        return cls(p.name, built.asm, built.mem_init, built.effects,
                   built.n_tasks_hint, p, built.policy)


def _mix_program(name: str) -> tuple[Program, "object"]:
    p = Program(name)
    return p, p.input(INPUT, INPUT_WORDS, "frame")


def no_dependency(n: int = 20) -> Bench:
    """Independent tasks: every task reads the shared input, writes its own region."""
    p, frame = _mix_program("no_dependency")
    for i in range(n):
        p.task(MIX[i % len(MIX)], in_=frame, out=4, tid=i)
    return Bench.of(p)


def same_dependency(chains: int = 4, depth: int = 5) -> Bench:
    """Chains of RAW-dependent tasks, every task mapped to the SAME function."""
    p, frame = _mix_program("same_dependency")
    for c in range(chains):
        func = MIX[c % len(MIX)]
        prev = frame
        for d in range(depth):
            prev = p.task(func, in_=prev, out=4, in_size=4, tid=d)
    return Bench.of(p)


def diff_dependency(chains: int = 4, depth: int = 5) -> Bench:
    """Chains of RAW-dependent tasks mapped to DIFFERENT functions."""
    p, frame = _mix_program("diff_dependency")
    k = 0
    for c in range(chains):
        prev = frame
        for d in range(depth):
            prev = p.task(MIX[k % len(MIX)], in_=prev, out=4, in_size=4,
                          tid=d)
            k += 1
    return Bench.of(p)


def random_dependency(n: int = 24, seed: int = 0, p_dep: float = 0.5) -> Bench:
    """Random DAG: each task reads a random earlier task's output w.p. ``p_dep``."""
    rng = np.random.default_rng(seed)
    p, frame = _mix_program("random_dependency")
    handles = []
    for i in range(n):
        if i > 0 and rng.random() < p_dep:
            src = handles[int(rng.integers(0, i))]
        else:
            src = frame
        func = MIX[int(rng.integers(0, len(MIX)))]
        handles.append(p.task(func, in_=src, out=4, in_size=4, tid=i))
    return Bench.of(p)


def loop_no_dependency(iters: int = 8, body: int = 3) -> Bench:
    """One loop; iterations write disjoint regions via indirect addressing."""
    p, frame = _mix_program("loop_no_dependency")
    w = p.walker(stride=body * RSTRIDE, count=iters, name="out")
    with p.loop(iters):
        for j in range(body):
            p.task(MIX[j % len(MIX)], in_=frame,
                   out=w if j == 0 else w.offset(j * RSTRIDE),
                   out_size=4, tid=j)
        w.advance()
    return Bench.of(p)


def loop_dependency(iters: int = 8) -> Bench:
    """A pre-loop task produces data every iteration consumes (paper: 'dependency
    of the loop iteration on one or more outside tasks')."""
    p, frame = _mix_program("loop_dependency")
    pre = p.task("fft_256", in_=frame, out=4, tid=0)    # long-latency producer
    w = p.walker(stride=RSTRIDE, count=iters, name="out")
    with p.loop(iters):
        p.task("iir", in_=pre, out=w, out_size=4, tid=1)
        w.advance()
    return Bench.of(p)


def _branch_bench(name: str, taken: bool, kind: str, n_each: int = 6) -> Bench:
    """Shared skeleton for the three branch benchmarks.

    Layout:   [optional producer task]
              if <region> → taken block
              <not-taken block: n_each tasks>     (speculated path)
              <taken block: n_each tasks>
              vector_max join
    """
    p, frame = _mix_program(name)
    thr = p.let(5, "thr")
    cond = p.region(1, name="cond")
    if kind == "bus":
        # producer the branch waits on (Bus-Read)
        p.task("correlation", in_=frame, out=cond, tid=0)
        cond.effect(9 if taken else 1)
    else:
        cond.init(9 if taken else 1)
    br = p.branch(on=cond, cond=">=", thr=thr, kind=kind)
    with br.not_taken():                 # fall-through, speculated path
        for i in range(n_each):
            p.task(MIX[i % len(MIX)], in_=frame, out=4, tid=i)
    with br.taken():
        for i in range(n_each):
            p.task(MIX[(i + 3) % len(MIX)], in_=frame, out=4, tid=i)
    p.task("vector_max", in_=frame, out=1, tid=0xF)
    return Bench.of(p)


def branch_taken_no_dep(n_each: int = 6) -> Bench:
    return _branch_bench("branch_taken_no_dep", True, "mem", n_each)


def branch_not_taken_no_dep(n_each: int = 6) -> Bench:
    return _branch_bench("branch_not_taken_no_dep", False, "mem", n_each)


def branch_taken_dependency(n_each: int = 6) -> Bench:
    return _branch_bench("branch_taken_dependency", True, "bus", n_each)


BAND_WORDS = 0x20      # per-band region footprint of the audio pipeline


def audio_compression(bands: int = 8, time_domain: bool = False) -> Bench:
    """Algorithm 1: correlate; if correlated ≥ threshold → per-band FIR×3
    (time domain) else per-band FFT→VecDot×3→iFFT (frequency domain).

    Branch kind: BR (the condition value is produced by the correlation task).
    Speculation predicts not-taken = frequency domain, so ``time_domain=True``
    is the mis-speculated variant (paper Fig 9 'BT').

    Both arms process the same band span (only one arm ever runs), so the
    per-band space is allocated once and walked by each arm's own pointer.
    """
    p = Program(f"audio_compression_{'bt' if time_domain else 'bnt'}")
    frame = p.input(INPUT, INPUT_WORDS, "audio")
    corr = p.task("correlation", in_=frame, out=1, tid=0)   # "Correlate audio"
    corr.out.effect(9 if time_domain else 1)
    thr = p.let(5, "thr")
    bandspace = p.region(bands * BAND_WORDS, name="bands")

    br = p.branch(on=corr.out, cond=">=", thr=thr, kind="bus")
    with br.not_taken():
        # ---- frequency domain (fall-through / speculated path) ----
        w = p.walker(start=bandspace.addr, stride=BAND_WORDS, name="band")
        with p.loop(bands):
            fft_o = w.offset(0x8)
            dot_o = w.offset(0x10)
            ifft_o = w.offset(0x18)
            p.task("fft_256", in_=w, out=fft_o, in_size=4, out_size=4, tid=1)
            for j in range(3):
                p.task("vector_dot", in_=fft_o, out=dot_o, in_size=4,
                       out_size=1, tid=2 + j)
            p.task("fft_256", in_=dot_o, out=ifft_o, in_size=4, out_size=4,
                   tid=5)
            w.advance()
    with br.taken():
        # ---- time domain ----
        w = p.walker(start=bandspace.addr, stride=BAND_WORDS, name="band")
        with p.loop(bands):
            outs = [w.offset(k) for k in (0x8, 0x10, 0x18)]
            for j, o in enumerate(outs):
                p.task("real_fir", in_=w, out=o, in_size=4, out_size=4,
                       tid=1 + j)
            w.advance()
    return Bench.of(p)


# ---------------------------------------------------------------------------
# multi-application pair (the paper's abstract motivation): a second real
# application interleaved with the audio stream under one HTS — formerly
# core/hts/multiapp.py, superseded by Program.merge for the general case
# ---------------------------------------------------------------------------
IMG_BASE = 0x800        # image app's region space (disjoint from audio's)
TILE_WORDS = 0x20


def image_compression(tiles: int = 8) -> Bench:
    """Per 8×8 tile: DCT → vector_max (quantization range proxy) →
    correlation (inter-tile prediction) → vector_add (residual).
    Straight-line (unrolled), pid=1 — the DCT-heavy complement to the
    FIR/FFT-heavy audio mix (Fig 2's image-processing example)."""
    p = Program("image_compression", region_base=IMG_BASE)
    with p.process(1):
        prev = None
        for t in range(tiles):
            tile = p.region(TILE_WORDS, align=TILE_WORDS, name=f"tile{t}")
            dct = p.task("dct", in_=tile.sub(0x0, 8), out=tile.sub(0x8, 8),
                         tid=t)
            p.task("vector_max", in_=dct, out=tile.sub(0x10, 1), tid=t)
            if prev is not None:
                p.task("correlation", in_=dct, out=tile.sub(0x11, 1), tid=t)
            p.task("vector_add", in_=dct, out=tile.sub(0x18, 8), tid=t)
            prev = dct
    return Bench.of(p)


def audio_straightline(bands: int = 8) -> Bench:
    """Unrolled audio compression, frequency-domain path (pid=0) — the
    loop-free variant used for multi-application sharing studies (merge it
    with :func:`image_compression` via ``Program.merge``)."""
    p = Program("audio_straightline")
    frame = p.input(INPUT, INPUT_WORDS, "audio")
    p.task("correlation", in_=frame, out=1, tid=0)
    for b in range(bands):
        band = p.region(TILE_WORDS, align=TILE_WORDS, name=f"band{b}")
        fft = p.task("fft_256", in_=band.sub(0x0, 4), out=band.sub(0x8, 4),
                     tid=1)
        for j in range(3):
            p.task("vector_dot", in_=fft, out=band.sub(0x10 + j, 1),
                   tid=2 + j)
        p.task("fft_256", in_=band.sub(0x10, 4), out=band.sub(0x18, 4),
               tid=5)
    return Bench.of(p)


SYNTHETIC_NO_BRANCH = (no_dependency, same_dependency, diff_dependency,
                       random_dependency, loop_no_dependency, loop_dependency)
SYNTHETIC_BRANCH = (branch_taken_no_dep, branch_not_taken_no_dep,
                    branch_taken_dependency)
ALL_SYNTHETIC = SYNTHETIC_NO_BRANCH + SYNTHETIC_BRANCH


def all_benches() -> list[Bench]:
    return [g() for g in ALL_SYNTHETIC] + [
        audio_compression(8, False), audio_compression(8, True)]


def merge_benches(benches, name: str = "shared", **merge_kwargs) -> Bench:
    """N-way :meth:`builder.Program.merge` of builder-backed benches (N CPUs
    pushing into the one Task Queue; pids distinguish the owners) —
    performed on the program graphs, not on assembly text."""
    benches = list(benches)
    if any(b.program is None for b in benches):
        raise ValueError("merge needs builder-backed Bench objects")
    return Bench.of(Program.merge([b.program for b in benches], name,
                                  **merge_kwargs))
