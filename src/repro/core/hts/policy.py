"""Scheduling policy: per-pid priority weights and per-pid FU quotas.

The paper's multi-application sharing story gives every tenant equal
standing: the reservation station issues strictly in *age* order, so a
greedy tenant that keeps the RS full can starve a latency-sensitive one
(``Result.fairness`` measures exactly this).  Priority-aware scheduling
for heterogeneous accelerator pools (Chen & Marculescu 2017) and the
hardware-HEFT scheduler of Fusco et al. 2022 both recover QoS with cheap
priority/quota logic in the arbiter; :class:`SchedPolicy` is that logic's
configuration:

* **weights** — per-pid priority weight.  The RS arbiter issues
  priority-class first (higher weight wins), age order *within* a class;
  all-equal weights degrade to the paper's pure age order bit-for-bit.
* **quotas** — optional per-pid cap on *in-flight accelerator units per
  function class*.  A pid at its cap is masked out of the per-class
  free-unit ranking until one of its tasks completes; the freed unit
  falls to the next eligible entry (the arbiter stays work-conserving).
* **rs_caps** — optional per-pid cap on *reservation-station entries*
  (admission control).  FU quotas gate only execution occupancy: a
  greedy tenant can still fill the whole RS with pending entries and
  dispatch-block every later tenant behind a structural stall.  An RS
  cap stalls *that pid's own* task dispatch once its RS occupancy
  reaches the cap (exactly like the RS-full structural stall, but per
  pid), so floods capped below ``rs_entries`` can never exhaust the
  shared window — the headroom is effectively reserved for uncapped
  tenants, mirroring how FU quotas below the pool size reserve units.

A policy is **data, not configuration**: the JAX machine receives the
weight/quota arrays as traced runtime arguments (like ``n_fu``), so
sweeping priority ratios never recompiles and can ride the same ``vmap``
as the FU axis.  The golden oracle implements the identical arbitration
sequentially; ``hts.compare`` proves the two agree on every scenario.

>>> pol = SchedPolicy.of(weights={1: 8}, quotas={2: 1})
>>> pol.weight_of(1), pol.weight_of(2), pol.quota_of(2)
(8, 0, 1)
>>> int(pol.weight_array()[1])
8
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional

import numpy as np

#: pid is a 4-bit ISA field (Table I) — 16 addressable processes.
NUM_PIDS = 16
#: weights are clipped to [0, PRIO_CAP] so the combined issue key
#: ``(PRIO_CAP - weight) * AGE_SPAN + age`` stays an exact int32.
PRIO_CAP = 1 << 12
#: must exceed any task age (age increments once per dispatched task and
#: is bounded by ``HtsParams.max_tasks``).
AGE_SPAN = 1 << 17
#: quota value meaning "uncapped" (larger than any possible in-flight count).
NO_QUOTA = 1 << 30


@dataclasses.dataclass(frozen=True)
class SchedPolicy:
    """Per-pid scheduling policy (hashable: safe inside ``HtsParams``).

    Use :meth:`of` to build one from plain dicts; the stored form is
    sorted ``(pid, value)`` tuples so two policies with the same content
    hash and compare equal.
    """
    weights: tuple[tuple[int, int], ...] = ()   # (pid, priority weight)
    quotas: tuple[tuple[int, int], ...] = ()    # (pid, max in-flight/class)
    rs_caps: tuple[tuple[int, int], ...] = ()   # (pid, max RS entries)
    default_weight: int = 0
    #: frontend arbitration between per-tenant dispatch streams
    #: (``core/hts/frontend.py``): ``"rr"`` = round-robin over eligible
    #: streams (the default — all tenants equal at dispatch), ``"weighted"``
    #: = a stream's pid priority weight ranks first, round-robin within a
    #: weight class.  Irrelevant to single-stream (merged) programs.
    fe_mode: str = "rr"
    #: RS unit-selection rule once an entry wins arbitration: ``"greedy"``
    #: grants the lowest-indexed free unit of the class (the paper's
    #: machine — all units identical, so index order is finish order);
    #: ``"eft"`` grants the free unit with the earliest predicted finish
    #: time under the per-(class, unit) cost tables (``fu_cost``) — only
    #: *free* units are candidates, so the busy-horizon term is zero and
    #: EFT ranks by cost-table latency, ties broken by unit index.  With
    #: uniform costs the two are bit-identical.  Like the weight/quota
    #: arrays this is traced runtime data: flipping modes never recompiles.
    issue_mode: str = "greedy"

    @staticmethod
    def _norm_fe_mode(fe_mode: str) -> str:
        if fe_mode not in ("rr", "weighted"):
            raise ValueError(f'fe_mode must be "rr" or "weighted", '
                             f'got {fe_mode!r}')
        return fe_mode

    @staticmethod
    def _norm_issue_mode(issue_mode: str) -> str:
        if issue_mode not in ("greedy", "eft"):
            raise ValueError(f'issue_mode must be "greedy" or "eft", '
                             f'got {issue_mode!r}')
        return issue_mode

    @classmethod
    def of(cls, weights: Optional[Mapping[int, int]] = None,
           quotas: Optional[Mapping[int, int]] = None,
           rs_caps: Optional[Mapping[int, int]] = None,
           default_weight: int = 0, fe_mode: str = "rr",
           issue_mode: str = "greedy") -> "SchedPolicy":
        """Build a policy from ``{pid: weight}`` / ``{pid: quota}`` /
        ``{pid: rs_cap}`` dicts."""
        def norm(m, what, lo, hi):
            items = []
            for pid, v in sorted((m or {}).items()):
                if not 0 <= int(pid) < NUM_PIDS:
                    raise ValueError(f"pid {pid} outside the 4-bit ISA "
                                     f"field [0, {NUM_PIDS})")
                if not lo <= int(v) <= hi:
                    raise ValueError(f"{what} for pid {pid} must be in "
                                     f"[{lo}, {hi}], got {v}")
                items.append((int(pid), int(v)))
            return tuple(items)
        if not 0 <= int(default_weight) <= PRIO_CAP:
            raise ValueError(f"default_weight must be in [0, {PRIO_CAP}], "
                             f"got {default_weight}")
        return cls(weights=norm(weights, "weight", 0, PRIO_CAP),
                   quotas=norm(quotas, "quota", 1, NO_QUOTA),
                   rs_caps=norm(rs_caps, "rs_cap", 1, NO_QUOTA),
                   default_weight=int(default_weight),
                   fe_mode=cls._norm_fe_mode(fe_mode),
                   issue_mode=cls._norm_issue_mode(issue_mode))

    # ----------------------------------------------------------- lookups
    def weight_of(self, pid: int) -> int:
        return dict(self.weights).get(pid, self.default_weight)

    def quota_of(self, pid: int) -> int:
        """Per-class in-flight cap for ``pid`` (``NO_QUOTA`` if uncapped)."""
        return dict(self.quotas).get(pid, NO_QUOTA)

    def rs_cap_of(self, pid: int) -> int:
        """Max RS entries ``pid`` may hold at once (``NO_QUOTA`` = uncapped)."""
        return dict(self.rs_caps).get(pid, NO_QUOTA)

    @property
    def is_default(self) -> bool:
        """True iff this policy degrades to pure age-order arbitration."""
        return (not self.quotas and not self.rs_caps
                and self.issue_mode == "greedy"
                and all(w == self.default_weight for _, w in self.weights))

    # ------------------------------------------------------ array forms
    def weight_array(self, num_pids: int = NUM_PIDS) -> np.ndarray:
        """(num_pids,) int32 weight table (clipped to [0, PRIO_CAP])."""
        arr = np.full((num_pids,), self.default_weight, np.int32)
        for pid, w in self.weights:
            arr[pid] = w
        return np.clip(arr, 0, PRIO_CAP)

    def quota_array(self, num_pids: int = NUM_PIDS) -> np.ndarray:
        """(num_pids,) int32 per-class in-flight caps (NO_QUOTA = uncapped)."""
        arr = np.full((num_pids,), NO_QUOTA, np.int32)
        for pid, q in self.quotas:
            arr[pid] = q
        return arr

    def rs_cap_array(self, num_pids: int = NUM_PIDS) -> np.ndarray:
        """(num_pids,) int32 RS-entry admission caps (NO_QUOTA = uncapped)."""
        arr = np.full((num_pids,), NO_QUOTA, np.int32)
        for pid, q in self.rs_caps:
            arr[pid] = q
        return arr

    # --------------------------------------------------------- utilities
    def merge_with(self, other: "SchedPolicy") -> "SchedPolicy":
        """Union of two policies; conflicting entries for a pid are an error
        (used by :meth:`builder.Program.merge` to combine tenant policies)."""
        if other.default_weight != self.default_weight:
            raise ValueError("cannot merge policies with different "
                             "default weights")
        if other.fe_mode != self.fe_mode:
            raise ValueError("cannot merge policies with different "
                             "frontend modes "
                             f"({self.fe_mode!r} vs {other.fe_mode!r})")
        if other.issue_mode != self.issue_mode:
            raise ValueError("cannot merge policies with different "
                             "issue modes "
                             f"({self.issue_mode!r} vs {other.issue_mode!r})")
        out_w, out_q = dict(self.weights), dict(self.quotas)
        out_r = dict(self.rs_caps)
        for src, dst, what in ((other.weights, out_w, "weight"),
                               (other.quotas, out_q, "quota"),
                               (other.rs_caps, out_r, "rs_cap")):
            for pid, v in src:
                if pid in dst and dst[pid] != v:
                    raise ValueError(f"conflicting {what} for pid {pid}: "
                                     f"{dst[pid]} vs {v}")
                dst[pid] = v
        return SchedPolicy.of(out_w, out_q, out_r, self.default_weight,
                              self.fe_mode, self.issue_mode)

    def issue_key(self, pid: int, age: int) -> int:
        """The arbiter's scalar sort key: priority class first (higher
        weight = smaller key), age order within a class.  Both simulators
        order RS entries by exactly this value."""
        w = min(max(self.weight_of(pid), 0), PRIO_CAP)
        return (PRIO_CAP - w) * AGE_SPAN + age

    def describe(self) -> str:
        if self.is_default:
            return "age-order (no priorities, no quotas)"
        parts = []
        if self.weights:
            parts.append("weights " + ",".join(f"{p}:{w}"
                                               for p, w in self.weights))
        if self.quotas:
            parts.append("quotas " + ",".join(f"{p}:{q}"
                                              for p, q in self.quotas))
        if self.rs_caps:
            parts.append("rs_caps " + ",".join(f"{p}:{q}"
                                               for p, q in self.rs_caps))
        if self.fe_mode != "rr":
            parts.append(f"frontends {self.fe_mode}")
        if self.issue_mode != "greedy":
            parts.append(f"issue {self.issue_mode}")
        return "; ".join(parts)
