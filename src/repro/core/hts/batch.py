"""Population packing: N scenarios as one ``vmap`` axis.

The compiled machine (:mod:`machine`) takes *everything* as runtime inputs —
program table, program length, memory images, FU counts, policy tables — so
a **population of scenarios** can be one more batch axis next to the existing
FU and policy axes.  What stands between "a list of programs" and "one
``vmap``-batched call" is shape bookkeeping, and that lives here:

* :func:`prepare` normalises any program-ish object (``Program`` /
  ``BuiltProgram`` / ``Bench`` / assembly text / code array) to a
  :class:`Prepared` — the name, machine code, memory images and attached
  policy that every ``api`` entry point consumes;
* :func:`prog_bucket` rounds a program length up to a power-of-two table
  size, so one compilation serves every scenario in the same *shape
  bucket* instead of one compilation per program length;
* :func:`pack_population` pads N prepared programs into common-shape
  arrays — ``ftab`` (N, max_prog, fields), ``p_len`` (N,), per-scenario
  ``mem``/``eff`` images on the shared ``params.total_mem`` footprint, and
  per-scenario ``n_fu``/``prio``/``quota``/``rs_cap`` tables — returning a
  :class:`PackedPopulation` that ``api.run_many`` / ``api.sweep`` /
  ``api.compare`` feed straight into one jitted, scenario-vmapped machine.

Padding is semantics-free: padded ``ftab`` rows are never fetched
(``pc >= p_len``), and a scenario's images only occupy the addresses its
program reserved.  ``tests/test_hts_population.py`` pins both properties
(padded vs unpadded schedules are bit-identical).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import numpy as np

from . import isa, machine
from .builder import BuiltProgram, Program
from .costs import NUM_FUNCS, norm_fu_cost
from .frontend import STREAM_FIELDS, MultiProgram, StreamSet
from .golden import HtsParams
from .policy import SchedPolicy

#: smallest program-table shape bucket (power-of-two buckets above it).
MIN_BUCKET = 32


# ---------------------------------------------------------------------------
# program normalisation (shared by every api entry point)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Prepared:
    """A program normalised to raw machine inputs."""
    name: str
    code: np.ndarray
    mem_init: dict[int, int]
    effects: dict[int, int]
    policy: Optional[SchedPolicy] = None    # attached by builder/merge
    #: per-tenant frontends (``frontend.MultiProgram``); ``None`` = the
    #: historical single merged in-order frontend
    streams: Optional[StreamSet] = None


def prepare(program) -> Prepared:
    """Accept Program | MultiProgram | BuiltProgram | Bench-like | asm text
    | code array."""
    if isinstance(program, Prepared):
        return program
    if isinstance(program, MultiProgram):
        return Prepared(program.name, program.code, program.mem_init,
                        program.effects, program.policy, program.streams)
    if isinstance(program, Program):
        program = program.build()
    if isinstance(program, BuiltProgram):
        return Prepared(program.name, program.code, program.mem_init,
                        program.effects, program.policy)
    if isinstance(program, str):                      # assembly text
        from . import assembler
        return Prepared("<asm>", assembler.assemble(program), {}, {})
    if isinstance(program, np.ndarray):               # raw machine code
        return Prepared("<code>", program, {}, {})
    if hasattr(program, "asm"):                       # programs.Bench (duck)
        from . import assembler
        return Prepared(getattr(program, "name", "<bench>"),
                        assembler.assemble(program.asm),
                        dict(getattr(program, "mem_init", {}) or {}),
                        dict(getattr(program, "effects", {}) or {}),
                        getattr(program, "policy", None))
    raise TypeError(f"cannot interpret {type(program).__name__} as an HTS "
                    "program")


def norm_n_fu(n_fu) -> tuple[int, ...]:
    """An int (uniform) or NUM_FUNCS per-class counts → per-class tuple."""
    if isinstance(n_fu, (int, np.integer)):
        return (int(n_fu),) * NUM_FUNCS
    t = tuple(int(k) for k in n_fu)
    if len(t) != NUM_FUNCS:
        raise ValueError(f"n_fu must be an int or {NUM_FUNCS} per-class "
                         f"counts, got {len(t)}")
    return t


def norm_policy(policy: Optional[SchedPolicy], prep: Prepared,
                params: HtsParams) -> SchedPolicy:
    """Effective policy: explicit arg > program-attached > params default."""
    if policy is not None:
        return policy
    if prep.policy is not None:
        return prep.policy
    return params.policy


# ---------------------------------------------------------------------------
# shape buckets
# ---------------------------------------------------------------------------
def work_estimate(program) -> int:
    """Static proxy for a scenario's batched-simulation *step count*.

    A batched while loop runs until its *slowest* lane halts, so a batch of
    wildly different scenario lengths wastes lane-steps on the short ones.
    Under event-skip, task execution cycles are skipped over — the steps
    that remain track the frontend's executed instructions and the
    scheduler events, so the instruction count is the proxy that actually
    predicts step counts (Spearman ≈ 0.9 on generated populations;
    cycle-weighted estimates sort *worse*, because long-latency kernels
    are exactly what event-skip elides).
    """
    return len(isa.decode_table(prepare(program).code))


def plan_chunks(programs: Sequence, max_chunk: int = 32,
                min_chunk: int = 8,
                profile=None) -> tuple[tuple[int, ...], ...]:
    """Scenario indices grouped into straggler-isolating vmap chunks.

    A chunk runs as long as its slowest lane, so one heavy scenario in a
    wide batch wastes every other lane's steps.  Scenarios are sorted by
    cost (ascending) and partitioned **geometrically**: the lightest half
    of the population rides in ``max_chunk``-wide batches, the next
    quarter in half-width ones, and so on down to ``min_chunk`` — so the
    heavy tail executes in narrow batches where it can only hold up a few
    lanes.  Widths are powers of two (times ``max_chunk``), so a plan
    compiles at most one machine per distinct width.  Each chunk packs
    (``pack_population``) and runs (``run_many``) as one batch.

    The cost key is :func:`work_estimate` (the static instruction-count
    proxy) unless ``profile`` supplies *measured* per-scenario step
    counts: either a length-N sequence/array of step counts, or anything
    with a ``.steps`` attribute — in particular a first run's
    :class:`~repro.core.hts.api.PopulationResult`, whose ``steps`` are
    the machine's own while-loop trip counts.  Profile-guided plans
    re-chunk long ``run_many`` sweeps from real costs, which is what
    closes the heterogeneous-population gap the proxy leaves open (the
    proxy tracks event counts, not their spread).
    """
    if not 0 < min_chunk <= max_chunk:
        raise ValueError("need 0 < min_chunk <= max_chunk")
    if profile is None:
        key = [work_estimate(p) for p in programs]
    else:
        key = np.asarray(getattr(profile, "steps", profile))
        if key is None or key.dtype == object or key.ndim != 1:
            raise ValueError("profile must be a 1-D sequence of per-"
                             "scenario step counts or expose .steps")
        if len(key) != len(programs):
            raise ValueError(f"profile has {len(key)} step counts for "
                             f"{len(programs)} programs")
        key = [int(x) for x in key]
    order = sorted(range(len(programs)), key=lambda i: key[i])
    chunks: list[tuple[int, ...]] = []
    k, n, width = 0, len(order), max_chunk
    while k < n:
        w = min(width, n - k)
        chunks.append(tuple(order[k:k + w]))
        k += w
        width = max(min_chunk, width // 2)   # narrower toward the tail
    return tuple(chunks)


def prog_bucket(length: int, floor: int = MIN_BUCKET) -> int:
    """Smallest power-of-two program-table size >= ``length`` (>= floor).

    Scenarios in the same bucket share one compiled machine; the bucket
    ladder keeps the number of distinct compilations logarithmic in the
    population's length spread instead of linear in its size.
    """
    if length > 0 and floor <= 0:
        raise ValueError("bucket floor must be positive")
    b = max(int(floor), 1)
    while b < length:
        b *= 2
    return b


# ---------------------------------------------------------------------------
# the packed batch
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True, eq=False)
class PackedPopulation:
    """N scenarios padded to common static shapes: one compile, one vmap.

    Array layout (scenario-major; every row feeds one machine instance):

    * ``ftab`` (N, max_prog, fields) — decoded program tables, zero-padded;
    * ``p_len`` (N,) — true program lengths (padding rows never fetch);
    * ``mem`` / ``eff`` (N, total_mem) — per-scenario memory/effects images
      on the shared ``params.total_mem`` footprint;
    * ``n_fu`` (N, NUM_FUNCS) — per-scenario accelerator counts;
    * ``prio`` / ``quota`` / ``rs_cap`` (N, NUM_PIDS) — per-scenario
      scheduling-policy tables;
    * ``fu_cost`` (N, NUM_FUNCS, FU_COST_WIDTH) — per-scenario
      per-(class, unit) execution-latency multipliers (all ones =
      homogeneous pool); ``eft`` (N,) — per-scenario EFT-issue flags
      (the lowered ``policy.issue_mode``);
    * ``streams`` (N, max_streams, 4) — per-scenario frontend stream
      tables (``frontend.STREAM_FIELDS``), padded with inactive rows
      (``end <= start`` — never fetched); single-frontend scenarios get
      the one merged stream, so multi-frontend populations ride the same
      shape buckets and batches as everything else.

    ``preps``/``policies`` retain the per-scenario sources so differential
    checks (``api.compare``) can drive the golden oracle scenario by
    scenario against the one batched machine run.
    """
    names: tuple[str, ...]
    preps: tuple[Prepared, ...]
    policies: tuple[SchedPolicy, ...]
    ftab: np.ndarray
    p_len: np.ndarray
    mem: np.ndarray
    eff: np.ndarray
    n_fu: np.ndarray
    prio: np.ndarray
    quota: np.ndarray
    rs_cap: np.ndarray
    fu_cost: np.ndarray
    eft: np.ndarray
    streams: np.ndarray
    max_prog: int
    params: HtsParams               # shared capacities (policy stripped)

    def __len__(self) -> int:
        return len(self.names)

    @property
    def widest_fu(self) -> int:
        """Largest per-class FU count in the batch (pool-width floor)."""
        return int(self.n_fu.max())

    def machine_args(self):
        """The 11 batched arrays in ``machine.make_machine`` run order."""
        return (self.ftab, self.p_len, self.n_fu, self.mem, self.eff,
                self.prio, self.quota, self.rs_cap, self.fu_cost,
                self.eft, self.streams)

    def stream_table(self, i: int) -> np.ndarray:
        """Scenario ``i``'s stream table without the batch padding rows
        (what the golden oracle consumes in differential checks)."""
        tab = self.streams[i]
        keep = tab[:, 1] > tab[:, 0]
        return tab[keep] if keep.any() else tab[:1]


def replicate(pop: PackedPopulation, width: int) -> PackedPopulation:
    """A ``width``-lane population that tiles ``pop``'s lanes.

    Every batched array repeats lane-for-lane (lane ``i`` is source lane
    ``i % len(pop)``), so the replica exercises exactly the same step
    bodies at a different lane width — the controlled variable of the
    width-cost sweeps (``benchmarks/stepwidth.py``).  Names are suffixed
    with the replica index to stay unique.
    """
    n = len(pop)
    if width < 1:
        raise ValueError(f"width must be >= 1, got {width}")
    idx = np.arange(width) % n

    def tile(a: np.ndarray) -> np.ndarray:
        return np.ascontiguousarray(np.asarray(a)[idx])

    return dataclasses.replace(
        pop,
        names=tuple(f"{pop.names[i % n]}#r{i // n}" for i in range(width)),
        preps=tuple(pop.preps[i % n] for i in range(width)),
        policies=tuple(pop.policies[i % n] for i in range(width)),
        ftab=tile(pop.ftab), p_len=tile(pop.p_len), mem=tile(pop.mem),
        eff=tile(pop.eff), n_fu=tile(pop.n_fu), prio=tile(pop.prio),
        quota=tile(pop.quota), rs_cap=tile(pop.rs_cap),
        fu_cost=tile(pop.fu_cost), eft=tile(pop.eft),
        streams=tile(pop.streams))


def _broadcast_n_fu(n_fu, n: int) -> np.ndarray:
    """One shared FU spec or a length-N per-scenario list → (N, NUM_FUNCS).

    A flat sequence of ints is always read as *per-class* counts (the
    established ``run``/``sweep`` meaning); per-scenario specs are a
    sequence of N ints or N per-class tuples.
    """
    if isinstance(n_fu, (int, np.integer)):
        return np.tile(np.asarray(norm_n_fu(n_fu), np.int32), (n, 1))
    seq = list(n_fu)
    flat = all(isinstance(x, (int, np.integer)) for x in seq)
    if flat and len(seq) == NUM_FUNCS:
        return np.tile(np.asarray(norm_n_fu(seq), np.int32), (n, 1))
    if len(seq) != n:
        raise ValueError(
            f"n_fu must be an int, {NUM_FUNCS} per-class counts, or one "
            f"entry per scenario ({n}); got a length-{len(seq)} sequence")
    return np.asarray([norm_n_fu(x) for x in seq], np.int32)


def _broadcast_policy(policy, preps: Sequence[Prepared],
                      params: HtsParams) -> tuple[SchedPolicy, ...]:
    """One shared policy, a per-scenario list, or None (per-program)."""
    if policy is None or isinstance(policy, SchedPolicy):
        return tuple(norm_policy(policy, p, params) for p in preps)
    pols = list(policy)
    if len(pols) != len(preps):
        raise ValueError(f"got {len(pols)} policies for {len(preps)} "
                         "scenarios")
    return tuple(norm_policy(pol, p, params)
                 for pol, p in zip(pols, preps))


def _broadcast_fu_cost(fu_cost, n: int, params: HtsParams) -> np.ndarray:
    """One shared cost-table spec or one per scenario → (N, NF, WIDTH).

    A ``None`` entry (or a ``None`` argument) falls back to
    ``params.fu_cost`` (all ones if that is unset too).  A single spec is
    anything ``costs.norm_fu_cost`` accepts — a mapping or a full table of
    per-class rows; per-scenario specs are a length-N sequence of those.
    """
    if fu_cost is None:
        return np.tile(norm_fu_cost(params.fu_cost), (n, 1, 1))
    if (isinstance(fu_cost, (list, tuple)) and len(fu_cost) == n
            and all(x is None or np.ndim(x) == 2
                    or isinstance(x, dict) for x in fu_cost)):
        return np.stack([norm_fu_cost(x if x is not None
                                      else params.fu_cost)
                         for x in fu_cost])
    return np.tile(norm_fu_cost(fu_cost), (n, 1, 1))


def pack_population(programs: Sequence,
                    *, params: HtsParams = HtsParams(),
                    n_fu: Union[int, Sequence] = 2,
                    policy=None,
                    fu_cost=None,
                    max_prog: Optional[int] = None,
                    max_streams: Optional[int] = None) -> PackedPopulation:
    """Pack N programs into one :class:`PackedPopulation`.

    ``programs`` — anything :func:`prepare` accepts, one per scenario.
    ``n_fu`` — shared spec (int / per-class tuple) or one entry per
    scenario.  ``policy`` — shared :class:`SchedPolicy`, one per scenario,
    or ``None`` (each program's attached policy, then ``params.policy``).
    ``fu_cost`` — shared per-(class, unit) cost-table spec
    (``costs.norm_fu_cost`` forms) or one per scenario; ``None`` falls
    back to ``params.fu_cost`` (all ones if unset — homogeneous pools).
    ``max_prog`` — the shared table shape; defaults to the population's
    :func:`prog_bucket`.  ``max_streams`` — the shared frontend-stream
    table width; defaults to the population's widest stream set.  The
    stream count is a compilation *shape* (like ``max_prog``), so callers
    that must keep one compiled machine across batches — the serving
    engine's bucket cache — pin it explicitly; extra rows are inactive
    padding (``end <= start``, never fetched).  All scenarios share
    ``params`` capacities (the machine is compiled once per
    ``(params, costs, shapes)``).
    """
    preps = tuple(prepare(p) for p in programs)
    if not preps:
        raise ValueError("pack_population needs at least one program")
    n = len(preps)

    tables = [isa.decode_table(p.code) for p in preps]
    longest = max(len(t) for t in tables)
    if max_prog is None:
        max_prog = prog_bucket(longest)
    elif longest > max_prog:
        which = preps[max(range(n), key=lambda i: len(tables[i]))].name
        raise ValueError(f"program {which!r} length {longest} > max_prog "
                         f"{max_prog}")

    ftab = np.zeros((n, max_prog, tables[0].shape[1]), np.int32)
    p_len = np.zeros((n,), np.int32)
    for i, t in enumerate(tables):
        ftab[i, :len(t)] = t
        p_len[i] = len(t)

    mem = np.zeros((n, params.total_mem), np.int32)
    eff = np.zeros((n, params.total_mem), np.int32)
    for i, p in enumerate(preps):
        mem[i], eff[i] = machine.images(params, p.mem_init, p.effects)

    pols = _broadcast_policy(policy, preps, params)
    prio = np.stack([pol.weight_array() for pol in pols]).astype(np.int32)
    quota = np.stack([pol.quota_array() for pol in pols]).astype(np.int32)
    rs_cap = np.stack([pol.rs_cap_array() for pol in pols]).astype(np.int32)
    eft = np.asarray([1 if pol.issue_mode == "eft" else 0 for pol in pols],
                     np.int32)

    # per-scenario frontend stream tables, padded to the batch's widest
    # stream count with inactive rows (end <= start: arrived-and-drained,
    # semantics-free like the ftab padding)
    tabs = [(p.streams.table(pol) if p.streams is not None
             else StreamSet.single(int(p_len[i])).table())
            for i, (p, pol) in enumerate(zip(preps, pols))]
    max_ns = max(len(t) for t in tabs)
    if max_streams is not None:
        if max_ns > max_streams:
            raise ValueError(f"population has a {max_ns}-stream scenario > "
                             f"max_streams {max_streams}")
        max_ns = int(max_streams)
    streams = np.zeros((n, max_ns, len(STREAM_FIELDS)), np.int32)
    for i, t in enumerate(tabs):
        streams[i, :len(t)] = t

    return PackedPopulation(
        names=tuple(p.name for p in preps), preps=preps, policies=pols,
        ftab=ftab, p_len=p_len, mem=mem, eff=eff,
        n_fu=_broadcast_n_fu(n_fu, n), prio=prio, quota=quota,
        rs_cap=rs_cap, fu_cost=_broadcast_fu_cost(fu_cost, n, params),
        eft=eft, streams=streams, max_prog=int(max_prog),
        # the policy/cost tables above are the runtime truth — strip the
        # params copies so one compiled machine serves every policy and
        # cost profile in the batch
        params=dataclasses.replace(params, policy=SchedPolicy(),
                                   fu_cost=None))
