"""JAX version-compatibility shims shared across the repo.

One place for the "which spelling does this JAX have" dance, so every
module that wants ``shard_map`` (the pipeline executor in
``sched/pipeline.py``, the scenario-axis sharder in ``hts/shard.py``)
resolves it the same way instead of inlining its own fallback.
"""
from __future__ import annotations

import jax


def shard_map(body, *, mesh, in_specs, out_specs, check: bool = False):
    """``shard_map`` across JAX spellings.

    JAX >= 0.6 exposes ``jax.shard_map`` (validity flag ``check_vma``);
    earlier releases ship ``jax.experimental.shard_map.shard_map`` (flag
    ``check_rep``).  ``check`` maps onto whichever flag exists — the
    callers here compute per-shard outputs with no cross-device
    replication invariant, so it defaults off.
    """
    if hasattr(jax, "shard_map"):                   # jax >= 0.6 spelling
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(body, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check)
