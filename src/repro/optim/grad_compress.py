"""Int8 gradient compression with error feedback (distributed-optimization
trick for the slow cross-pod axis).

Inside the train step (under ``shard_map`` over the gradient-sync axes), each
shard quantizes its local gradient block to int8 with a globally-agreed scale,
all-reduces the int8 payload (as int32 accumulators), and dequantizes.  The
quantization residual is carried in the optimizer state and added back next
step (error feedback), which keeps SGD/Adam convergence (Karimireddy et al.,
EF-SGD) while cutting cross-pod gradient bytes 4×.

The compiled effect visible in the dry-run HLO: the ``pod``-axis all-reduce
operand dtype drops from f32 to s8/s32 — see EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize(g, axis_name: str):
    """Quantize ``g`` to int8 with a pmax-agreed per-tensor scale."""
    absmax = jax.lax.pmax(jnp.max(jnp.abs(g)), axis_name)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(g, axis_name: str):
    """int8-compressed all-reduce of ``g`` over ``axis_name``.

    Returns (mean gradient, residual error for feedback).
    """
    q, scale = quantize(g.astype(jnp.float32), axis_name)
    deq_local = q.astype(jnp.float32) * scale
    err = g.astype(jnp.float32) - deq_local
    total = jax.lax.psum(q.astype(jnp.int32), axis_name).astype(jnp.float32)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return total * scale / n, err


def make_compressed_grad_sync(mesh, axis_name: str = "pod"):
    """Returns grad_sync(local_grads, err_state) → (synced, new_err) running
    under shard_map over the full mesh (gradient tensors arrive sharded;
    only the ``axis_name`` reduction is replaced by the compressed one)."""
    from jax.experimental.shard_map import shard_map

    def sync_leaf(g, e):
        mean, err = compressed_psum(g + e, axis_name)
        return mean, err

    def sync(grads, errs):
        return jax.tree.map(
            lambda g, e: sync_leaf(g, e), grads, errs,
        )

    # note: callers wrap this in shard_map with per-leaf PartitionSpecs.
    return sync
