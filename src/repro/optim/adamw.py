"""Functional AdamW with global-norm clipping and sharded state.

Optimizer state mirrors the parameter tree, so the same PartitionSpecs apply
leaf-for-leaf (ZeRO-style: fsdp-sharded params ⇒ fsdp-sharded m/v).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4                 # peak; scheduled externally
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def init(params) -> dict[str, Any]:
    zeros = lambda: jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros(), "v": zeros(), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def update(grads, state, params, cfg: AdamWConfig, lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = cfg.lr * lr_scale

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def leaf(g, m, v, p):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p
        return p - lr * upd, m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [leaf(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr, "clip": clip}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
