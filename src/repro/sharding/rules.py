"""Logical-axis sharding rules (MaxText-style), with automatic divisibility
fallback and per-(arch × shape) overrides.

Every tensor in the framework carries *logical* axis names; rules map them to
mesh axes.  ``spec_for(shape, axes)`` silently drops a mapping whose mesh-axis
product does not divide the dimension (replicating instead) and records the
drop — a framework must not hard-fail because e.g. kv_heads=8 < model=16.

Default mapping rationale (DESIGN.md §7):
  * ``batch``     → ("pod", "data")   — plain data parallelism across pods;
  * weight fsdp axes (``embed_fsdp``) → "data" — ZeRO-3 style weight/optimizer
    sharding over the *intra-pod* data axis only, so the per-layer weight
    all-gathers ride the fast intra-pod ICI and only gradient all-reduces
    cross the pod axis;
  * ``heads``/``mlp``/``vocab``/``expert`` → "model" — tensor parallelism;
  * ``kv_seq``    → "data" only for single-sequence long-context decode.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

LogicalAxes = tuple[Optional[str], ...]

DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": (),
    "kv_seq": (),
    "embed": (),
    "embed_fsdp": ("data",),       # weight dim carrying the ZeRO shard
    "heads": ("model",),
    "kv_heads": ("model",),        # auto-dropped when not divisible
    "head_dim": (),
    "mlp": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    "layers": (),
    "state": (),
    "frames": (),
    "cache_batch": ("data",),
    "stage": ("stage",),
}

#: per-shape overrides (merged over DEFAULT_RULES by the launch layer)
LONG_CONTEXT_RULES = {
    "cache_batch": (),             # batch=1: can't shard batch…
    "kv_seq": ("data",),           # …shard the KV length instead (SP)
}

#: §Perf (decode_kv_seq_shard): when kv_heads cannot shard over "model"
#: (GQA kv ∈ {1, 2, 8} < 16), shard the cache's sequence axis there instead —
#: the duplicate-axis guard in ``spec_for`` keeps whichever binds first, so
#: archs with shardable kv_heads are unaffected.
DECODE_OPT_RULES = {
    "kv_seq": ("model",),
}

#: §Perf iteration 2 (decode): ZeRO/fsdp weight sharding is wrong for serving
#: — it all-gathers the full weights every step.  Inference-TP instead:
#: weights 2D-sharded over (model × data) on their output dims, activations
#: (tiny at decode) gathered instead of weights.  Activation constraints bind
#: "data" to batch first, so only *weight* tensors pick up the extra axis
#: (duplicate-axis guard).
DECODE_OPT2_RULES = {
    "kv_seq": ("model",),
    "embed_fsdp": (),
    "heads": ("model", "data"),
    "mlp": ("model", "data"),
    "vocab": ("model", "data"),
    "expert": ("model", "data"),
}


@dataclasses.dataclass
class Rules:
    mapping: dict[str, tuple[str, ...]]
    mesh: Optional[Mesh] = None
    dropped: set = dataclasses.field(default_factory=set)

    def _axes_in_mesh(self, axes: tuple[str, ...]) -> tuple[str, ...]:
        if self.mesh is None:
            return axes
        return tuple(a for a in axes if a in self.mesh.axis_names)

    def spec_for(self, shape: tuple[int, ...], axes: LogicalAxes) -> PartitionSpec:
        """PartitionSpec for a tensor of ``shape`` with logical ``axes``.

        Drops (→ replicate) any mapping whose mesh-axis product does not
        divide the dim, and any mesh axis already consumed by an earlier dim
        of the same tensor (first binding wins); both are recorded in
        ``self.dropped``.
        """
        assert len(shape) == len(axes), (shape, axes)
        parts: list[Any] = []
        used: set[str] = set()
        for dim, name in zip(shape, axes):
            if name is None:
                parts.append(None)
                continue
            mesh_axes = self._axes_in_mesh(self.mapping.get(name, ()))
            if any(a in used for a in mesh_axes):
                self.dropped.add((name, dim, mesh_axes, "duplicate"))
                mesh_axes = tuple(a for a in mesh_axes if a not in used)
            if not mesh_axes:
                parts.append(None)
                continue
            size = 1
            if self.mesh is not None:
                for a in mesh_axes:
                    size *= self.mesh.shape[a]
            if self.mesh is not None and dim % size != 0:
                self.dropped.add((name, dim, mesh_axes))
                parts.append(None)
                continue
            used.update(mesh_axes)
            parts.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
        return PartitionSpec(*parts)

    def sharding_for(self, shape, axes) -> NamedSharding:
        assert self.mesh is not None
        return NamedSharding(self.mesh, self.spec_for(shape, axes))


_ctx = threading.local()


def current() -> Optional[Rules]:
    return getattr(_ctx, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Rules):
    prev = getattr(_ctx, "rules", None)
    _ctx.rules = rules
    try:
        yield rules
    finally:
        _ctx.rules = prev


def make_rules(mesh: Optional[Mesh] = None,
               overrides: Optional[dict[str, tuple[str, ...]]] = None) -> Rules:
    mapping = dict(DEFAULT_RULES)
    mapping.update(overrides or {})
    return Rules(mapping=mapping, mesh=mesh)


def constraint(x: jax.Array, axes: LogicalAxes) -> jax.Array:
    """Annotate activation ``x`` with logical ``axes`` under the active rules.

    No-op when no rules context is active (unit tests, single-device smoke).
    """
    rules = current()
    if rules is None or rules.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, rules.sharding_for(x.shape, axes))
