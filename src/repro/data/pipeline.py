"""Deterministic, restart-exact data pipeline.

Batches are a pure function of (seed, step): after a failure/restart the
pipeline resumes from the checkpointed step with bit-identical batches — a
prerequisite for exactly-resumable training (tested in
tests/test_fault_tolerance.py).  Two sources:

  * ``SyntheticLM`` — hashed token streams (throughput/dry-run work);
  * ``CorpusLM``    — a memory-mapped token file, sampled with a
    step-deterministic RNG (the real-data path).

Per-host sharding: each process materializes only its slice of the global
batch (``host_slice``); a background prefetch thread hides host latency.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    corpus_path: Optional[str] = None


class SyntheticLM:
    """tokens[b, t] = hash(seed, step, b, t) mod vocab — cheap and exact."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int, host_slice: slice = slice(None)) -> dict:
        cfg = self.cfg
        rows = range(*host_slice.indices(cfg.global_batch))
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step]))
        toks = rng.integers(0, cfg.vocab,
                            (cfg.global_batch, cfg.seq_len + 1), np.int32)
        toks = toks[list(rows)]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class CorpusLM:
    """Memory-mapped token corpus with deterministic step-indexed sampling."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.data = np.load(cfg.corpus_path, mmap_mode="r")
        assert self.data.ndim == 1

    def batch(self, step: int, host_slice: slice = slice(None)) -> dict:
        cfg = self.cfg
        n = len(self.data) - cfg.seq_len - 1
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step]))
        starts = rng.integers(0, n, (cfg.global_batch,))
        rows = range(*host_slice.indices(cfg.global_batch))
        toks = np.stack([self.data[s:s + cfg.seq_len + 1]
                         for s in starts[list(rows)]]).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_source(cfg: DataConfig):
    return CorpusLM(cfg) if cfg.corpus_path else SyntheticLM(cfg)


def prefetch(source, start_step: int, host_slice: slice = slice(None),
             depth: int = 2) -> Iterator[tuple[int, dict]]:
    """Background-thread prefetch of (step, batch) pairs."""
    q: queue.Queue = queue.Queue(maxsize=depth)
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            try:
                q.put((step, source.batch(step, host_slice)), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()


def shard_batch(batch: dict, sharding) -> dict:
    """Place a host batch onto devices under the given NamedSharding tree."""
    return jax.tree.map(
        lambda x, s: jax.device_put(jnp.asarray(x), s), batch, sharding)
