"""Production mesh construction (assignment MULTI-POD DRY-RUN step 1).

A function, not a module-level constant, so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_for(devices: int, model_parallel: int = 1,
                  pods: int = 1):
    """Small-scale mesh helper for tests (e.g. 8 host devices)."""
    data = devices // (model_parallel * pods)
    if pods > 1:
        return jax.make_mesh((pods, data, model_parallel),
                             ("pod", "data", "model"))
    return jax.make_mesh((data, model_parallel), ("data", "model"))
