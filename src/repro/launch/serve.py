"""Serving launcher: continuous-batching server with optional speculative
decoding, over any ``--arch`` (smoke-sized on CPU).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --requests 16 --slots 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.core.sched import serving
from repro.models import registry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--policy", default="ooo", choices=["ooo", "naive"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = registry.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    srv = serving.Server(model, params, n_slots=args.slots,
                         max_len=args.max_len, policy=args.policy)
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, int(rng.integers(2, 8))).tolist()
        srv.submit(serving.Request(i, prompt,
                                   int(rng.integers(2, args.max_new))))
    t0 = time.perf_counter()
    stats = srv.run()
    dt = time.perf_counter() - t0
    print(f"policy={args.policy} completed={stats.completed} "
          f"steps={stats.steps} utilization={stats.utilization(args.slots):.2f} "
          f"wall={dt:.1f}s tok/s={stats.slot_busy_steps / max(dt, 1e-9):.0f}")


if __name__ == "__main__":
    main()
