import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax-importing module (jax locks the
# device count at first init).  Tests shrink the pool via this env override:
if "REPRO_DRYRUN_DEVICES" in os.environ:                         # noqa: E402
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture × input shape × mesh) cell; record memory/cost analysis and
collective traffic for the roofline table (deliverable g).

FLOP/byte accounting: XLA's HloCostAnalysis counts a while-loop body ONCE, so
scan-over-layers graphs under-report by ~n_layers×.  Each cell therefore also
compiles two (three for hybrid) small *probe* models with the layer scan fully
unrolled; per-layer body cost = Δcost/Δlayers, and the corrected total is
``fixed + units×body``.  Kernels are routed to their loop-free jnp references
during dry-run lowering (ops.KERNELS_ENABLED=False) so attention/SSM math is
exactly countable.  Raw and corrected figures are both recorded.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
        --shape train_4k --mesh single --out experiments/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse                                                  # noqa: E402
import contextlib                                                # noqa: E402
import dataclasses                                               # noqa: E402
import json                                                      # noqa: E402
import time                                                      # noqa: E402
import traceback                                                 # noqa: E402

import jax                                                       # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec            # noqa: E402

from repro.configs.base import SHAPES, applicable_shapes         # noqa: E402
from repro.configs.registry import all_archs, get_config         # noqa: E402
from repro.kernels import ops as kops                            # noqa: E402
from repro.launch.mesh import make_production_mesh               # noqa: E402
from repro.models import layers as Lmod                          # noqa: E402
from repro.models import registry                                # noqa: E402
from repro.roofline import analysis, hlo_collectives             # noqa: E402
from repro.runtime import flags as flags_lib                     # noqa: E402
from repro.runtime import train as train_rt                      # noqa: E402
from repro.sharding import rules as rules_lib                    # noqa: E402


@contextlib.contextmanager
def dryrun_mode(unroll: bool = False):
    """Loop-free kernels (exact counting); optionally unroll layer scans."""
    prev_k, prev_u = kops.KERNELS_ENABLED, Lmod.SCAN_UNROLL
    kops.KERNELS_ENABLED = False
    Lmod.SCAN_UNROLL = unroll
    try:
        yield
    finally:
        kops.KERNELS_ENABLED = prev_k
        Lmod.SCAN_UNROLL = prev_u


def rules_for(mesh, shape_name: str, opt: int = 0):
    overrides = {}
    if shape_name == "long_500k":
        overrides = dict(rules_lib.LONG_CONTEXT_RULES)
    elif opt and SHAPES[shape_name].kind == "decode":
        overrides = dict(rules_lib.DECODE_OPT2_RULES if opt >= 2
                         else rules_lib.DECODE_OPT_RULES)
    return rules_lib.make_rules(mesh, overrides)


def _shard(mesh, tree_specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, PartitionSpec))


def lower_with_cfg(cfg, shape, mesh, rules):
    """Lower + compile one (cfg × shape) under ``rules``; returns compiled."""
    model = registry.build(cfg)

    if shape.kind == "train":
        tcfg = train_rt.TrainConfig(
            remat_policy=os.environ.get("REPRO_DRYRUN_REMAT", "nothing"))
        batch = model.input_specs(shape)
        state = train_rt.abstract_state(model)
        step = train_rt.jit_train_step(model, mesh, rules, tcfg, batch)
        lowered = step.lower(state, batch)
    elif shape.kind == "prefill":
        batch = model.input_specs(shape)
        pspecs = _shard(mesh, model.param_pspecs(rules))
        bspecs = _shard(mesh, train_rt.batch_pspecs(batch, rules))

        def prefill_step(params, b):
            with rules_lib.use_rules(rules):
                return _prefill_logits(model, params, b)

        fn = jax.jit(prefill_step, in_shardings=(pspecs, bspecs),
                     out_shardings=None)
        lowered = fn.lower(model.abstract_params(), batch)
    else:   # decode
        inp = model.input_specs(shape)
        cache = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len))
        pspecs = _shard(mesh, model.param_pspecs(rules))
        cspecs = _shard(mesh, model.cache_pspecs(shape.global_batch,
                                                 shape.seq_len, rules))
        tspec = _shard(mesh, rules.spec_for((shape.global_batch, 1),
                                            ("cache_batch", None)))

        def serve_step(params, cache, tokens, pos):
            with rules_lib.use_rules(rules):
                return model.decode_step(params, cache, tokens, pos)

        fn = jax.jit(serve_step,
                     in_shardings=(pspecs, cspecs, tspec, None),
                     out_shardings=(None, cspecs))
        lowered = fn.lower(model.abstract_params(), cache, inp["tokens"],
                           inp["pos"])
    return lowered.compile()


def _prefill_logits(model, params, batch):
    """Family-uniform prefill: full-prompt forward, last-position logits."""
    from repro.models import rwkv6, transformer, whisper, zamba2
    cfg = model.cfg
    if cfg.family in ("dense", "moe"):
        logits, cache = transformer.prefill(params, cfg, batch["tokens"],
                                            batch["tokens"].shape[1])
        return logits[:, -1]
    if cfg.family == "vlm":
        logits, _ = transformer.forward(params, cfg, batch["tokens"],
                                        batch["prefix_embeds"])
        return logits[:, -1]
    if cfg.family == "ssm":
        logits, _ = rwkv6.forward(params, cfg, batch["tokens"])
        return logits[:, -1]
    if cfg.family == "hybrid":
        logits, _ = zamba2.forward(params, cfg, batch["tokens"])
        return logits[:, -1]
    enc = whisper.encode(params, cfg, batch["frames"])
    return whisper.decode_seq(params, cfg, batch["tokens"], enc)[:, -1]


def _cell_costs(compiled):
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):     # older jax: one dict per program
        cost = cost[0] if cost else {}
    cost = dict(cost)
    coll = hlo_collectives.collective_bytes_per_device(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            float(coll["total_per_device"]), coll)


def _probe_cfgs(cfg):
    """Probe configs + true-unit count for the layer-scan correction.

    Returns (list of (cfg_variant, units), units_true) where cost(variant) =
    fixed + units×body is solved for (fixed, body).
    """
    if cfg.family == "hybrid":
        p = cfg.shared_attn_period
        n_groups = cfg.n_layers // p
        tail = cfg.n_layers - n_groups * p
        mk = lambda L: dataclasses.replace(cfg, n_layers=L)
        # group-units; the 3-layer tail is probed exactly as a 3rd variant
        variants = [(mk(p), 1), (mk(2 * p), 2)]
        extra = (mk(p + tail), 1) if tail else None
        return variants, float(n_groups), extra, tail
    if cfg.family == "audio":
        mk = lambda L: dataclasses.replace(cfg, n_layers=L, enc_layers=L)
        return [(mk(1), 1), (mk(2), 2)], float(cfg.n_layers), None, 0
    mk = lambda L: dataclasses.replace(cfg, n_layers=L)
    return [(mk(1), 1), (mk(2), 2)], float(cfg.n_layers), None, 0


def probe_correction(cfg, shape, mesh, rules):
    """(flops, bytes, coll) corrected totals per device via unrolled probes."""
    variants, units_true, extra, tail = _probe_cfgs(cfg)
    meas = []
    with dryrun_mode(unroll=True):
        for cfg_v, units in variants:
            comp = lower_with_cfg(cfg_v, shape, mesh, rules)
            f, b, c, _ = _cell_costs(comp)
            meas.append((units, f, b, c))
        tail_cost = (0.0, 0.0, 0.0)
        if extra is not None:
            comp = lower_with_cfg(extra[0], shape, mesh, rules)
            f, b, c, _ = _cell_costs(comp)
            base = meas[0]
            tail_cost = (f - base[1], b - base[2], c - base[3])
    (u0, f0, b0, c0), (u1, f1, b1, c1) = meas
    du = u1 - u0
    body = ((f1 - f0) / du, (b1 - b0) / du, (c1 - c0) / du)
    fixed = (f0 - u0 * body[0], b0 - u0 * body[1], c0 - u0 * body[2])
    total = tuple(fixed[i] + units_true * body[i] + tail_cost[i]
                  for i in range(3))
    return {
        "per_unit": {"flops": body[0], "bytes": body[1], "coll": body[2]},
        "fixed": {"flops": fixed[0], "bytes": fixed[1], "coll": fixed[2]},
        "units_true": units_true,
        "tail_layers": tail,
        "corrected_per_device": {"flops": total[0], "bytes": total[1],
                                 "coll": total[2]},
    }


def run_cell(arch: str, shape_name: str, mesh_name: str, out_dir: str,
             probe: bool = True, opt: bool = False) -> dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    cfg = get_config(arch)
    ok, reason = applicable_shapes(cfg)[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "chips": mesh.size, "opt": opt}
    perf_kw = flags_lib.optimized(opt) if opt else {}
    if not ok:
        rec.update(status="SKIP", reason=reason)
    else:
        try:
            shape = SHAPES[shape_name]
            rules = rules_for(mesh, shape_name, opt)
            with flags_lib.use_flags(**perf_kw), dryrun_mode():
                compiled = lower_with_cfg(cfg, shape, mesh, rules)
            flops_raw, bytes_raw, coll_raw, coll = _cell_costs(compiled)
            try:
                mem = compiled.memory_analysis()
                mem_stats = {
                    "argument_bytes": getattr(mem, "argument_size_in_bytes",
                                              None),
                    "output_bytes": getattr(mem, "output_size_in_bytes", None),
                    "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                    "peak_bytes": getattr(mem, "temp_size_in_bytes", None),
                }
            except Exception:
                mem_stats = {}

            model = registry.build(cfg)
            kind = shape.kind
            tokens = (shape.global_batch * shape.seq_len
                      if kind in ("train", "prefill") else shape.global_batch)
            mflops = analysis.model_flops(
                model.active_param_count(), tokens,
                "train" if kind == "train" else "serve")

            corr = None
            if probe:
                with flags_lib.use_flags(**perf_kw):
                    corr = probe_correction(cfg, shape, mesh, rules)
                # corrected totals cannot be below the once-counted raw
                # figures (guards probe-extrapolation noise on small cells)
                cdev = corr["corrected_per_device"]
                cdev["flops"] = max(cdev["flops"], flops_raw)
                cdev["bytes"] = max(cdev["bytes"], bytes_raw)
                cdev["coll"] = max(cdev["coll"], coll_raw)
                cost_dict = {"flops": cdev["flops"],
                             "bytes accessed": cdev["bytes"]}
                coll_corr = {"total_per_device": cdev["coll"],
                             "per_op": coll["per_op"],
                             "counts": coll["counts"]}
            else:
                cost_dict = {"flops": flops_raw, "bytes accessed": bytes_raw}
                coll_corr = coll

            roof = analysis.from_compiled(arch, shape_name, mesh_name,
                                          mesh.size, cost_dict, coll_corr,
                                          mflops, mem_stats)
            rec.update(status="OK",
                       kind=kind,
                       tokens_per_step=tokens,
                       params_total=model.param_count(),
                       params_active=model.active_param_count(),
                       raw_per_device={"flops": flops_raw, "bytes": bytes_raw,
                                       "collective": coll_raw},
                       probe=corr, memory=mem_stats, collectives=coll,
                       dropped_shardings=sorted(str(d) for d in rules.dropped),
                       roofline=roof.to_dict(),
                       compile_seconds=round(time.time() - t0, 1))
        except Exception as e:
            rec.update(status="FAIL", error=f"{type(e).__name__}: {e}",
                       traceback=traceback.format_exc()[-2000:])
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{arch}_{shape_name}_{mesh_name}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-probe", action="store_true",
                    help="skip the unrolled cost probes (faster)")
    ap.add_argument("--opt", type=int, default=0, nargs="?", const=1,
                    help="§Perf optimization level (1, 2)")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = all_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                fname = os.path.join(args.out,
                                     f"{arch}_{shape}_{mesh_name}.json")
                if args.skip_existing and os.path.exists(fname):
                    with open(fname) as f:
                        if json.load(f).get("status") in ("OK", "SKIP"):
                            print(f"[CACHED] {arch} × {shape} × {mesh_name}",
                                  flush=True)
                            continue
                rec = run_cell(arch, shape, mesh_name, args.out,
                               probe=not args.no_probe, opt=args.opt)
                status = rec["status"]
                extra = ""
                if status == "OK":
                    r = rec["roofline"]
                    extra = (f" bottleneck={r['bottleneck']}"
                             f" t=({r['t_compute']:.2e},{r['t_memory']:.2e},"
                             f"{r['t_collective']:.2e})s"
                             f" useful={r['useful_flops_ratio']:.2f}"
                             f" compile={rec['compile_seconds']}s")
                elif status == "FAIL":
                    n_fail += 1
                    extra = " " + rec["error"][:200]
                print(f"[{status}] {arch} × {shape} × {mesh_name}{extra}",
                      flush=True)
    if n_fail:
        raise SystemExit(f"{n_fail} cells failed")


if __name__ == "__main__":
    main()
