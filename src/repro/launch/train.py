"""Training launcher: ``--arch <id>`` + shape + mesh + fault tolerance.

On real hardware this runs under one process per host; on CPU it drives the
same code path with the local device set.  Restart-exact resume comes from
the (seed, step)-deterministic data pipeline + checkpointed state.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
        --steps 20 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse

import jax

from repro.configs.registry import get_config
from repro.data import pipeline as data_lib
from repro.models import registry
from repro.optim.adamw import AdamWConfig
from repro.runtime import train as train_rt
from repro.sharding import rules as rules_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="nothing",
                    choices=["none", "nothing", "dots"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    model = registry.build(cfg)
    print(f"arch={cfg.name} family={cfg.family} "
          f"params={model.param_count()/1e6:.1f}M "
          f"devices={len(jax.devices())}")

    tcfg = train_rt.TrainConfig(
        optimizer=AdamWConfig(lr=args.lr),
        remat_policy=args.remat,
        warmup_steps=min(20, args.steps),
        total_steps=args.steps,
        microbatches=args.microbatches,
        ckpt_every=args.ckpt_every)

    dcfg = data_lib.DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                               global_batch=args.batch)
    source = data_lib.make_source(dcfg)

    n_dev = len(jax.devices())
    if n_dev > 1:
        from repro.launch.mesh import make_mesh_for
        mesh = make_mesh_for(n_dev, model_parallel=args.model_parallel)
        rules = rules_lib.make_rules(mesh)
        batch0 = jax.eval_shape(lambda: source.batch(0))
        step_fn = train_rt.jit_train_step(model, mesh, rules, tcfg, batch0)
    else:
        step_fn = jax.jit(train_rt.make_train_step(model, tcfg),
                          donate_argnums=0)

    loop = train_rt.TrainLoop(
        model, source, step_fn, tcfg, args.ckpt_dir,
        init_fn=lambda: train_rt.init_state(model, jax.random.PRNGKey(0)))
    loop.run(args.steps)
    for h in loop.history[:3] + loop.history[-3:]:
        print({k: round(v, 4) for k, v in h.items()})


if __name__ == "__main__":
    main()
