"""The paper's own workload configuration: Table II accelerator set + default
HTS design parameters (see repro.core.hts)."""
from repro.core.hts.costs import FUNCTIONS, hts_costs  # noqa: F401  re-export
from repro.core.hts.golden import HtsParams            # noqa: F401

DEFAULT_N_FU = (2,) * 10
