"""Architecture / shape configuration dataclasses and the shape suite.

Every assigned architecture gets one module in this package defining ``CONFIG``
(exact published dims) — see registry.py for the ``--arch <id>`` lookup.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                  # per-expert FFN hidden dim
    num_shared: int = 0            # always-on shared experts (qwen2-moe)
    d_shared: int = 0              # hidden dim of the shared expert block
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: str                      # "rwkv6" | "mamba2"
    d_state: int = 64              # N (mamba2) / head key dim (rwkv6)
    head_dim: int = 64             # P (mamba2) / head value dim (rwkv6)
    conv_kernel: int = 4           # mamba2 depthwise conv width
    expand: int = 2                # mamba2 inner expansion


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                # 0 → d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    shared_attn_period: int = 0    # zamba2: shared attn block every k layers
    n_shared_blocks: int = 0       # zamba2: alternating shared blocks
    enc_layers: int = 0            # whisper: encoder depth (n_layers = decoder)
    prefix_len: int = 0            # paligemma: image-token prefix length
    source: str = ""               # provenance note ([arXiv/hf; tier])

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """May run the long_500k cell (SSM/linear-attn/hybrid)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode(self) -> bool:
        return True                # all assigned archs are decoder-bearing

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        replace = dict(
            n_layers=min(self.n_layers, 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads * 4 // self.n_heads)),
            d_ff=256,
            vocab=512,
            d_head=32,
            enc_layers=min(self.enc_layers, 2),
            prefix_len=min(self.prefix_len, 8),
            shared_attn_period=2 if self.shared_attn_period else 0,
            n_shared_blocks=min(self.n_shared_blocks, 2),
        )
        if self.moe:
            replace["moe"] = MoEConfig(
                num_experts=8, top_k=2, d_expert=64,
                num_shared=min(self.moe.num_shared, 1),
                d_shared=64 if self.moe.num_shared else 0)
        if self.ssm:
            replace["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=16)
        return dataclasses.replace(self, **replace)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(arch: ArchConfig) -> dict[str, tuple[bool, str]]:
    """shape name → (runs?, reason-if-skipped). 40-cell bookkeeping."""
    out = {}
    for name, sh in SHAPES.items():
        if name == "long_500k" and not arch.sub_quadratic:
            out[name] = (False, "full quadratic attention — 500k KV "
                                "infeasible; run only for SSM/hybrid "
                                "(DESIGN.md §5)")
        else:
            out[name] = (True, "")
    return out
