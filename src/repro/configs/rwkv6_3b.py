"""rwkv6-3b (Finch): attention-free, data-dependent decay [arXiv:2404.05892; hf]."""
from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,   # 40 wkv heads × 64
    d_ff=8960, vocab=65536, d_head=64,
    ssm=SSMConfig(kind="rwkv6", d_state=64, head_dim=64),
    source="[arXiv:2404.05892; hf]",
)
