"""paligemma-3b: SigLIP frontend (stubbed) + gemma MQA decoder [arXiv:2407.07726; hf].

The vision tower is a STUB per the assignment: input_specs() provides
precomputed patch embeddings (B, 256, d_model); the transformer backbone is
what is modeled (prefix-LM attention over the image prefix).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
    d_ff=16384, vocab=257216, d_head=256, prefix_len=256,
    source="[arXiv:2407.07726; hf]",
)
