"""qwen2-moe-a2.7b: 4 shared + 60 routed experts, top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B; hf].

60 routed experts are padded to 64 for even expert-parallel sharding over the
16-way model axis (padding experts receive no tokens; DESIGN.md §5).
"""
from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=151936, qkv_bias=True,
    moe=MoEConfig(num_experts=60, top_k=4, d_expert=1408,
                  num_shared=4, d_shared=4 * 1408),
    source="[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]",
)
