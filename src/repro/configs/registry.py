"""--arch <id> registry for the assigned architecture pool."""
from __future__ import annotations

import importlib

from .base import ArchConfig

ARCHS = {
    "yi-34b": "yi_34b",
    "command-r-plus-104b": "command_r_plus_104b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "qwen2-1.5b": "qwen2_1_5b",
    "rwkv6-3b": "rwkv6_3b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "paligemma-3b": "paligemma_3b",
    "zamba2-7b": "zamba2_7b",
    "whisper-base": "whisper_base",
}


def get_config(arch: str) -> ArchConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.CONFIG


def all_archs() -> list[str]:
    return list(ARCHS)
