"""whisper-base: enc-dec; conv frontend STUBBED — input_specs() provides
precomputed frame embeddings (B, T, d_model) [arXiv:2212.04356; unverified]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51865, enc_layers=6,
    source="[arXiv:2212.04356; unverified]",
)
