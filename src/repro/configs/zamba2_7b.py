"""zamba2-7b: Mamba2 backbone + 2 alternating shared attention blocks
[arXiv:2411.15242; unverified]."""
from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000,
    ssm=SSMConfig(kind="mamba2", d_state=64, head_dim=64, expand=2),
    shared_attn_period=6, n_shared_blocks=2,
    source="[arXiv:2411.15242; unverified]",
)
