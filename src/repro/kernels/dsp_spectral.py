"""Pallas TPU kernels for the spectral accelerators: 256-pt FFT and DCT.

TPU adaptation (DESIGN.md §3): the paper's FFT accelerator is a radix-2
in-place butterfly ASIC.  On TPU we keep the radix-2 dataflow but express each
stage as *static* reshapes + vector FMAs over a batch of frames (the butterfly
index arithmetic becomes layout, which the Mosaic compiler handles as cheap
relayouts), and the bit-reversal permutation as a static gather.  The DCT is
the textbook MXU case: a (64, 64) coefficient matmul.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .common import INTERPRET

BB = 128     # frames per grid step


def _bitrev(n: int) -> np.ndarray:
    bits = n.bit_length() - 1
    idx = np.arange(n)
    rev = np.zeros_like(idx)
    for b in range(bits):
        rev |= ((idx >> b) & 1) << (bits - 1 - b)
    return rev


def _twiddle_tables(N: int) -> tuple[np.ndarray, np.ndarray]:
    """(stages, N/2) twiddle tables; stage s uses the first 2^s entries."""
    stages = N.bit_length() - 1
    twr = np.zeros((stages, N // 2), np.float32)
    twi = np.zeros((stages, N // 2), np.float32)
    for s in range(stages):
        m = 1 << s
        tw = np.exp(-2j * np.pi * np.arange(m) / (2 * m))
        twr[s, :m], twi[s, :m] = tw.real, tw.imag
    return twr, twi


def _fft_kernel(xr_ref, xi_ref, twr_ref, twi_ref, or_ref, oi_ref, *, N: int):
    # inputs arrive bit-reverse permuted (static relayout done by the wrapper,
    # where XLA fuses it into the HBM→VMEM stream)
    stages = N.bit_length() - 1
    xr = xr_ref[...]
    xi = xi_ref[...]
    bb = xr.shape[0]
    for s in range(stages):
        m = 1 << s                      # butterfly half-span
        g = N // (2 * m)                # groups
        twr = twr_ref[s, :m].astype(xr.dtype)
        twi = twi_ref[s, :m].astype(xr.dtype)
        xr4 = xr.reshape(bb, g, 2, m)
        xi4 = xi.reshape(bb, g, 2, m)
        er, ei = xr4[:, :, 0, :], xi4[:, :, 0, :]
        orr, oii = xr4[:, :, 1, :], xi4[:, :, 1, :]
        tr = orr * twr - oii * twi      # twiddled odd
        ti = orr * twi + oii * twr
        xr = jnp.concatenate([(er + tr)[:, :, None, :],
                              (er - tr)[:, :, None, :]], axis=2).reshape(bb, N)
        xi = jnp.concatenate([(ei + ti)[:, :, None, :],
                              (ei - ti)[:, :, None, :]], axis=2).reshape(bb, N)
    or_ref[...] = xr
    oi_ref[...] = xi


def fft(x: jax.Array) -> jax.Array:
    """Radix-2 complex FFT. x: (B, N, 2) re/im, N power of two → (B, N, 2)."""
    B, N, _ = x.shape
    assert N & (N - 1) == 0, "radix-2 FFT needs a power-of-two frame"
    stages = N.bit_length() - 1
    twr, twi = _twiddle_tables(N)
    x = x[:, _bitrev(N), :]       # bit-reversal pre-pass (see kernel docstring)
    yr, yi = pl.pallas_call(
        functools.partial(_fft_kernel, N=N),
        grid=(pl.cdiv(B, BB),),
        in_specs=[pl.BlockSpec((BB, N), lambda i: (i, 0)),
                  pl.BlockSpec((BB, N), lambda i: (i, 0)),
                  pl.BlockSpec((stages, N // 2), lambda i: (0, 0)),
                  pl.BlockSpec((stages, N // 2), lambda i: (0, 0))],
        out_specs=[pl.BlockSpec((BB, N), lambda i: (i, 0)),
                   pl.BlockSpec((BB, N), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((B, N), x.dtype),
                   jax.ShapeDtypeStruct((B, N), x.dtype)],
        interpret=INTERPRET,
    )(x[..., 0], x[..., 1], jnp.asarray(twr), jnp.asarray(twi))
    return jnp.stack([yr, yi], axis=-1)


def fft_256(x: jax.Array) -> jax.Array:
    assert x.shape[1] == 256
    return fft(x)


# ---------------------------------------------------------------------------
# DCT-II as an MXU matmul
# ---------------------------------------------------------------------------
def _dct_kernel(x_ref, m_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], m_ref[...],
                         preferred_element_type=jnp.float32).astype(o_ref.dtype)


def dct(x: jax.Array, mat: jax.Array) -> jax.Array:
    """x: (B, N) @ matᵀ: (N, N) — mat is ref.dct_matrix(N); returns (B, N)."""
    B, N = x.shape
    return pl.pallas_call(
        _dct_kernel,
        grid=(pl.cdiv(B, BB),),
        in_specs=[pl.BlockSpec((BB, N), lambda i: (i, 0)),
                  pl.BlockSpec((N, N), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((BB, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, N), x.dtype),
        interpret=INTERPRET,
    )(x, mat.T)
