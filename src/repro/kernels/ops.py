"""Public jit'd wrappers over the Pallas kernels (padding, head flattening,
GQA repeat, fallbacks).  Models call these, never pallas_call directly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import attention as _attn
from . import dsp_fir as _fir
from . import dsp_spectral as _spec
from . import dsp_vector as _vec
from . import mamba2 as _m2
from . import ref
from . import rmsnorm as _rms
from . import rwkv6 as _rwkv
from .common import round_up

#: When False (set by the dry-run), the transformer-family ops route to their
#: loop-free jnp references so XLA's HloCostAnalysis counts every FLOP exactly
#: (Pallas interpret-mode kernels lower to host while-loops whose bodies the
#: analysis counts once).  The runtime path keeps kernels on.
KERNELS_ENABLED = True


def _pad_rows(x, mult):
    r = x.shape[0]
    pad = round_up(r, mult) - r
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return x, r


def _make_ref_bwd(fast_fn, ref_fn):
    """custom_vjp: Pallas kernel forward, reference-VJP backward.

    Residuals are just the primal inputs (remat-style): the backward pass
    re-runs the pure-jnp reference forward under ``jax.vjp``, so gradients are
    exactly the reference gradients while the forward stays on the kernel.
    (Hand-written backward kernels are a recorded §Perf follow-up.)
    """
    @jax.custom_vjp
    def f(*args):
        return fast_fn(*args)

    def fwd(*args):
        return fast_fn(*args), args

    def bwd(res, g):
        _, vjp = jax.vjp(ref_fn, *res)
        return vjp(g)

    f.defvjp(fwd, bwd)
    return f


# ---------------------------------------------------------------------------
# DSP ops (Table II accelerator functions)
# ---------------------------------------------------------------------------
@jax.jit
def real_fir(x, h):
    xp, r = _pad_rows(x, _fir.BB)
    return _fir.real_fir(xp, h)[:r]


@jax.jit
def complex_fir(x, h):
    xp, r = _pad_rows(x, _fir.BB)
    return _fir.complex_fir(xp, h)[:r]


@functools.partial(jax.jit, static_argnames=("K", "mu"))
def adaptive_fir(x, d, mu, K):
    xp, r = _pad_rows(x, _fir.BB)
    dp, _ = _pad_rows(d, _fir.BB)
    return _fir.adaptive_fir(xp, dp, mu, K)[:r]


@jax.jit
def iir(x, b, a):
    xp, r = _pad_rows(x, _fir.BB)
    return _fir.iir(xp, b, a)[:r]


@jax.jit
def vector_dot(x, y):
    xp, r = _pad_rows(x, _vec.BB)
    yp, _ = _pad_rows(y, _vec.BB)
    return _vec.vector_dot(xp, yp)[:r]


@jax.jit
def vector_add(x, y):
    xp, r = _pad_rows(x, _vec.BB)
    yp, _ = _pad_rows(y, _vec.BB)
    return _vec.vector_add(xp, yp)[:r]


@jax.jit
def vector_max(x):
    xp, r = _pad_rows(x, _vec.BB)
    # pad rows are zero; true rows are what we slice back out
    return _vec.vector_max(xp)[:r]


@functools.partial(jax.jit, static_argnames=("max_lag",))
def correlation(x, y, max_lag):
    xp, r = _pad_rows(x, _vec.BB)
    yp, _ = _pad_rows(y, _vec.BB)
    return _vec.correlation(xp, yp, max_lag)[:r]


@jax.jit
def fft_256(x):
    xp, r = _pad_rows(x, _spec.BB)
    return _spec.fft_256(xp)[:r]


@jax.jit
def dct(x):
    xp, r = _pad_rows(x, _spec.BB)
    mat = ref.dct_matrix(x.shape[-1], x.dtype)
    return _spec.dct(xp, mat)[:r]


#: accelerator-id → executable op, mirroring costs.FUNCTIONS.  Used by the
#: end-to-end DSP example that *actually runs* the HTS schedule on TPU kernels.
def dsp_dispatch_table():
    return {
        "real_fir": lambda x: real_fir(x, jnp.ones((8,), x.dtype) / 8),
        "complex_fir": lambda x: complex_fir(
            jnp.stack([x, x], -1), jnp.ones((8, 2), x.dtype) / 8)[..., 0],
        "adaptive_fir": lambda x: adaptive_fir(x, x, 0.01, 8),
        "iir": lambda x: iir(x, jnp.asarray([0.2, 0.3], x.dtype),
                             jnp.asarray([1.0, -0.5], x.dtype)),
        "vector_dot": lambda x: vector_dot(x, x)[:, None] * jnp.ones_like(x),
        "vector_add": lambda x: vector_add(x, x),
        "vector_max": lambda x: vector_max(x)[:, None] * jnp.ones_like(x),
        "fft_256": lambda x: _fft_frame(x),
        "dct": lambda x: dct(_fit(x, 64))[:, : x.shape[1]],
        "correlation": lambda x: correlation(x, x, 4)[:, :1] * jnp.ones_like(x),
    }


def _fit(x, n):
    cur = x.shape[1]
    if cur < n:
        return jnp.pad(x, ((0, 0), (0, n - cur)))
    return x[:, :n]


def _fft_frame(x):
    z = _fit(x, 256)
    out = fft_256(jnp.stack([z, jnp.zeros_like(z)], -1))
    return out[:, : x.shape[1], 0]


# ---------------------------------------------------------------------------
# Transformer ops
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _rmsnorm_vjp(eps: float):
    def fast(xf, wf):
        xp, r = _pad_rows(xf, _rms.BR)
        return _rms.rmsnorm(xp, wf, eps)[:r]

    return _make_ref_bwd(fast, lambda xf, wf: ref.rmsnorm(xf, wf, eps))


def rmsnorm(x, w, eps: float = 1e-6):
    """x: (..., D); w: (D,)."""
    if not KERNELS_ENABLED:
        return ref.rmsnorm(x, w, eps)
    shape = x.shape
    flat = x.reshape(-1, shape[-1])
    return _rmsnorm_vjp(eps)(flat, w).reshape(shape)


@functools.lru_cache(maxsize=None)
def _attn_vjp(causal: bool, scale, q_offset: int):
    def fast(q3, k3, v3):
        return _attn.flash_attention(q3, k3, v3, causal=causal, scale=scale,
                                     q_offset=q_offset)

    def reference(q3, k3, v3):
        return ref.flash_attention(q3[:, None], k3[:, None], v3[:, None],
                                   causal=causal, scale=scale,
                                   q_offset=q_offset)[:, 0]

    return _make_ref_bwd(fast, reference)


def flash_attention(q, k, v, *, causal=True, scale=None, q_offset=0,
                    use_kernel=True):
    """q: (B, Hq, Tq, D); k, v: (B, Hkv, Tk, D) — GQA repeated here.

    Falls back to the jnp reference for tiny shapes (decode) where a kernel
    launch has no advantage.
    """
    B, Hq, Tq, D = q.shape
    Hkv = k.shape[1]
    if Hq != Hkv:
        rep = Hq // Hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if not KERNELS_ENABLED or not use_kernel or Tq < 8:
        return ref.flash_attention(q, k, v, causal=causal, scale=scale,
                                   q_offset=q_offset)
    Tk = k.shape[2]
    out = _attn_vjp(causal, scale, int(q_offset))(
        q.reshape(B * Hq, Tq, D), k.reshape(B * Hq, Tk, D),
        v.reshape(B * Hq, Tk, D))
    return out.reshape(B, Hq, Tq, D)


@functools.lru_cache(maxsize=None)
def _rwkv_vjp(chunk: int):
    def fast(r, k, v, w, u):
        B, T, H, K = r.shape
        V = v.shape[-1]

        def flat(x):
            return x.transpose(0, 2, 1, 3).reshape(B * H, T, -1)

        u_flat = jnp.tile(u, (B, 1))
        o = _rwkv.wkv6(flat(r), flat(k), flat(v), flat(w), u_flat, chunk=chunk)
        return o.reshape(B, H, T, V).transpose(0, 2, 1, 3)

    return _make_ref_bwd(fast, ref.rwkv6_scan)


def rwkv6_scan(r, k, v, w, u, *, use_kernel=True, chunk=_rwkv.DEFAULT_CHUNK):
    """r,k,w: (B, T, H, K); v: (B, T, H, V); u: (H, K) → (B, T, H, V)."""
    if not KERNELS_ENABLED or not use_kernel:
        return ref.rwkv6_scan(r, k, v, w, u)
    return _rwkv_vjp(chunk)(r, k, v, w, u)


@functools.lru_cache(maxsize=None)
def _ssd_vjp(chunk: int):
    def fast(x, a, b, c):
        return _m2.ssd(x, a, b, c, chunk=chunk)

    return _make_ref_bwd(fast, ref.mamba2_ssd)


def mamba2_ssd(x, a, b, c, *, use_kernel=True, chunk=_m2.DEFAULT_CHUNK):
    """x: (B, T, H, P); a: (B, T, H); b, c: (B, T, N) → (B, T, H, P)."""
    if not KERNELS_ENABLED or not use_kernel:
        return ref.mamba2_ssd(x, a, b, c)
    return _ssd_vjp(chunk)(x, a, b, c)
