"""Pure-jnp oracles for every Pallas kernel in this package.

These define the *semantics*; kernels are asserted allclose against them over
shape/dtype sweeps in tests/test_kernels_*.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# DSP function set (the paper's Table II accelerators)
# ---------------------------------------------------------------------------

def real_fir(x: jax.Array, h: jax.Array) -> jax.Array:
    """Real FIR: y[b, n] = sum_k h[k] * x[b, n - k]   (causal, zero-padded).

    x: (B, N) float; h: (K,) float → (B, N)
    """
    K = h.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0)))
    return sum(h[k] * jax.lax.dynamic_slice_in_dim(xp, K - 1 - k, x.shape[1], 1)
               for k in range(K))


def complex_fir(x: jax.Array, h: jax.Array) -> jax.Array:
    """Complex FIR on interleaved re/im channels.

    x: (B, N, 2); h: (K, 2) → (B, N, 2)
    """
    xr, xi = x[..., 0], x[..., 1]
    hr, hi = h[:, 0], h[:, 1]
    yr = real_fir(xr, hr) - real_fir(xi, hi)
    yi = real_fir(xr, hi) + real_fir(xi, hr)
    return jnp.stack([yr, yi], axis=-1)


def adaptive_fir(x: jax.Array, d: jax.Array, mu: float, K: int) -> jax.Array:
    """LMS adaptive FIR: per-frame sequential weight update.

    x, d: (B, N) input / desired → (B, N) filter output sequence.
    w_{n+1} = w_n + mu * e[n] * x_window[n]
    """
    B, N = x.shape
    xp = jnp.pad(x, ((0, 0), (K - 1, 0)))

    def frame(xb, db, xpb):
        def step(w, n):
            win = jax.lax.dynamic_slice_in_dim(xpb, n, K)[::-1]
            y = jnp.dot(w, win)
            e = db[n] - y
            return w + mu * e * win, y
        _, ys = jax.lax.scan(step, jnp.zeros((K,), x.dtype), jnp.arange(N))
        return ys

    return jax.vmap(frame)(x, d, xp)


def iir(x: jax.Array, b: jax.Array, a: jax.Array) -> jax.Array:
    """Direct-form-II biquad-style IIR.

    y[n] = sum_j b[j] x[n-j] - sum_{j>=1} a[j] y[n-j];   a[0] assumed 1.
    x: (B, N); b: (Kb,); a: (Ka,) → (B, N)
    """
    Kb, Ka = b.shape[0], a.shape[0]
    xp = jnp.pad(x, ((0, 0), (Kb - 1, 0)))

    def frame(xpb):
        def step(ys, n):
            xwin = jax.lax.dynamic_slice_in_dim(xpb, n, Kb)[::-1]
            y = jnp.dot(b, xwin) - jnp.dot(a[1:], ys[:Ka - 1])
            return jnp.concatenate([y[None], ys[:-1]]), y
        _, out = jax.lax.scan(step, jnp.zeros((Ka - 1,), x.dtype),
                              jnp.arange(x.shape[1]))
        return out

    return jax.vmap(frame)(xp)


def vector_dot(x: jax.Array, y: jax.Array) -> jax.Array:
    """(B, N) · (B, N) → (B,)"""
    return jnp.sum(x * y, axis=-1)


def vector_add(x: jax.Array, y: jax.Array) -> jax.Array:
    return x + y


def vector_max(x: jax.Array) -> jax.Array:
    return jnp.max(x, axis=-1)


def fft_256(x: jax.Array) -> jax.Array:
    """256-point complex FFT. x: (B, 256, 2) re/im → (B, 256, 2)."""
    z = x[..., 0] + 1j * x[..., 1]
    f = jnp.fft.fft(z, axis=-1)
    return jnp.stack([f.real, f.imag], axis=-1).astype(x.dtype)


def dct_matrix(n: int, dtype=jnp.float32) -> jax.Array:
    """Orthonormal DCT-II matrix."""
    k = np.arange(n)[:, None]
    i = np.arange(n)[None, :]
    m = np.cos(np.pi * (2 * i + 1) * k / (2 * n))
    m[0] *= 1.0 / np.sqrt(2)
    m *= np.sqrt(2.0 / n)
    return jnp.asarray(m, dtype)


def dct(x: jax.Array) -> jax.Array:
    """DCT-II over the last axis. x: (B, N) → (B, N)."""
    return x @ dct_matrix(x.shape[-1], x.dtype).T


def correlation(x: jax.Array, y: jax.Array, max_lag: int) -> jax.Array:
    """Sliding cross-correlation: c[b, l] = sum_n x[b, n] y[b, n + l - max_lag].

    x, y: (B, N) → (B, 2*max_lag + 1)
    """
    N = x.shape[1]
    yp = jnp.pad(y, ((0, 0), (max_lag, max_lag)))
    return jnp.stack(
        [jnp.sum(x * jax.lax.dynamic_slice_in_dim(yp, l, N, 1), axis=-1)
         for l in range(2 * max_lag + 1)], axis=-1)


# ---------------------------------------------------------------------------
# Transformer hot-spot kernels
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    r = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * r).astype(dt) * w


def flash_attention(q, k, v, *, causal: bool = True, scale: float | None = None,
                    q_offset: int = 0):
    """Reference multi-head attention (no kernel): q (B,H,Tq,D), k/v (B,H,Tk,D).

    ``q_offset``: absolute position of q[0] relative to k[0] (decode phases).
    """
    if scale is None:
        scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        Tq, Tk = q.shape[2], k.shape[2]
        qi = jnp.arange(Tq)[:, None] + q_offset
        ki = jnp.arange(Tk)[None, :]
        logits = jnp.where(ki <= qi, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def rwkv6_scan(r, k, v, w, u):
    """RWKV-6 (Finch) WKV recurrence, per head.

    r,k,w: (B, T, H, K); v: (B, T, H, V); u: (H, K)
    S_t = diag(w_t) S_{t-1} + k_t^T v_t;  o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
    Returns o: (B, T, H, V).
    """
    B, T, H, K = r.shape
    V = v.shape[-1]

    def head(rb, kb, vb, wb, ub):      # (T,K),(T,K),(T,V),(T,K),(K,)
        def step(S, t):
            kv = kb[t][:, None] * vb[t][None, :]            # (K, V)
            o = rb[t] @ (S + ub[:, None] * kv)              # (V,)
            S = wb[t][:, None] * S + kv
            return S, o
        _, o = jax.lax.scan(step, jnp.zeros((K, V), jnp.float32),
                            jnp.arange(T))
        return o

    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    out = jax.vmap(jax.vmap(head, in_axes=(1, 1, 1, 1, 0), out_axes=1),
                   in_axes=(0, 0, 0, 0, None), out_axes=0)(rf, kf, vf, wf,
                                                           u.astype(jnp.float32))
    return out.astype(r.dtype)


def mamba2_ssd(x, a, b, c):
    """Mamba-2 SSD recurrence (state-space dual), per head.

    x: (B, T, H, P) inputs; a: (B, T, H) scalar decay per head;
    b, c: (B, T, N) input/output projections (shared across heads).
    h_t = exp(a_t) * h_{t-1} + b_t ⊗ x_t;  y_t = c_t · h_t
    Returns y: (B, T, H, P).
    """
    B, T, H, P = x.shape
    N = b.shape[-1]

    def seq(xb, ab, bb, cb):           # (T,H,P),(T,H),(T,N),(T,N)
        def step(h, t):                # h: (H, N, P)
            decay = jnp.exp(ab[t])[:, None, None]
            h = decay * h + bb[t][None, :, None] * xb[t][:, None, :]
            y = jnp.einsum("n,hnp->hp", cb[t], h)
            return h, y
        _, y = jax.lax.scan(step, jnp.zeros((H, N, P), jnp.float32),
                            jnp.arange(T))
        return y

    xf, af, bf, cf = (t.astype(jnp.float32) for t in (x, a, b, c))
    return jax.vmap(seq)(xf, af, bf, cf).astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down
