"""Mamba-2 SSD recurrence as a Pallas TPU kernel.

Per sequence, heads H, head dim P, state dim N:

    h_t = exp(a_t) ⊙ h_{t-1} + b_t ⊗ x_t        h ∈ R^{H×N×P}
    y_t = c_t · h_t                              y ∈ R^{H×P}

Grid (B, T/C) with the chunk axis sequential and the state carried in VMEM
scratch (f32).  Each in-chunk step is an outer-product FMA + an N-contraction
(b ⊗ x and c·h), both VPU/MXU friendly at (N, P) = (64…128, 64…128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import INTERPRET

DEFAULT_CHUNK = 64


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, h_scr, *, C: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)     # (C, H, P)
    a = a_ref[0].astype(jnp.float32)     # (C, H)
    b = b_ref[0].astype(jnp.float32)     # (C, N)
    c = c_ref[0].astype(jnp.float32)     # (C, N)
    H, P = x.shape[1], x.shape[2]
    N = b.shape[-1]

    def step(t, carry):
        h, ys = carry                                    # h: (H, N, P)
        decay = jnp.exp(a[t])[:, None, None]
        h = decay * h + b[t][None, :, None] * x[t][:, None, :]
        y = jnp.einsum("n,hnp->hp", c[t], h)
        ys = jax.lax.dynamic_update_slice_in_dim(ys, y[None], t, axis=0)
        return h, ys

    h, ys = jax.lax.fori_loop(
        0, C, step, (h_scr[...], jnp.zeros((C, H, P), jnp.float32)))
    h_scr[...] = h
    y_ref[0] = ys.astype(y_ref.dtype)


def ssd(x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array, *,
        chunk: int = DEFAULT_CHUNK) -> jax.Array:
    """x: (B, T, H, P); a: (B, T, H); b, c: (B, T, N) → y: (B, T, H, P)."""
    B, T, H, P = x.shape
    N = b.shape[-1]
    C = min(chunk, T)
    nc = pl.cdiv(T, C)
    return pl.pallas_call(
        functools.partial(_ssd_kernel, C=C),
        grid=(B, nc),
        in_specs=[
            pl.BlockSpec((1, C, H, P), lambda i, c_: (i, c_, 0, 0)),
            pl.BlockSpec((1, C, H), lambda i, c_: (i, c_, 0)),
            pl.BlockSpec((1, C, N), lambda i, c_: (i, c_, 0)),
            pl.BlockSpec((1, C, N), lambda i, c_: (i, c_, 0)),
        ],
        out_specs=pl.BlockSpec((1, C, H, P), lambda i, c_: (i, c_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((H, N, P), jnp.float32)],
        interpret=INTERPRET,
    )(x, a, b, c)
