"""Flash-attention forward Pallas kernel (GQA-aware wrapper lives in ops.py).

Canonical TPU online-softmax pattern: grid = (batch·heads, q_blocks, k_blocks)
with the k axis innermost ("arbitrary" — sequential), VMEM scratch carrying
the running max ``m``, normalizer ``l`` and accumulator across k blocks.
Causal q/k blocks that are fully masked are skipped with ``pl.when`` — for
causal attention this halves the compute vs a masked dense sweep.

Block shapes are MXU-aligned: (BQ, D) × (BK, D)ᵀ contraction with BQ = BK =
128 and head dim D padded to a lane multiple by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import INTERPRET

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, q_offset: int, bq: int, bk: int,
                 nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # skip fully-masked blocks: first query row this block = qi*bq + q_offset
    run = True
    if causal:
        run = (ki * bk) <= (qi * bq + q_offset + bq - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)            # (BQ, D)
        k = k_ref[0].astype(jnp.float32)            # (BK, D)
        v = v_ref[0].astype(jnp.float32)            # (BK, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            rows = qi * bq + q_offset + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 0)
            cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        m_prev = m_scr[...]                          # (BQ, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = alpha * acc_scr[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scr[...]
        o_ref[0] = (acc_scr[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: float | None = None,
                    q_offset: int = 0, bq: int = DEFAULT_BQ,
                    bk: int = DEFAULT_BK) -> jax.Array:
    """q: (BH, Tq, D); k, v: (BH, Tk, D) — heads pre-flattened, GQA pre-repeated.

    ``q_offset`` positions q[0] at absolute key index ``q_offset`` (chunked
    prefill / decode append).
    """
    BH, Tq, D = q.shape
    Tk = k.shape[1]
    bq = min(bq, Tq)
    bk = min(bk, Tk)
    nq = pl.cdiv(Tq, bq)
    nk = pl.cdiv(Tk, bk)
    if scale is None:
        scale = D ** -0.5
    kern = functools.partial(
        _attn_kernel, scale=scale, causal=causal, q_offset=q_offset,
        bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kern,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Tq, D), q.dtype),
        scratch_shapes=[            # VMEM: running max / normalizer / accumulator
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=INTERPRET,
    )(q, k, v)
