"""Pallas TPU kernels for the vector accelerators: dot, add, max, correlation."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET

BB = 512


def _vdot_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = jnp.sum(x_ref[...] * y_ref[...], axis=-1, keepdims=True)


def vector_dot(x: jax.Array, y: jax.Array) -> jax.Array:
    """(B, N) · (B, N) → (B,)"""
    B, N = x.shape
    out = pl.pallas_call(
        _vdot_kernel,
        grid=(pl.cdiv(B, BB),),
        in_specs=[pl.BlockSpec((BB, N), lambda i: (i, 0)),
                  pl.BlockSpec((BB, N), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BB, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1), x.dtype),
        interpret=INTERPRET,
    )(x, y)
    return out[:, 0]


def _vadd_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] + y_ref[...]


def vector_add(x: jax.Array, y: jax.Array) -> jax.Array:
    B, N = x.shape
    return pl.pallas_call(
        _vadd_kernel,
        grid=(pl.cdiv(B, BB),),
        in_specs=[pl.BlockSpec((BB, N), lambda i: (i, 0)),
                  pl.BlockSpec((BB, N), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BB, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, N), x.dtype),
        interpret=INTERPRET,
    )(x, y)


def _vmax_kernel(x_ref, o_ref):
    o_ref[...] = jnp.max(x_ref[...], axis=-1, keepdims=True)


def vector_max(x: jax.Array) -> jax.Array:
    B, N = x.shape
    out = pl.pallas_call(
        _vmax_kernel,
        grid=(pl.cdiv(B, BB),),
        in_specs=[pl.BlockSpec((BB, N), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BB, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1), x.dtype),
        interpret=INTERPRET,
    )(x)
    return out[:, 0]


def _corr_kernel(x_ref, y_ref, o_ref, *, max_lag: int):
    x = x_ref[...]
    y = y_ref[...]
    n = x.shape[-1]
    yp = jnp.pad(y, ((0, 0), (max_lag, max_lag)))
    cols = []
    for lag in range(2 * max_lag + 1):    # static unroll: shift + FMA + reduce
        cols.append(jnp.sum(x * yp[:, lag:lag + n], axis=-1, keepdims=True))
    o_ref[...] = jnp.concatenate(cols, axis=-1)


def correlation(x: jax.Array, y: jax.Array, max_lag: int) -> jax.Array:
    """Sliding cross-correlation, lags in [-max_lag, max_lag]. (B,N)→(B,2L+1)."""
    B, N = x.shape
    L = 2 * max_lag + 1
    return pl.pallas_call(
        functools.partial(_corr_kernel, max_lag=max_lag),
        grid=(pl.cdiv(B, BB),),
        in_specs=[pl.BlockSpec((BB, N), lambda i: (i, 0)),
                  pl.BlockSpec((BB, N), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BB, L), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, L), x.dtype),
        interpret=INTERPRET,
    )(x, y)
