"""Shared Pallas kernel utilities.

All kernels in this package are written for TPU (``pl.pallas_call`` with
explicit ``BlockSpec`` VMEM tiling, MXU-aligned inner dims where the math
allows) and VALIDATED on CPU in ``interpret=True`` mode — the kernel body
executes in Python, so correctness vs the ``ref.py`` oracles is exact.
"""
from __future__ import annotations

import jax

#: interpret mode: True everywhere except a real TPU backend.
INTERPRET = jax.default_backend() != "tpu"

#: TPU lane / sublane quanta (fp32).  Block shapes are chosen as multiples
#: where the workload allows; odd DSP frame sizes (40, 64, 256) are padded by
#: the ops.py wrappers so kernel tiles stay hardware-aligned.
LANE = 128
SUBLANE = 8
MXU = 128


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b
