"""RWKV-6 (Finch) WKV recurrence as a Pallas TPU kernel.

Recurrence per head (state S ∈ R^{K×V}, data-dependent decay w_t):

    o_t = r_t (S_{t-1} + diag(u) k_tᵀ v_t)
    S_t = diag(w_t) S_{t-1} + k_tᵀ v_t

TPU adaptation: the time axis is chunked; the grid is (B·H, T/C) with the
chunk axis innermost/sequential, and the state S carried across chunks in a
VMEM scratch buffer (f32).  Inside a chunk the recurrence is stepped with a
``fori_loop`` of rank-1 updates — exact (no decay-ratio reformulation, which
underflows for long chunks), and each step is a (K×V) VPU FMA.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .common import INTERPRET

DEFAULT_CHUNK = 64


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_scr, *, C: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0].astype(jnp.float32)          # (C, K)
    k = k_ref[0].astype(jnp.float32)          # (C, K)
    v = v_ref[0].astype(jnp.float32)          # (C, V)
    w = w_ref[0].astype(jnp.float32)          # (C, K)
    u = u_ref[0].astype(jnp.float32)          # (1, K) → (K,)

    def step(t, carry):
        S, out = carry                         # S: (K, V); out: (C, V)
        kv = k[t][:, None] * v[t][None, :]     # (K, V) rank-1
        o = (r[t][:, None] * (S + u[:, None] * kv)).sum(axis=0)   # (V,)
        S = w[t][:, None] * S + kv
        out = jax.lax.dynamic_update_slice_in_dim(out, o[None], t, axis=0)
        return S, out

    S, out = jax.lax.fori_loop(
        0, C, step, (s_scr[...], jnp.zeros((C, v.shape[-1]), jnp.float32)))
    s_scr[...] = S
    o_ref[0] = out.astype(o_ref.dtype)


def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
         u: jax.Array, *, chunk: int = DEFAULT_CHUNK) -> jax.Array:
    """r,k,w: (BH, T, K); v: (BH, T, V); u: (BH, K) → o: (BH, T, V).

    Heads are pre-flattened into BH by the ops.py wrapper (u broadcast per head).
    """
    BH, T, K = r.shape
    V = v.shape[-1]
    C = min(chunk, T)
    nc = pl.cdiv(T, C)
    return pl.pallas_call(
        functools.partial(_wkv6_kernel, C=C),
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, C, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, C, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, C, V), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, C, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, K), lambda b, c: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, C, V), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, T, V), r.dtype),
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=INTERPRET,
    )(r, k, v, w, u)
