"""Pallas TPU kernels for the FIR/IIR filter accelerators (Table II).

These are the paper's Function-level accelerators, adapted to TPU: instead of
one ASIC processing one 40-sample dataframe, each kernel processes a *batch*
of dataframes per grid step — the TPU-native analogue of "many accelerator
instances", with the batch tile as the VMEM working set.

Layout: frames are (B, N) f32; the batch dim is tiled by ``BB`` (sublane-
aligned), the frame dim stays whole (N ≤ 256 ≪ lane budget).  Filters are
shift+FMA chains on the VPU; taps are unrolled (K is a design-time constant of
the accelerator, like the paper's fixed dataframe size).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET

BB = 256      # batch tile (frames per grid step)


def _grid(b: int) -> tuple[int, ...]:
    return (pl.cdiv(b, BB),)


# ---------------------------------------------------------------------------
# real FIR
# ---------------------------------------------------------------------------
def _real_fir_kernel(x_ref, h_ref, o_ref, *, K: int):
    x = x_ref[...]
    h = h_ref[...]
    n = x.shape[-1]
    acc = h[0] * x
    for k in range(1, K):
        # x shifted right by k with zero fill: y[:, n] += h[k] * x[:, n-k]
        shifted = jnp.pad(x, ((0, 0), (k, 0)))[:, :n]
        acc = acc + h[k] * shifted
    o_ref[...] = acc


def real_fir(x: jax.Array, h: jax.Array) -> jax.Array:
    """x: (B, N) f32, h: (K,) f32 → (B, N)."""
    B, N = x.shape
    K = h.shape[0]
    return pl.pallas_call(
        functools.partial(_real_fir_kernel, K=K),
        grid=_grid(B),
        in_specs=[pl.BlockSpec((BB, N), lambda i: (i, 0)),
                  pl.BlockSpec((K,), lambda i: (0,))],
        out_specs=pl.BlockSpec((BB, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, N), x.dtype),
        interpret=INTERPRET,
    )(x, h)


# ---------------------------------------------------------------------------
# complex FIR (re/im planes)
# ---------------------------------------------------------------------------
def _complex_fir_kernel(xr_ref, xi_ref, h_ref, or_ref, oi_ref, *, K: int):
    xr, xi = xr_ref[...], xi_ref[...]
    h = h_ref[...]            # (K, 2)
    n = xr.shape[-1]
    ar = h[0, 0] * xr - h[0, 1] * xi
    ai = h[0, 0] * xi + h[0, 1] * xr
    for k in range(1, K):
        sr = jnp.pad(xr, ((0, 0), (k, 0)))[:, :n]
        si = jnp.pad(xi, ((0, 0), (k, 0)))[:, :n]
        ar = ar + h[k, 0] * sr - h[k, 1] * si
        ai = ai + h[k, 0] * si + h[k, 1] * sr
    or_ref[...] = ar
    oi_ref[...] = ai


def complex_fir(x: jax.Array, h: jax.Array) -> jax.Array:
    """x: (B, N, 2) re/im, h: (K, 2) → (B, N, 2)."""
    B, N, _ = x.shape
    K = h.shape[0]
    yr, yi = pl.pallas_call(
        functools.partial(_complex_fir_kernel, K=K),
        grid=_grid(B),
        in_specs=[pl.BlockSpec((BB, N), lambda i: (i, 0)),
                  pl.BlockSpec((BB, N), lambda i: (i, 0)),
                  pl.BlockSpec((K, 2), lambda i: (0, 0))],
        out_specs=[pl.BlockSpec((BB, N), lambda i: (i, 0)),
                   pl.BlockSpec((BB, N), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((B, N), x.dtype),
                   jax.ShapeDtypeStruct((B, N), x.dtype)],
        interpret=INTERPRET,
    )(x[..., 0], x[..., 1], h)
    return jnp.stack([yr, yi], axis=-1)


# ---------------------------------------------------------------------------
# adaptive (LMS) FIR — sequential weight update, batch-vectorized
# ---------------------------------------------------------------------------
def _adaptive_fir_kernel(x_ref, d_ref, o_ref, *, K: int, mu: float):
    x = x_ref[...]            # (BB, N)
    d = d_ref[...]
    bb, n = x.shape
    xp = jnp.pad(x, ((0, 0), (K - 1, 0)))     # (BB, N+K-1)

    def step(i, carry):
        w, out = carry                         # w: (BB, K)
        win = jax.lax.dynamic_slice_in_dim(xp, i, K, axis=1)[:, ::-1]
        y = jnp.sum(w * win, axis=1)           # (BB,)
        e = d[:, i] - y
        w = w + mu * e[:, None] * win
        out = jax.lax.dynamic_update_slice_in_dim(out, y[:, None], i, axis=1)
        return w, out

    _, out = jax.lax.fori_loop(
        0, n, step, (jnp.zeros((bb, K), x.dtype), jnp.zeros_like(x)))
    o_ref[...] = out


def adaptive_fir(x: jax.Array, d: jax.Array, mu: float, K: int) -> jax.Array:
    """LMS filter output per frame. x, d: (B, N) → (B, N)."""
    B, N = x.shape
    return pl.pallas_call(
        functools.partial(_adaptive_fir_kernel, K=K, mu=mu),
        grid=_grid(B),
        in_specs=[pl.BlockSpec((BB, N), lambda i: (i, 0)),
                  pl.BlockSpec((BB, N), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BB, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, N), x.dtype),
        interpret=INTERPRET,
    )(x, d)


# ---------------------------------------------------------------------------
# IIR — recurrence over the frame, batch-vectorized
# ---------------------------------------------------------------------------
def _iir_kernel(x_ref, b_ref, a_ref, o_ref, *, Kb: int, Ka: int):
    x = x_ref[...]
    b = b_ref[...]
    a = a_ref[...]
    bb, n = x.shape
    xp = jnp.pad(x, ((0, 0), (Kb - 1, 0)))

    def step(i, carry):
        ys, out = carry                        # ys: (BB, Ka-1) newest-first
        xwin = jax.lax.dynamic_slice_in_dim(xp, i, Kb, axis=1)[:, ::-1]
        y = xwin @ b - ys @ a[1:]
        ys = jnp.concatenate([y[:, None], ys[:, :-1]], axis=1)
        out = jax.lax.dynamic_update_slice_in_dim(out, y[:, None], i, axis=1)
        return ys, out

    _, out = jax.lax.fori_loop(
        0, n, step, (jnp.zeros((bb, Ka - 1), x.dtype), jnp.zeros_like(x)))
    o_ref[...] = out


def iir(x: jax.Array, b: jax.Array, a: jax.Array) -> jax.Array:
    """x: (B, N); b: (Kb,); a: (Ka,) with a[0] = 1 → (B, N)."""
    B, N = x.shape
    return pl.pallas_call(
        functools.partial(_iir_kernel, Kb=b.shape[0], Ka=a.shape[0]),
        grid=_grid(B),
        in_specs=[pl.BlockSpec((BB, N), lambda i: (i, 0)),
                  pl.BlockSpec((b.shape[0],), lambda i: (0,)),
                  pl.BlockSpec((a.shape[0],), lambda i: (0,))],
        out_specs=pl.BlockSpec((BB, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, N), x.dtype),
        interpret=INTERPRET,
    )(x, b, a)
