"""Fused RMSNorm Pallas kernel (row tile × full feature dim in VMEM)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET

BR = 256


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    r = jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    o_ref[...] = ((x * r).astype(o_ref.dtype)) * w_ref[...]


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    """x: (R, D) rows; w: (D,) → (R, D). Callers flatten leading dims."""
    R, D = x.shape
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(pl.cdiv(R, BR),),
        in_specs=[pl.BlockSpec((BR, D), lambda i: (i, 0)),
                  pl.BlockSpec((D,), lambda i: (0,))],
        out_specs=pl.BlockSpec((BR, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        interpret=INTERPRET,
    )(x, w)
