"""Decoder-only transformer family: dense (yi/command-r/phi3/qwen2), MoE
(olmoe/qwen2-moe) and prefix-LM VLM (paligemma).

Layers are stacked along a scanned axis (small HLO, remat-friendly).  All
activations carry logical sharding constraints; MoE dispatch is scatter-based
(no (tokens × experts × capacity) one-hot ever materializes).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding.rules import constraint
from . import layers as L
from .layers import Spec, cast

# ---------------------------------------------------------------------------
# templates
# ---------------------------------------------------------------------------
def padded_experts(n: int, mult: int = 16) -> int:
    return -(-n // mult) * mult


def moe_template(cfg) -> dict:
    m = cfg.moe
    E = padded_experts(m.num_experts)
    D, F = cfg.d_model, m.d_expert
    t = {
        "router": Spec((D, E), (None, "expert")),
        "w_gate": Spec((E, D, F), ("expert", "embed_fsdp", None)),
        "w_up": Spec((E, D, F), ("expert", "embed_fsdp", None)),
        "w_down": Spec((E, F, D), ("expert", None, "embed_fsdp")),
    }
    if m.num_shared:
        t["shared"] = {
            "w_gate": Spec((D, m.d_shared), ("embed_fsdp", "mlp")),
            "w_up": Spec((D, m.d_shared), ("embed_fsdp", "mlp")),
            "w_down": Spec((m.d_shared, D), ("mlp", "embed_fsdp")),
            "gate_proj": Spec((D, 1), (None, None)),
        }
    return t


def mlp_template(cfg) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    return {
        "w_gate": Spec((D, F), ("embed_fsdp", "mlp")),
        "w_up": Spec((D, F), ("embed_fsdp", "mlp")),
        "w_down": Spec((F, D), ("mlp", "embed_fsdp")),
    }


def block_template(cfg) -> dict:
    t = {
        "ln1": Spec((cfg.d_model,), (None,), init="ones"),
        "attn": L.attn_template(cfg),
        "ln2": Spec((cfg.d_model,), (None,), init="ones"),
    }
    t["moe" if cfg.moe else "mlp"] = (moe_template(cfg) if cfg.moe
                                      else mlp_template(cfg))
    return t


def template(cfg) -> dict:
    t = {
        "embed": Spec((cfg.vocab, cfg.d_model), ("vocab", "embed_fsdp"),
                      scale=1.0),
        "layers": L.stack_layers(block_template(cfg), cfg.n_layers),
        "final_norm": Spec((cfg.d_model,), (None,), init="ones"),
    }
    if not cfg.tie_embeddings:
        t["lm_head"] = Spec((cfg.d_model, cfg.vocab), ("embed_fsdp", "vocab"))
    return t


# ---------------------------------------------------------------------------
# MoE forward (scatter-based dispatch)
# ---------------------------------------------------------------------------
def moe_apply(p, cfg, x):
    """x: (B, T, D) → (y, aux_loss).

    Baseline: one global scatter dispatch (position cumsum over all B·T·k
    assignment rows — replicated under SPMD).  With ``FLAGS.moe_grouped``:
    GShard-style grouped dispatch — per-sequence capacity and position
    cumsum, so the dispatch math is sharded along the batch/data axis and
    each cumsum is T·k long instead of B·T·k (§Perf iteration 1).
    """
    from repro.runtime.flags import FLAGS
    m = cfg.moe
    E = padded_experts(m.num_experts)
    k = m.top_k
    B, T, D = x.shape
    N = B * T

    if FLAGS.moe_grouped:
        scores = (x @ cast(p["router"])).astype(jnp.float32)     # (B, T, E)
        if E != m.num_experts:
            scores = jnp.where(jnp.arange(E)[None, None] >= m.num_experts,
                               -1e30, scores)
        probs = jax.nn.softmax(scores, axis=-1)
        gates, topi = jax.lax.top_k(probs, k)                    # (B, T, k)
        gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)

        C = max(int(math.ceil(T * k / E * m.capacity_factor)), 1)
        flat_e = topi.reshape(B, T * k)                          # per group
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)      # (B, T·k, E)
        pos = jnp.cumsum(onehot, axis=1) - 1
        my_pos = jnp.take_along_axis(pos, flat_e[..., None],
                                     axis=2)[..., 0]             # (B, T·k)
        keep = my_pos < C
        dst = jnp.where(keep, flat_e * C + my_pos, E * C)

        x_rep = jnp.repeat(x, k, axis=1)                         # (B, T·k, D)
        xe = jax.vmap(lambda d, xr: jnp.zeros((E * C + 1, D), x.dtype)
                      .at[d].add(xr))(dst, x_rep)
        xe = constraint(xe[:, :-1].reshape(B, E, C, D),
                        ("batch", "expert", None, None))
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, cast(p["w_gate"]))) \
            * jnp.einsum("becd,edf->becf", xe, cast(p["w_up"]))
        ye = jnp.einsum("becf,efd->becd", h, cast(p["w_down"]))
        ye = constraint(ye, ("batch", "expert", None, None))
        ye_flat = jnp.concatenate(
            [ye.reshape(B, E * C, D), jnp.zeros((B, 1, D), x.dtype)], axis=1)
        y_tok = jnp.take_along_axis(ye_flat, dst[..., None], axis=1) \
            * keep[..., None].astype(x.dtype)
        y = (y_tok.reshape(B, T, k, D)
             * gates[..., None].astype(x.dtype)).sum(axis=2)
        probs2 = probs.reshape(N, E)
        topi2 = topi.reshape(N, k)
    else:
        xf = x.reshape(N, D)
        scores = (xf @ cast(p["router"])).astype(jnp.float32)    # (N, E)
        if E != m.num_experts:                                   # mask padding
            pad_mask = jnp.arange(E) >= m.num_experts
            scores = jnp.where(pad_mask[None, :], -1e30, scores)
        probs = jax.nn.softmax(scores, axis=-1)
        gates, topi = jax.lax.top_k(probs, k)                    # (N, k)
        gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)

        C = max(int(math.ceil(N * k / E * m.capacity_factor)), 1)
        flat_e = topi.reshape(-1)                                # (N·k,)
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - 1
        my_pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
        keep = my_pos < C
        dst = jnp.where(keep, flat_e * C + my_pos, E * C)        # E·C = drop

        x_rep = jnp.repeat(xf, k, axis=0)                        # (N·k, D)
        xe = jnp.zeros((E * C + 1, D), x.dtype).at[dst].add(x_rep)
        xe = constraint(xe[:-1].reshape(E, C, D), ("expert", None, None))

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, cast(p["w_gate"]))) \
            * jnp.einsum("ecd,edf->ecf", xe, cast(p["w_up"]))
        ye = jnp.einsum("ecf,efd->ecd", h, cast(p["w_down"]))
        ye = constraint(ye, ("expert", None, None))

        ye_flat = jnp.concatenate(
            [ye.reshape(E * C, D), jnp.zeros((1, D), x.dtype)], axis=0)
        y_tok = ye_flat[dst] * keep[:, None].astype(x.dtype)
        y = (y_tok.reshape(N, k, D)
             * gates[..., None].astype(x.dtype)).sum(axis=1).reshape(B, T, D)
        probs2 = probs
        topi2 = topi

    if m.num_shared:
        s = p["shared"]
        shared_out = L.swiglu(x, s["w_gate"], s["w_up"], s["w_down"])
        g = jax.nn.sigmoid(L.linear(x, s["gate_proj"]))
        y = y + g * shared_out

    # Switch-style load-balance loss over the true (unpadded) experts
    me = probs2[:, :m.num_experts].mean(axis=0)
    ce = (jax.nn.one_hot(topi2, E, dtype=jnp.float32).sum(1).mean(axis=0)
          [:m.num_experts]) / k
    aux = m.num_experts * jnp.sum(me * ce)
    return y, aux


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------
def block_apply(lp, cfg, x, positions, *, prefix_len: int = 0):
    """One decoder block, train/prefill path.  x: (B, T, D)."""
    h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
    if prefix_len > 0:
        # prefix-LM (paligemma): bidirectional over [0, P), causal afterwards
        q, kk, v = L.attn_qkv(lp["attn"], cfg, h, positions)
        P = prefix_len
        from repro.kernels import ops as kops
        o_pre = kops.flash_attention(q[:, :, :P], kk[:, :, :P], v[:, :, :P],
                                     causal=False)
        o_suf = kops.flash_attention(q[:, :, P:], kk, v, causal=True,
                                     q_offset=P)
        o = jnp.concatenate([o_pre, o_suf], axis=2)
        attn = L.attn_out(lp["attn"], o)
    else:
        attn = L.self_attention(lp["attn"], cfg, h, positions)
    x = x + constraint(attn, ("batch", "seq", None))
    h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if cfg.moe:
        y, aux = moe_apply(lp["moe"], cfg, h)
    else:
        y, aux = L.swiglu(h, **{k: lp["mlp"][k] for k in
                                ("w_gate", "w_up", "w_down")}), 0.0
    return x + constraint(y, ("batch", "seq", None)), aux


def _quant_decode_attention(p, cfg, x, ck, cv, ks, vs, pos):
    """int8-KV decode attention (grouped-query path, scalar pos).

    Cache stores int8 values with per-(token, head) scales; new K/V rows are
    quantized at write; dequantization folds into the attention contractions
    (scale applied on the (..., T) axis) — the cache never materializes in
    a wide dtype.
    """
    B = x.shape[0]
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    positions = jnp.full((1,), pos, jnp.int32)
    q, k, v = L.attn_qkv(p, cfg, x, positions)

    def quantize(t):                       # (B, Hkv, 1, Dh) → int8 + scale
        s = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1) / 127.0
        s = jnp.maximum(s, 1e-8)
        q8 = jnp.clip(jnp.round(t.astype(jnp.float32) / s[..., None]),
                      -127, 127).astype(jnp.int8)
        return q8, s

    k8, k_s = quantize(k)
    v8, v_s = quantize(v)
    ck = jax.lax.dynamic_update_slice_in_dim(ck, k8, pos, axis=2)
    cv = jax.lax.dynamic_update_slice_in_dim(cv, v8, pos, axis=2)
    ks = jax.lax.dynamic_update_slice_in_dim(ks, k_s.astype(ks.dtype), pos,
                                             axis=2)
    vs = jax.lax.dynamic_update_slice_in_dim(vs, v_s.astype(vs.dtype), pos,
                                             axis=2)
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, Dh)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg.astype(jnp.float32),
                   ck.astype(jnp.float32)) * ks[:, :, None, :]
    s = s * (Dh ** -0.5)
    mask = jnp.arange(ck.shape[2])[None, None, None, :] <= pos
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", (w * vs[:, :, None, :]),
                   cv.astype(jnp.float32))
    o = o.reshape(B, Hq, 1, Dh).astype(x.dtype)
    return L.attn_out(p, o), ck, cv, ks, vs


def block_decode(lp, cfg, x, ck, cv, pos, ks=None, vs=None):
    """One-token decode. x: (B, 1, D); ck/cv: (B, Hkv, Tmax, Dh);
    ks/vs: int8-mode per-(token, head) scales (or None)."""
    h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
    if ks is not None:
        attn, ck, cv, ks, vs = _quant_decode_attention(
            lp["attn"], cfg, h, ck, cv, ks, vs, pos)
        x = x + attn
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        if cfg.moe:
            y, _ = moe_apply(lp["moe"], cfg, h)
        else:
            y = L.swiglu(h, **{k: lp["mlp"][k] for k in
                               ("w_gate", "w_up", "w_down")})
        return x + y, ck, cv, ks, vs
    attn, ck, cv = L.decode_attention(lp["attn"], cfg, h, ck, cv, pos)
    x = x + attn
    h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if cfg.moe:
        y, _ = moe_apply(lp["moe"], cfg, h)
    else:
        y = L.swiglu(h, **{k: lp["mlp"][k] for k in
                           ("w_gate", "w_up", "w_down")})
    return x + y, ck, cv


# ---------------------------------------------------------------------------
# model entry points
# ---------------------------------------------------------------------------
def embed_tokens(params, tokens):
    e = jnp.take(cast(params["embed"]), tokens, axis=0)
    return constraint(e, ("batch", "seq", None))


def unembed(params, cfg, x):
    head = params.get("lm_head")
    w = cast(head) if head is not None else cast(params["embed"]).T
    logits = x @ w
    return constraint(logits, ("batch", "seq", "vocab"))


def forward(params, cfg, tokens, prefix_embeds: Optional[jax.Array] = None,
            remat_policy: str = "nothing"):
    """tokens: (B, T) → logits (B, T', V), aux.  With ``prefix_embeds``
    (B, P, D) the sequence is [prefix; tokens] and attention is prefix-LM."""
    x = embed_tokens(params, tokens)
    prefix_len = 0
    if prefix_embeds is not None:
        prefix_len = prefix_embeds.shape[1]
        x = jnp.concatenate([cast(prefix_embeds), x], axis=1)
    T = x.shape[1]
    positions = jnp.arange(T)

    def layer_fn(carry, lp):
        x, aux = carry
        x, a = block_apply(lp, cfg, x, positions, prefix_len=prefix_len)
        return (x, aux + a), None

    layer_fn = remat(layer_fn, remat_policy)
    (x, aux), _ = L.scan(layer_fn, (x, jnp.float32(0.0)),
                         params["layers"])
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params, cfg, x), aux / max(cfg.n_layers, 1)


def remat(fn, policy: str):
    if policy == "none":
        return fn
    policies = {
        "nothing": jax.checkpoint_policies.nothing_saveable,
        "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    }
    return jax.checkpoint(fn, policy=policies.get(policy,
                                                  policies["nothing"]),
                          prevent_cse=False)


def train_loss(params, cfg, batch, remat_policy: str = "nothing"):
    logits, aux = forward(params, cfg, batch["tokens"],
                          batch.get("prefix_embeds"), remat_policy)
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:          # vlm: loss on text only
        logits = logits[:, -labels.shape[1]:]
    return L.softmax_xent(logits, labels) + 0.01 * aux


def init_cache(cfg, batch: int, max_len: int, dtype=L.COMPUTE_DTYPE):
    from repro.runtime.flags import FLAGS
    Hkv, Dh, Lr = cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
    shape = (Lr, batch, Hkv, max_len, Dh)
    if FLAGS.decode_kv_int8:
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_s": jnp.zeros(shape[:-1], jnp.float32),
                "v_s": jnp.zeros(shape[:-1], jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_specs(cfg, batch: int, max_len: int, rules, dtype=L.COMPUTE_DTYPE):
    Hkv, Dh, Lr = cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
    shape = (Lr, batch, Hkv, max_len, Dh)
    axes = ("layers", "cache_batch", "kv_heads", "kv_seq", None)
    return jax.tree.map(
        lambda _: rules.spec_for(shape, axes), {"k": 0, "v": 0})


def decode_step(params, cfg, cache, tokens, pos):
    """tokens: (B, 1); pos: scalar (or per-lane) position →
    (logits (B, 1, V), cache)."""
    x = embed_tokens(params, tokens)

    if "k_s" in cache:                       # int8 KV mode
        def layer_fn(x, inp):
            lp, ck, cv, sk, sv = inp
            x, ck, cv, sk, sv = block_decode(lp, cfg, x, ck, cv, pos, sk, sv)
            return x, (ck, cv, sk, sv)

        x, (k8, v8, sk, sv) = L.scan(
            layer_fn, x, (params["layers"], cache["k"], cache["v"],
                          cache["k_s"], cache["v_s"]))
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return unembed(params, cfg, x), {"k": k8, "v": v8,
                                         "k_s": sk, "v_s": sv}

    def layer_fn(x, inp):
        lp, ck, cv = inp
        x, ck, cv = block_decode(lp, cfg, x, ck, cv, pos)
        return x, (ck, cv)

    x, (ks, vs) = L.scan(layer_fn, x,
                         (params["layers"], cache["k"], cache["v"]))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params, cfg, x), {"k": ks, "v": vs}


def chunk_step(params, cfg, cache, tokens, pos):
    """Score a k-token chunk against the cache (speculative-decode verify).

    tokens: (B, k); pos: scalar — chunk occupies [pos, pos+k).
    Returns (logits (B, k, V), cache with the chunk's K/V written).
    Positions ≥ pos+k in the cache are ignored by masking, so a later
    overwrite at a smaller pos implements rollback (the paper's TM discard).
    """
    x = embed_tokens(params, tokens)
    B, k, _ = x.shape
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    Tmax = cache["k"].shape[3]
    positions = pos + jnp.arange(k)

    def layer_fn(x, inp):
        lp, ck, cv = inp
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        q, kk, vv = L.attn_qkv(lp["attn"], cfg, h, positions)
        ck = jax.lax.dynamic_update_slice_in_dim(
            ck, kk.astype(ck.dtype), pos, axis=2)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cv, vv.astype(cv.dtype), pos, axis=2)
        kr = jnp.repeat(ck, Hq // Hkv, axis=1)
        vr = jnp.repeat(cv, Hq // Hkv, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                       kr.astype(jnp.float32)) * (Dh ** -0.5)
        cols = jnp.arange(Tmax)[None, None, None, :]
        rows = positions[None, None, :, None]
        s = jnp.where(cols <= rows, s, -1e30)
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", w,
                       vr.astype(jnp.float32)).astype(x.dtype)
        x = x + L.attn_out(lp["attn"], o)
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        if cfg.moe:
            y, _ = moe_apply(lp["moe"], cfg, h)
        else:
            y = L.swiglu(h, **{kk2: lp["mlp"][kk2] for kk2 in
                               ("w_gate", "w_up", "w_down")})
        return x + y, (ck, cv)

    x, (ks, vs) = L.scan(layer_fn, x,
                         (params["layers"], cache["k"], cache["v"]))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params, cfg, x), {"k": ks, "v": vs}


def prefill(params, cfg, tokens, max_len: int,
            prefix_embeds: Optional[jax.Array] = None):
    """Run the full prompt, returning logits and a populated KV cache."""
    x = embed_tokens(params, tokens)
    prefix_len = 0
    if prefix_embeds is not None:
        prefix_len = prefix_embeds.shape[1]
        x = jnp.concatenate([cast(prefix_embeds), x], axis=1)
    B, T, _ = x.shape
    positions = jnp.arange(T)
    pad = max_len - T

    def layer_fn(x, lp):
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = L.attn_qkv(lp["attn"], cfg, h, positions)
        from repro.kernels import ops as kops
        if prefix_len > 0:
            o_pre = kops.flash_attention(q[:, :, :prefix_len],
                                         k[:, :, :prefix_len],
                                         v[:, :, :prefix_len], causal=False)
            o_suf = kops.flash_attention(q[:, :, prefix_len:], k, v,
                                         causal=True, q_offset=prefix_len)
            o = jnp.concatenate([o_pre, o_suf], axis=2)
        else:
            o = kops.flash_attention(q, k, v, causal=True)
        x = x + L.attn_out(lp["attn"], o)
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        if cfg.moe:
            y, _ = moe_apply(lp["moe"], cfg, h)
        else:
            y = L.swiglu(h, **{kk: lp["mlp"][kk] for kk in
                               ("w_gate", "w_up", "w_down")})
        ck = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        cv = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        return x + y, (ck, cv)

    x, (ks, vs) = L.scan(layer_fn, x, params["layers"])
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params, cfg, x), {"k": ks, "v": vs}
