"""Whisper-style encoder-decoder (audio family).

The conv frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (B, T, D) from ``input_specs``.  Encoder uses
sinusoidal positions + bidirectional attention; the decoder uses RoPE for its
causal self-attention (divergence from Whisper's learned positions, noted in
DESIGN.md — keeps parameter templates independent of sequence length) and
cross-attends to the encoder output.  Decode keeps a self-attention KV cache
plus the cross K/V computed once at prefill.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.sharding.rules import constraint
from . import layers as L
from . import transformer as T
from .layers import Spec, cast


def enc_block_template(cfg) -> dict:
    return {
        "ln1": Spec((cfg.d_model,), (None,), init="ones"),
        "attn": L.attn_template(cfg),
        "ln2": Spec((cfg.d_model,), (None,), init="ones"),
        "mlp": {
            "w_up": Spec((cfg.d_model, cfg.d_ff), ("embed_fsdp", "mlp")),
            "b_up": Spec((cfg.d_ff,), ("mlp",), init="zeros"),
            "w_down": Spec((cfg.d_ff, cfg.d_model), ("mlp", "embed_fsdp")),
            "b_down": Spec((cfg.d_model,), (None,), init="zeros"),
        },
    }


def dec_block_template(cfg) -> dict:
    t = enc_block_template(cfg)
    t["ln_x"] = Spec((cfg.d_model,), (None,), init="ones")
    t["xattn"] = L.attn_template(cfg)
    return t


def template(cfg) -> dict:
    return {
        "embed": Spec((cfg.vocab, cfg.d_model), ("vocab", "embed_fsdp"),
                      scale=1.0),
        "enc_layers": L.stack_layers(enc_block_template(cfg), cfg.enc_layers),
        "enc_norm": Spec((cfg.d_model,), (None,), init="ones"),
        "dec_layers": L.stack_layers(dec_block_template(cfg), cfg.n_layers),
        "final_norm": Spec((cfg.d_model,), (None,), init="ones"),
        "lm_head": Spec((cfg.d_model, cfg.vocab), ("embed_fsdp", "vocab")),
    }


def _sinusoid(T_, D):
    pos = jnp.arange(T_)[:, None].astype(jnp.float32)
    dim = jnp.arange(D // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10_000.0, 2 * dim / D)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _mlp(lp, x):
    return L.gelu_mlp(x, lp["mlp"]["w_up"], lp["mlp"]["b_up"],
                      lp["mlp"]["w_down"], lp["mlp"]["b_down"])


def encode(params, cfg, frames, remat_policy: str = "nothing"):
    """frames: (B, T, D) stub embeddings → encoder states (B, T, D)."""
    x = cast(frames) + cast(_sinusoid(frames.shape[1], cfg.d_model))[None]
    x = constraint(x, ("batch", "frames", None))
    positions = jnp.arange(x.shape[1])

    def layer_fn(x, lp):
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        x = x + L.self_attention(lp["attn"], cfg, h, positions, causal=False,
                                 use_rope=False)
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        return x + _mlp(lp, h), None

    layer_fn = T.remat(layer_fn, remat_policy)
    x, _ = L.scan(layer_fn, x, params["enc_layers"])
    return L.rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def _cross_attention(lp, cfg, x, enc, positions):
    """q from decoder x; k/v from encoder states."""
    B, Tq, _ = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = L.linear(x, lp["wq"], lp.get("bq")).reshape(B, Tq, H, Dh)
    k = L.linear(enc, lp["wk"], lp.get("bk")).reshape(B, -1, Hkv, Dh)
    v = L.linear(enc, lp["wv"], lp.get("bv")).reshape(B, -1, Hkv, Dh)
    q = q.transpose(0, 2, 1, 3)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    o = ops.flash_attention(q, k, v, causal=False)
    return L.attn_out(lp, o)


def decode_seq(params, cfg, tokens, enc, remat_policy: str = "nothing"):
    """Teacher-forced decoder pass. tokens: (B, T); enc: (B, Te, D)."""
    x = jnp.take(cast(params["embed"]), tokens, axis=0)
    x = constraint(x, ("batch", "seq", None))
    positions = jnp.arange(x.shape[1])

    def layer_fn(x, lp):
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        x = x + L.self_attention(lp["attn"], cfg, h, positions, causal=True)
        h = L.rmsnorm(x, lp["ln_x"], cfg.norm_eps)
        x = x + _cross_attention(lp["xattn"], cfg, h, enc, positions)
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        return x + _mlp(lp, h), None

    layer_fn = T.remat(layer_fn, remat_policy)
    x, _ = L.scan(layer_fn, x, params["dec_layers"])
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return T.unembed(params, cfg, x)


def train_loss(params, cfg, batch, remat_policy: str = "nothing"):
    enc = encode(params, cfg, batch["frames"], remat_policy)
    logits = decode_seq(params, cfg, batch["tokens"], enc, remat_policy)
    return L.softmax_xent(logits, batch["labels"])


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def init_cache(cfg, batch: int, max_len: int, dtype=L.COMPUTE_DTYPE):
    Hkv, Dh, Lr = cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
    return {
        "k": jnp.zeros((Lr, batch, Hkv, max_len, Dh), dtype),
        "v": jnp.zeros((Lr, batch, Hkv, max_len, Dh), dtype),
        # cross K/V precomputed from encoder states at prefill time
        "xk": jnp.zeros((Lr, batch, Hkv, max_len, Dh), dtype),
        "xv": jnp.zeros((Lr, batch, Hkv, max_len, Dh), dtype),
    }


def cache_axes():
    a = ("layers", "cache_batch", "kv_heads", "kv_seq", None)
    return {"k": a, "v": a, "xk": a, "xv": a}


def decode_step(params, cfg, cache, tokens, pos):
    x = jnp.take(cast(params["embed"]), tokens, axis=0)
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    B = x.shape[0]

    def layer_fn(x, inp):
        lp, ck, cv, xk, xv = inp
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        attn, ck, cv = L.decode_attention(lp["attn"], cfg, h, ck, cv, pos)
        x = x + attn
        # cross attention against precomputed (non-causal, full) K/V
        h = L.rmsnorm(x, lp["ln_x"], cfg.norm_eps)
        q = L.linear(h, lp["xattn"]["wq"]).reshape(B, 1, H, Dh).transpose(0, 2, 1, 3)
        kk = jnp.repeat(xk, H // Hkv, axis=1)
        vv = jnp.repeat(xv, H // Hkv, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                       kk.astype(jnp.float32)) * (Dh ** -0.5)
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", w,
                       vv.astype(jnp.float32)).astype(x.dtype)
        x = x + L.attn_out(lp["xattn"], o)
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        return x + _mlp(lp, h), (ck, cv)

    x, (ks, vs) = L.scan(
        layer_fn, x,
        (params["dec_layers"], cache["k"], cache["v"], cache["xk"],
         cache["xv"]))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return T.unembed(params, cfg, x), dict(cache, k=ks, v=vs)
