"""RWKV-6 "Finch" (attention-free) language model.

Time-mix with data-dependent decay (low-rank LoRA on w), WKV6 recurrence via
the Pallas kernel, squared-ReLU channel mix.  Decode carries O(1) state per
layer: the (H, K, V) WKV state and the two token-shift registers — this is
why rwkv6 runs the ``long_500k`` cell (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.sharding.rules import constraint
from . import layers as L
from .layers import Spec, cast

DECAY_LORA = 64


def block_template(cfg) -> dict:
    D = cfg.d_model
    H, K = cfg.n_heads, cfg.ssm.d_state
    V = cfg.ssm.head_dim
    F = cfg.d_ff
    return {
        "ln1": Spec((D,), (None,), init="ones"),
        "att": {
            "mu": Spec((5, D), (None, None), init="zeros"),   # r k v w g mixes
            "wr": Spec((D, H * K), ("embed_fsdp", "heads")),
            "wk": Spec((D, H * K), ("embed_fsdp", "heads")),
            "wv": Spec((D, H * V), ("embed_fsdp", "heads")),
            "wg": Spec((D, H * V), ("embed_fsdp", "heads")),
            "w0": Spec((H * K,), ("heads",), init="zeros"),
            "wa": Spec((D, DECAY_LORA), ("embed_fsdp", None)),
            "wb": Spec((DECAY_LORA, H * K), (None, "heads")),
            "u": Spec((H, K), ("heads", None)),
            "ln_x": Spec((H * V,), ("heads",), init="ones"),
            "wo": Spec((H * V, D), ("heads", "embed_fsdp")),
        },
        "ln2": Spec((D,), (None,), init="ones"),
        "ffn": {
            "mu": Spec((2, D), (None, None), init="zeros"),   # k r mixes
            "wk": Spec((D, F), ("embed_fsdp", "mlp")),
            "wv": Spec((F, D), ("mlp", "embed_fsdp")),
            "wr": Spec((D, D), ("embed_fsdp", None)),
        },
    }


def template(cfg) -> dict:
    return {
        "embed": Spec((cfg.vocab, cfg.d_model), ("vocab", "embed_fsdp"),
                      scale=1.0),
        "layers": L.stack_layers(block_template(cfg), cfg.n_layers),
        "final_norm": Spec((cfg.d_model,), (None,), init="ones"),
        "lm_head": Spec((cfg.d_model, cfg.vocab), ("embed_fsdp", "vocab")),
    }


def _mix(x, x_prev_seq, mu):
    """Token shift: x + mu * (shift(x) - x), vectorized over the 5 mixes."""
    return x + mu * (x_prev_seq - x)


def _decay(att, xw):
    w = att["w0"] + jnp.tanh(xw @ cast(att["wa"])) @ cast(att["wb"])
    return jnp.exp(-jnp.exp(w.astype(jnp.float32))).astype(xw.dtype)


def _head_norm(o, scale, H, V, eps):
    B, T = o.shape[:2]
    o = o.reshape(B, T, H, V)
    o = o * jax.lax.rsqrt(
        jnp.mean(jnp.square(o.astype(jnp.float32)), -1, keepdims=True) + eps
    ).astype(o.dtype)
    return o.reshape(B, T, H * V) * cast(scale)


def time_mix(att, cfg, x, x_shift):
    """x: (B, T, D); x_shift: x shifted right one step (first row = prev state)."""
    H, K, V = cfg.n_heads, cfg.ssm.d_state, cfg.ssm.head_dim
    B, T, D = x.shape
    mu = cast(att["mu"])
    xr, xk, xv, xw, xg = (_mix(x, x_shift, mu[i]) for i in range(5))
    r = (xr @ cast(att["wr"])).reshape(B, T, H, K)
    k = (xk @ cast(att["wk"])).reshape(B, T, H, K)
    v = (xv @ cast(att["wv"])).reshape(B, T, H, V)
    w = _decay(att, xw).reshape(B, T, H, K)
    g = jax.nn.silu(xg @ cast(att["wg"]))
    o = ops.rwkv6_scan(r, k, v, w, cast(att["u"]))
    o = _head_norm(o.reshape(B, T, H * V), att["ln_x"], H, V, cfg.norm_eps)
    return (o * g) @ cast(att["wo"])


def channel_mix(ffn, x, x_shift):
    mu = cast(ffn["mu"])
    xk = _mix(x, x_shift, mu[0])
    xr = _mix(x, x_shift, mu[1])
    k = jnp.square(jax.nn.relu(xk @ cast(ffn["wk"])))
    return jax.nn.sigmoid(xr @ cast(ffn["wr"])) * (k @ cast(ffn["wv"]))


def _shift(x):
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def forward(params, cfg, tokens, remat_policy: str = "nothing"):
    from .transformer import remat, unembed
    x = jnp.take(cast(params["embed"]), tokens, axis=0)
    x = constraint(x, ("batch", "seq", None))

    def layer_fn(x, lp):
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        x = x + time_mix(lp["att"], cfg, h, _shift(h))
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        x = x + channel_mix(lp["ffn"], h, _shift(h))
        return constraint(x, ("batch", "seq", None)), None

    layer_fn = remat(layer_fn, remat_policy)
    x, _ = L.scan(layer_fn, x, params["layers"])
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params, cfg, x), jnp.float32(0.0)


def train_loss(params, cfg, batch, remat_policy: str = "nothing"):
    logits, _ = forward(params, cfg, batch["tokens"], remat_policy)
    return L.softmax_xent(logits, batch["labels"])


# ---------------------------------------------------------------------------
# decode: O(1) recurrent state
# ---------------------------------------------------------------------------
def init_cache(cfg, batch: int, max_len: int, dtype=L.COMPUTE_DTYPE):
    del max_len   # state is O(1) in sequence length
    H, K, V = cfg.n_heads, cfg.ssm.d_state, cfg.ssm.head_dim
    Lr, D = cfg.n_layers, cfg.d_model
    return {
        "att_x": jnp.zeros((Lr, batch, D), dtype),
        "ffn_x": jnp.zeros((Lr, batch, D), dtype),
        "S": jnp.zeros((Lr, batch, H, K, V), jnp.float32),
    }


def cache_axes():
    return {
        "att_x": ("layers", "cache_batch", None),
        "ffn_x": ("layers", "cache_batch", None),
        "S": ("layers", "cache_batch", "heads", None, None),
    }


def decode_step(params, cfg, cache, tokens, pos):
    """tokens: (B, 1) → (logits (B, 1, V), cache)."""
    del pos
    from .transformer import unembed
    H, K, V = cfg.n_heads, cfg.ssm.d_state, cfg.ssm.head_dim
    x = jnp.take(cast(params["embed"]), tokens, axis=0)   # (B, 1, D)

    def layer_fn(x, inp):
        lp, ax, fx, S = inp                    # ax/fx: (B, D); S: (B, H, K, V)
        B = x.shape[0]
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        h1 = h[:, 0]
        att = lp["att"]
        mu = cast(att["mu"])
        xr, xk, xv, xw, xg = (h1 + mu[i] * (ax - h1) for i in range(5))
        r = (xr @ cast(att["wr"])).reshape(B, H, K)
        k = (xk @ cast(att["wk"])).reshape(B, H, K)
        v = (xv @ cast(att["wv"])).reshape(B, H, V)
        w = _decay(att, xw[:, None])[:, 0].reshape(B, H, K)
        g = jax.nn.silu(xg @ cast(att["wg"]))
        kv = k[..., None] * v[..., None, :].astype(jnp.float32)
        u = cast(att["u"]).astype(jnp.float32)
        o = jnp.einsum("bhk,bhkv->bhv", r.astype(jnp.float32),
                       S + u[None, :, :, None] * kv)
        S = w[..., None].astype(jnp.float32) * S + kv
        o = _head_norm(o.reshape(B, 1, H * V).astype(x.dtype),
                       att["ln_x"], H, V, cfg.norm_eps)
        x = x + ((o[:, 0] * g) @ cast(att["wo"]))[:, None]
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        h1n = h[:, 0]
        mu2 = cast(lp["ffn"]["mu"])
        xkf = h1n + mu2[0] * (fx - h1n)
        xrf = h1n + mu2[1] * (fx - h1n)
        kf = jnp.square(jax.nn.relu(xkf @ cast(lp["ffn"]["wk"])))
        y = jax.nn.sigmoid(xrf @ cast(lp["ffn"]["wr"])) * (kf @ cast(lp["ffn"]["wv"]))
        x = x + y[:, None]
        return x, (h1, h1n, S)

    x, (ax, fx, S) = L.scan(
        layer_fn, x, (params["layers"], cache["att_x"], cache["ffn_x"],
                      cache["S"]))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return unembed(params, cfg, x), {"att_x": ax, "ffn_x": fx, "S": S}
