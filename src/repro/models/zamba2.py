"""Zamba2-style hybrid: Mamba-2 backbone with alternating *shared* attention
blocks every ``shared_attn_period`` layers.

Layer structure (L = 81, period 6): 13 groups of [6 mamba layers + one shared
attention block], plus a 3-layer mamba tail.  The two shared blocks alternate
across groups — shared *weights*, but each invocation site keeps its own KV
cache.  Decode state: per-mamba-layer (conv window, SSD state) — O(1) — plus
13 site-local KV caches, which is what makes the ``long_500k`` cell feasible
(only 13 attention caches instead of 81).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.sharding.rules import constraint
from . import layers as L
from . import transformer as T
from .layers import Spec, cast


def _dims(cfg):
    d_in = cfg.ssm.expand * cfg.d_model
    H = d_in // cfg.ssm.head_dim
    N = cfg.ssm.d_state
    return d_in, H, N


def mamba_template(cfg) -> dict:
    D = cfg.d_model
    d_in, H, N = _dims(cfg)
    kconv = cfg.ssm.conv_kernel
    return {
        "ln": Spec((D,), (None,), init="ones"),
        "in_proj": Spec((D, 2 * d_in + 2 * N + H), ("embed_fsdp", "heads")),
        "conv_w": Spec((kconv, d_in + 2 * N), (None, "heads")),
        "conv_b": Spec((d_in + 2 * N,), ("heads",), init="zeros"),
        "a_log": Spec((H,), ("heads",), init="zeros"),
        "dt_bias": Spec((H,), ("heads",), init="zeros"),
        "d_skip": Spec((H,), ("heads",), init="zeros"),
        "out_norm": Spec((d_in,), ("heads",), init="ones"),
        "out_proj": Spec((d_in, D), ("heads", "embed_fsdp")),
    }


def template(cfg) -> dict:
    period = cfg.shared_attn_period
    n_groups = cfg.n_layers // period
    tail = cfg.n_layers - n_groups * period
    shared_block = {
        "ln1": Spec((cfg.d_model,), (None,), init="ones"),
        "attn": L.attn_template(cfg),
        "ln2": Spec((cfg.d_model,), (None,), init="ones"),
        "mlp": T.mlp_template(cfg),
    }
    t = {
        "embed": Spec((cfg.vocab, cfg.d_model), ("vocab", "embed_fsdp"),
                      scale=1.0),
        "groups": L.stack_layers(
            L.stack_layers(mamba_template(cfg), period), n_groups),
        "shared": L.stack_layers(shared_block, cfg.n_shared_blocks),
        "final_norm": Spec((cfg.d_model,), (None,), init="ones"),
        "lm_head": Spec((cfg.d_model, cfg.vocab), ("embed_fsdp", "vocab")),
    }
    if tail:
        t["tail"] = L.stack_layers(mamba_template(cfg), tail)
    return t


def _split_proj(cfg, proj):
    d_in, H, N = _dims(cfg)
    z, xbc, dt = jnp.split(proj, [d_in, 2 * d_in + 2 * N], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv over time. xbc: (B, T, C); w: (k, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1]] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def mamba_apply(lp, cfg, x):
    """One Mamba-2 layer, sequence path. x: (B, T, D)."""
    d_in, H, N = _dims(cfg)
    P = cfg.ssm.head_dim
    B, Tt, D = x.shape
    h = L.rmsnorm(x, lp["ln"], cfg.norm_eps)
    z, xbc, dt = _split_proj(cfg, h @ cast(lp["in_proj"]))
    xbc = _causal_conv(xbc, cast(lp["conv_w"]), cast(lp["conv_b"]))
    xin, bmat, cmat = jnp.split(xbc, [d_in, d_in + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])  # (B,T,H)
    a = (-jnp.exp(lp["a_log"]) * dt)                              # ≤ 0
    xh = xin.reshape(B, Tt, H, P) * dt[..., None].astype(xin.dtype)
    y = ops.mamba2_ssd(xh, a, bmat, cmat)                         # (B,T,H,P)
    y = y + xin.reshape(B, Tt, H, P) * cast(lp["d_skip"])[:, None]
    y = y.reshape(B, Tt, d_in)
    y = y * jax.nn.silu(z)
    y = y * jax.lax.rsqrt(jnp.mean(jnp.square(y.astype(jnp.float32)), -1,
                                   keepdims=True) + cfg.norm_eps).astype(y.dtype)
    y = y * cast(lp["out_norm"])
    return x + constraint(y @ cast(lp["out_proj"]), ("batch", "seq", None))


def _shared_apply(params, cfg, x, gi, positions):
    """Apply the (gi % n_shared)-th shared attention block.

    Selects the block's *weights* with a dynamic gather instead of
    ``lax.switch`` — one block computation in the HLO rather than one per
    branch (compile-time and code-size win; numerically identical)."""
    lp = jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(
            a, gi % cfg.n_shared_blocks, 0, keepdims=False),
        params["shared"])
    y, _ = T.block_apply(lp, cfg, x, positions)
    return y


def forward(params, cfg, tokens, remat_policy: str = "nothing"):
    x = jnp.take(cast(params["embed"]), tokens, axis=0)
    x = constraint(x, ("batch", "seq", None))
    positions = jnp.arange(x.shape[1])

    def group_fn(carry, inp):
        x = carry
        glp, gi = inp

        def mamba_fn(x, lp):
            return mamba_apply(lp, cfg, x), None

        x, _ = L.scan(mamba_fn, x, glp)
        x = _shared_apply(params, cfg, x, gi, positions)
        return x, None

    group_fn = T.remat(group_fn, remat_policy)
    n_groups = cfg.n_layers // cfg.shared_attn_period
    x, _ = L.scan(group_fn, x,
                  (params["groups"], jnp.arange(n_groups)))
    if "tail" in params:
        def mamba_fn(x, lp):
            return mamba_apply(lp, cfg, x), None
        x, _ = L.scan(mamba_fn, x, params["tail"])
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return T.unembed(params, cfg, x), jnp.float32(0.0)


def train_loss(params, cfg, batch, remat_policy: str = "nothing"):
    logits, _ = forward(params, cfg, batch["tokens"], remat_policy)
    return L.softmax_xent(logits, batch["labels"])


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def init_cache(cfg, batch: int, max_len: int, dtype=L.COMPUTE_DTYPE):
    d_in, H, N = _dims(cfg)
    P = cfg.ssm.head_dim
    period = cfg.shared_attn_period
    n_groups = cfg.n_layers // period
    tail = cfg.n_layers - n_groups * period
    kconv = cfg.ssm.conv_kernel
    cache = {
        "conv": jnp.zeros((n_groups, period, batch, kconv - 1, d_in + 2 * N),
                          dtype),
        "ssd": jnp.zeros((n_groups, period, batch, H, N, P), jnp.float32),
        "attn_k": jnp.zeros((n_groups, batch, cfg.n_kv_heads, max_len,
                             cfg.head_dim), dtype),
        "attn_v": jnp.zeros((n_groups, batch, cfg.n_kv_heads, max_len,
                             cfg.head_dim), dtype),
    }
    if tail:
        cache["conv_tail"] = jnp.zeros((tail, batch, kconv - 1, d_in + 2 * N),
                                       dtype)
        cache["ssd_tail"] = jnp.zeros((tail, batch, H, N, P), jnp.float32)
    return cache


def cache_axes(cfg):
    axes = {
        "conv": ("layers", None, "cache_batch", None, "heads"),
        "ssd": ("layers", None, "cache_batch", "heads", None, None),
        "attn_k": ("layers", "cache_batch", "kv_heads", "kv_seq", None),
        "attn_v": ("layers", "cache_batch", "kv_heads", "kv_seq", None),
    }
    period = cfg.shared_attn_period
    if cfg.n_layers % period:
        axes["conv_tail"] = ("layers", "cache_batch", None, "heads")
        axes["ssd_tail"] = ("layers", "cache_batch", "heads", None, None)
    return axes


def _mamba_decode(lp, cfg, x, conv_st, ssd_st):
    """x: (B, 1, D); conv_st: (B, k-1, C); ssd_st: (B, H, N, P)."""
    d_in, H, N = _dims(cfg)
    P = cfg.ssm.head_dim
    B = x.shape[0]
    h = L.rmsnorm(x, lp["ln"], cfg.norm_eps)
    z, xbc, dt = _split_proj(cfg, h[:, 0] @ cast(lp["in_proj"]))
    w = cast(lp["conv_w"])
    k = w.shape[0]
    window = jnp.concatenate([conv_st, xbc[:, None]], axis=1)  # (B, k, C)
    conv = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, w) + cast(lp["conv_b"]))
    xin, bvec, cvec = jnp.split(conv, [d_in, d_in + N], axis=-1)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])  # (B, H)
    decay = jnp.exp(-jnp.exp(lp["a_log"]) * dtf)                   # (B, H)
    xh = (xin.reshape(B, H, P) * dtf[..., None]).astype(jnp.float32)
    ssd_st = (decay[..., None, None] * ssd_st
              + bvec.astype(jnp.float32)[:, None, :, None] * xh[:, :, None, :])
    y = jnp.einsum("bn,bhnp->bhp", cvec.astype(jnp.float32), ssd_st)
    y = (y.astype(x.dtype) + xin.reshape(B, H, P) * cast(lp["d_skip"])[:, None])
    y = y.reshape(B, d_in) * jax.nn.silu(z)
    y = y * jax.lax.rsqrt(jnp.mean(jnp.square(y.astype(jnp.float32)), -1,
                                   keepdims=True) + cfg.norm_eps).astype(y.dtype)
    y = y * cast(lp["out_norm"])
    x = x + (y @ cast(lp["out_proj"]))[:, None]
    return x, window[:, 1:], ssd_st


def _shared_decode(params, cfg, x, gi, ck, cv, pos):
    lp = jax.tree.map(
        lambda a: jax.lax.dynamic_index_in_dim(
            a, gi % cfg.n_shared_blocks, 0, keepdims=False),
        params["shared"])
    return T.block_decode(lp, cfg, x, ck, cv, pos)


def decode_step(params, cfg, cache, tokens, pos):
    x = jnp.take(cast(params["embed"]), tokens, axis=0)   # (B, 1, D)
    n_groups = cfg.n_layers // cfg.shared_attn_period

    def group_fn(x, inp):
        glp, gi, conv_g, ssd_g, ck, cv = inp

        def mamba_fn(carry, inp2):
            x = carry
            lp, cst, sst = inp2
            x, cst, sst = _mamba_decode(lp, cfg, x, cst, sst)
            return x, (cst, sst)

        x, (conv_g, ssd_g) = L.scan(mamba_fn, x, (glp, conv_g, ssd_g))
        x, ck, cv = _shared_decode(params, cfg, x, gi, ck, cv, pos)
        return x, (conv_g, ssd_g, ck, cv)

    x, (conv, ssd, ck, cv) = L.scan(
        group_fn, x,
        (params["groups"], jnp.arange(n_groups), cache["conv"], cache["ssd"],
         cache["attn_k"], cache["attn_v"]))
    new_cache = dict(cache, conv=conv, ssd=ssd, attn_k=ck, attn_v=cv)
    if "tail" in params:
        def mamba_fn(carry, inp2):
            x = carry
            lp, cst, sst = inp2
            x, cst, sst = _mamba_decode(lp, cfg, x, cst, sst)
            return x, (cst, sst)
        x, (ct, st) = L.scan(
            mamba_fn, x, (params["tail"], cache["conv_tail"],
                          cache["ssd_tail"]))
        new_cache.update(conv_tail=ct, ssd_tail=st)
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return T.unembed(params, cfg, x), new_cache
