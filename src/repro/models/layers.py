"""Model building blocks: parameter templates, norms, RoPE, attention, MLP.

Parameters are plain nested dicts of arrays.  Each model defines a *template*
tree of :class:`Spec` descriptors — the single source of truth for shapes,
logical sharding axes, and initializers — from which ``init_params`` (random
materialization), ``abstract_params`` (ShapeDtypeStruct for the dry-run) and
``param_pspecs`` (PartitionSpec tree) are all derived.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.sharding.rules import Rules, constraint

COMPUTE_DTYPE = jnp.bfloat16
PARAM_DTYPE = jnp.float32

#: When True, model-level layer scans fully unroll.  Used by the dry-run's
#: cost probes: XLA's HloCostAnalysis counts a while-loop body ONCE, so the
#: roofline extracts exact per-layer costs from small unrolled probe models
#: (see launch/dryrun.py) instead of trusting under-counted scan totals.
SCAN_UNROLL = False


def scan(f, init, xs, length=None):
    """lax.scan for layer stacks, honoring the dry-run unroll probe flag."""
    return jax.lax.scan(f, init, xs, length=length,
                        unroll=True if SCAN_UNROLL else 1)


@dataclasses.dataclass(frozen=True)
class Spec:
    """Descriptor for one parameter tensor."""
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]      # logical sharding axes
    init: str = "normal"                 # normal | zeros | ones
    scale: Optional[float] = None        # fan-in scaling override

    def materialize(self, key) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, PARAM_DTYPE)
        if self.init == "ones":
            return jnp.ones(self.shape, PARAM_DTYPE)
        scale = self.scale
        if scale is None:
            fan_in = self.shape[0] if len(self.shape) > 1 else self.shape[-1]
            scale = 1.0 / math.sqrt(max(fan_in, 1))
        return jax.random.normal(key, self.shape, PARAM_DTYPE) * scale


def _tree_map_specs(fn, template):
    return jax.tree.map(fn, template,
                        is_leaf=lambda x: isinstance(x, Spec))


def init_params(key, template) -> Any:
    leaves, treedef = jax.tree.flatten(
        template, is_leaf=lambda x: isinstance(x, Spec))
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [s.materialize(k) for s, k in zip(leaves, keys)])


def abstract_params(template) -> Any:
    return _tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, PARAM_DTYPE), template)


def param_pspecs(template, rules: Rules) -> Any:
    return _tree_map_specs(lambda s: rules.spec_for(s.shape, s.axes), template)


def param_count(template) -> int:
    leaves = jax.tree.leaves(template, is_leaf=lambda x: isinstance(x, Spec))
    return sum(math.prod(s.shape) for s in leaves)


def stack_layers(layer_template, n: int) -> Any:
    """Prepend a scanned 'layers' axis to every Spec in a layer template."""
    return _tree_map_specs(
        lambda s: Spec((n,) + s.shape, ("layers",) + s.axes, s.init, s.scale),
        layer_template)


def cast(x):
    return x.astype(COMPUTE_DTYPE)


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------
def rmsnorm(x, scale, eps):
    return ops.rmsnorm(x, cast(scale), eps)


def linear(x, w, b=None):
    y = x @ cast(w)
    if b is not None:
        y = y + cast(b)
    return y


def rope(x, positions, theta: float):
    """Rotary embedding. x: (..., T, H, D); positions: (T,) or (..., T)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., T, half)
    cos = jnp.cos(ang)[..., :, None, :]                        # (..., T, 1, half)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(linear(x, w_gate)) * linear(x, w_up)
    h = constraint(h, ("batch", "seq", "mlp"))
    return linear(h, w_down)


def gelu_mlp(x, w_up, b_up, w_down, b_down):
    return linear(jax.nn.gelu(linear(x, w_up, b_up)), w_down, b_down)


# ---------------------------------------------------------------------------
# attention (GQA, RoPE, optional KV cache)
# ---------------------------------------------------------------------------
def attn_template(cfg, prefix_fsdp: str = "embed_fsdp") -> dict:
    D, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    t = {
        "wq": Spec((D, H * Dh), (prefix_fsdp, "heads")),
        "wk": Spec((D, Hkv * Dh), (prefix_fsdp, "kv_heads")),
        "wv": Spec((D, Hkv * Dh), (prefix_fsdp, "kv_heads")),
        "wo": Spec((H * Dh, D), ("heads", prefix_fsdp)),
    }
    if cfg.qkv_bias:
        t["bq"] = Spec((H * Dh,), ("heads",), init="zeros")
        t["bk"] = Spec((Hkv * Dh,), ("kv_heads",), init="zeros")
        t["bv"] = Spec((Hkv * Dh,), ("kv_heads",), init="zeros")
    return t


def attn_qkv(p, cfg, x, positions, *, use_rope=True):
    """x: (B, T, D) → q (B, H, T, Dh), k/v (B, Hkv, T, Dh)."""
    B, T, _ = x.shape
    H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = linear(x, p["wq"], p.get("bq")).reshape(B, T, H, Dh)
    k = linear(x, p["wk"], p.get("bk")).reshape(B, T, Hkv, Dh)
    v = linear(x, p["wv"], p.get("bv")).reshape(B, T, Hkv, Dh)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = constraint(q.transpose(0, 2, 1, 3), ("batch", "heads", "seq", None))
    k = constraint(k.transpose(0, 2, 1, 3), ("batch", "kv_heads", "seq", None))
    v = constraint(v.transpose(0, 2, 1, 3), ("batch", "kv_heads", "seq", None))
    return q, k, v


def attn_out(p, x_attn):
    """x_attn: (B, H, T, Dh) → (B, T, D)."""
    B, H, T, Dh = x_attn.shape
    y = x_attn.transpose(0, 2, 1, 3).reshape(B, T, H * Dh)
    return linear(y, p["wo"])


def self_attention(p, cfg, x, positions, *, causal=True, use_rope=True,
                   q_offset=0):
    q, k, v = attn_qkv(p, cfg, x, positions, use_rope=use_rope)
    o = ops.flash_attention(q, k, v, causal=causal, q_offset=q_offset)
    return attn_out(p, o)


def decode_attention(p, cfg, x, cache_k, cache_v, pos, *, use_rope=True):
    """One-token decode. x: (B, 1, D); cache_k/v: (B, Hkv, Tmax, Dh);
    pos: scalar position OR (B,) per-lane positions (continuous batching —
    each serving slot may be at a different depth).  Returns (y, k, v)."""
    B = x.shape[0]
    Hkv = cfg.n_kv_heads
    pos = jnp.asarray(pos, jnp.int32)
    per_lane = pos.ndim == 1
    positions = (pos[:, None] if per_lane
                 else jnp.full((1,), pos, jnp.int32))
    q, k, v = attn_qkv(p, cfg, x, positions, use_rope=use_rope)
    if per_lane:
        b_idx = jnp.arange(B)[:, None]
        h_idx = jnp.arange(Hkv)[None, :]
        cache_k = cache_k.at[b_idx, h_idx, pos[:, None]].set(
            k[:, :, 0].astype(cache_k.dtype))
        cache_v = cache_v.at[b_idx, h_idx, pos[:, None]].set(
            v[:, :, 0].astype(cache_v.dtype))
        row_pos = pos[:, None, None, None]
    else:
        cache_k = jax.lax.dynamic_update_slice_in_dim(
            cache_k, k.astype(cache_k.dtype), pos, axis=2)
        cache_v = jax.lax.dynamic_update_slice_in_dim(
            cache_v, v.astype(cache_v.dtype), pos, axis=2)
        row_pos = pos
    Hq = cfg.n_heads
    scale = cfg.head_dim ** -0.5
    from repro.runtime.flags import FLAGS
    if FLAGS.decode_gqa_packed:
        # grouped-query path: no GQA repeat, no fp32 materialization of the
        # cache — contraction accumulates in f32 via preferred_element_type.
        G = Hq // Hkv
        qg = q.reshape(B, Hkv, G, cfg.head_dim)
        s = jnp.einsum("bhgd,bhkd->bhgk", qg, cache_k,
                       preferred_element_type=jnp.float32) * scale
        mask = (jnp.arange(cache_k.shape[2])[None, None, None, :]
                <= (row_pos if per_lane else
                    jnp.asarray(row_pos)[None, None, None, None]))
        s = jnp.where(mask, s, -1e30)
        w = jax.nn.softmax(s, axis=-1)
        # keep w in f32: downcasting the weights to the cache dtype rounds
        # them and diverges from the baseline path (the packed win is the
        # avoided GQA repeat, not the weight precision)
        o = jnp.einsum("bhgk,bhkd->bhgd", w, cache_v,
                       preferred_element_type=jnp.float32)
        o = o.reshape(B, Hq, 1, cfg.head_dim).astype(x.dtype)
        return attn_out(p, o), cache_k, cache_v
    kk = jnp.repeat(cache_k, Hq // Hkv, axis=1)
    vv = jnp.repeat(cache_v, Hq // Hkv, axis=1)
    # masked single-query attention over the cache (memory-bound; jnp path)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    mask = jnp.arange(cache_k.shape[2])[None, None, None, :] <= row_pos
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", w, vv.astype(jnp.float32)).astype(x.dtype)
    return attn_out(p, o), cache_k, cache_v


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------
def softmax_xent(logits, labels, mask=None):
    """logits (..., V) fp32 CE; labels int; mask optional weights."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
