"""Uniform Model API over all architecture families.

``build(cfg)`` returns a :class:`Model` exposing: template / init /
train_loss / decode_step / init_cache / cache_pspecs / input shapes —
everything the runtime, launcher and dry-run need, family-agnostic.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.sharding.rules import Rules
from . import layers as L
from . import rwkv6 as R
from . import transformer as T
from . import whisper as W
from . import zamba2 as Z


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    template: Any
    train_loss: Callable            # (params, batch, remat_policy) -> loss
    decode_step: Callable           # (params, cache, tokens, pos) -> (logits, cache)
    init_cache: Callable            # (batch, max_len) -> cache pytree
    cache_axes: Any                 # logical axes pytree (mirrors cache)

    def init(self, key):
        return L.init_params(key, self.template)

    def abstract_params(self):
        return L.abstract_params(self.template)

    def param_pspecs(self, rules: Rules):
        return L.param_pspecs(self.template, rules)

    def param_count(self) -> int:
        return L.param_count(self.template)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: routed experts scaled k/E)."""
        import math
        total = 0
        flat, _ = jax.tree_util.tree_flatten_with_path(
            self.template, is_leaf=lambda x: isinstance(x, L.Spec))
        m = self.cfg.moe
        for path, spec in flat:
            n = math.prod(spec.shape)
            keys = jax.tree_util.keystr(path)
            if m and "moe" in keys and "shared" not in keys \
                    and "router" not in keys:
                n = int(n * m.top_k / max(m.num_experts, 1))
            total += n
        return total

    def cache_pspecs(self, batch: int, max_len: int, rules: Rules):
        cache = jax.eval_shape(lambda: self.init_cache(batch, max_len))
        return jax.tree.map(
            lambda leaf, axes: rules.spec_for(leaf.shape, axes),
            cache, self.cache_axes)

    # ---- input construction (ShapeDtypeStruct for dry-run, arrays for runs)
    def input_specs(self, shape: ShapeConfig, abstract: bool = True):
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len

        def arr(shp, dtype=jnp.int32):
            if abstract:
                return jax.ShapeDtypeStruct(shp, dtype)
            if dtype == jnp.int32:
                return jnp.zeros(shp, dtype)
            return jnp.zeros(shp, dtype)

        if shape.kind in ("train", "prefill"):
            if cfg.family == "audio":
                return {"frames": arr((B, S, cfg.d_model), jnp.float32),
                        "tokens": arr((B, S)), "labels": arr((B, S))}
            if cfg.family == "vlm":
                text = S - cfg.prefix_len
                return {"prefix_embeds": arr((B, cfg.prefix_len, cfg.d_model),
                                             jnp.float32),
                        "tokens": arr((B, text)), "labels": arr((B, text))}
            return {"tokens": arr((B, S)), "labels": arr((B, S))}
        # decode: one new token against a seq_len-deep cache
        return {"tokens": arr((B, 1)),
                "pos": jax.ShapeDtypeStruct((), jnp.int32) if abstract
                else jnp.int32(S - 1)}


def build(cfg: ArchConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        from repro.runtime.flags import FLAGS
        kv_axes = ("layers", "cache_batch", "kv_heads", "kv_seq", None)
        cache_axes = {"k": kv_axes, "v": kv_axes}
        if FLAGS.decode_kv_int8:
            cache_axes["k_s"] = kv_axes[:-1]
            cache_axes["v_s"] = kv_axes[:-1]
        return Model(
            cfg=cfg,
            template=T.template(cfg),
            train_loss=lambda p, b, rp="nothing": T.train_loss(p, cfg, b, rp),
            decode_step=lambda p, c, t, pos: T.decode_step(p, cfg, c, t, pos),
            init_cache=lambda b, m, dt=None: T.init_cache(
                cfg, b, m, dt if dt is not None else L.COMPUTE_DTYPE),
            cache_axes=cache_axes,
        )
    if fam == "ssm":
        return Model(
            cfg=cfg,
            template=R.template(cfg),
            train_loss=lambda p, b, rp="nothing": R.train_loss(p, cfg, b, rp),
            decode_step=lambda p, c, t, pos: R.decode_step(p, cfg, c, t, pos),
            init_cache=lambda b, m, dt=None: R.init_cache(
                cfg, b, m, dt if dt is not None else L.COMPUTE_DTYPE),
            cache_axes=R.cache_axes(),
        )
    if fam == "hybrid":
        return Model(
            cfg=cfg,
            template=Z.template(cfg),
            train_loss=lambda p, b, rp="nothing": Z.train_loss(p, cfg, b, rp),
            decode_step=lambda p, c, t, pos: Z.decode_step(p, cfg, c, t, pos),
            init_cache=lambda b, m, dt=None: Z.init_cache(
                cfg, b, m, dt if dt is not None else L.COMPUTE_DTYPE),
            cache_axes=Z.cache_axes(cfg),
        )
    if fam == "audio":
        return Model(
            cfg=cfg,
            template=W.template(cfg),
            train_loss=lambda p, b, rp="nothing": W.train_loss(p, cfg, b, rp),
            decode_step=lambda p, c, t, pos: W.decode_step(p, cfg, c, t, pos),
            init_cache=lambda b, m, dt=None: W.init_cache(
                cfg, b, m, dt if dt is not None else L.COMPUTE_DTYPE),
            cache_axes=W.cache_axes(),
        )
    raise ValueError(f"unknown family {fam!r}")


def build_arch(arch: str) -> Model:
    from repro.configs.registry import get_config
    return build(get_config(arch))


def build_smoke(arch: str) -> Model:
    from repro.configs.registry import get_config
    return build(get_config(arch).smoke())
