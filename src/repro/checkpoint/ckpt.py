"""Sharded checkpointing: atomic, async, elastic (reshard-on-restore).

Layout per step::

    <dir>/step_000100.tmp/     — written first
        manifest.json          — tree structure, shapes, dtypes, leaf files
        leaf_00000.npy … one file per pytree leaf (full array; per-shard
                         files when processes > 1 — single-host here)
    <dir>/step_000100/         — atomic rename on completion
    <dir>/LATEST               — pointer file, updated last

Restore rebuilds the pytree and ``device_put``s every leaf under the *target*
sharding — which may belong to a different mesh than the one that saved it
(elastic re-scaling: tested by saving under one mesh and restoring under
another in tests/test_fault_tolerance.py).

``AsyncCheckpointer`` snapshots to host memory synchronously (cheap) and does
file I/O on a worker thread so the train loop is never blocked on disk.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

#: numpy can't serialize ML dtypes — stored as a same-width integer view,
#: with the logical dtype recorded in the manifest.
_CODEC = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _encode(arr: np.ndarray) -> tuple[np.ndarray, str]:
    name = arr.dtype.name
    if name in _CODEC:
        return arr.view(_CODEC[name][1]), name
    return arr, name


def _decode(arr: np.ndarray, name: str) -> np.ndarray:
    if name in _CODEC:
        return arr.view(_CODEC[name][0])
    return arr


def _paths_of(tree) -> list[str]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(path) for path, _ in flat]


def save(directory: str, step: int, tree: Any, keep: int = 3) -> str:
    """Synchronous atomic save; returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(directory, name + ".tmp")
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    manifest = {"step": step, "leaves": []}
    for i, (path, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        stored, dtype_name = _encode(arr)
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), stored)
        manifest["leaves"].append({
            "path": jax.tree_util.keystr(path),
            "file": fname,
            "shape": list(arr.shape),
            "dtype": dtype_name,
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(os.path.join(directory, "LATEST.tmp"), "w") as f:
        f.write(name)
    os.replace(os.path.join(directory, "LATEST.tmp"),
               os.path.join(directory, "LATEST"))
    _retain(directory, keep)
    return final


def _retain(directory: str, keep: int):
    ckpts = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in ckpts[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    ptr = os.path.join(directory, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(directory, name)):
        return None
    return int(name.removeprefix("step_"))


def restore(directory: str, template: Any, step: Optional[int] = None,
            shardings: Any = None) -> tuple[Any, int]:
    """Rebuild ``template``-shaped tree; place under ``shardings`` if given.

    ``shardings`` may target a different mesh than the saver's (elastic).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}

    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_flat = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(flat))
    leaves = []
    for (kpath, tmpl), shard in zip(flat, shard_flat):
        entry = by_path[jax.tree_util.keystr(kpath)]
        arr = _decode(np.load(os.path.join(path, entry["file"])),
                      entry["dtype"])
        want = tuple(getattr(tmpl, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(f"shape mismatch at {entry['path']}: "
                             f"{arr.shape} vs {want}")
        leaves.append(jax.device_put(arr, shard) if shard is not None
                      else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), step


class AsyncCheckpointer:
    """Snapshot synchronously, write on a worker thread (latency hiding)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.error: Optional[BaseException] = None

    def save(self, step: int, tree: Any):
        self.wait()
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save(self.directory, step, snapshot, keep=self.keep)
            except BaseException as e:      # surfaced on next wait()
                self.error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.error is not None:
            err, self.error = self.error, None
            raise err
