"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (derived is a compact JSON blob).

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only fig10,tableII
"""
from __future__ import annotations

import argparse
import json

from benchmarks import explorer, extensions, frontend, multitenant, \
    paper_figs, population, priority, serving, stepwidth

SECTIONS = {
    "tableII": paper_figs.table2,
    "fig7": paper_figs.fig7,
    "fig8": paper_figs.fig8,
    "fig9": paper_figs.fig9,
    "fig10": paper_figs.fig10,
    "multiapp": extensions.multi_app_sharing,
    "multitenant": multitenant.section,
    "priority": priority.section,
    "population": population.section,
    "frontend": frontend.section,
    "serving": serving.section,
    "stepwidth": stepwidth.section,
    "explorer": explorer.section,
    "ablation": extensions.design_ablation,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated section names")
    args = ap.parse_args()
    names = (args.only.split(",") if args.only else list(SECTIONS))
    unknown = [n for n in names if n not in SECTIONS]
    if unknown:
        raise SystemExit(f"unknown section(s) {unknown}; "
                         f"choose from {list(SECTIONS)}")

    print("name,us_per_call,derived")
    for name in names:
        for row_name, us, derived in SECTIONS[name]():
            print(f"{row_name},{us:.1f},"
                  f"\"{json.dumps(derived, default=float)}\"", flush=True)


if __name__ == "__main__":
    main()
