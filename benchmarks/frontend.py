"""Per-tenant frontends benchmark: closing the measured head-of-line bound.

PR 4's ``rs_admission`` study (BENCH_priority.json) recorded a negative
finding: in the merged-stream model, per-pid RS admission caps provably
bound a flood's reservation-station occupancy, yet the late
high-priority tenant got *worse* (1.50x -> 2.50x slowdown) — with ONE
shared in-order frontend, dispatch order is stream order, so a blocking
admission stall on the flood also stalls every instruction queued behind
it.  This benchmark re-runs that exact contention scenario on the
per-tenant frontend subsystem (``core/hts/frontend.py``): each tenant is
its own dispatch stream, the late arrival is a real *arrival offset*
instead of a nop prelude, and ``rs_caps`` now backpressure only the
capped stream (the arbiter skips ineligible streams).

Headline (the acceptance bar of ISSUE 5): with per-tenant frontends the
late w8 tenant's slowdown under greedy ``rs_caps`` is strictly below the
merged-stream 2.50x and at most 1.3x solo, aggregate throughput stays
within 10% of the uncapped run, and every reported scenario is
differentially verified (``hts.compare``: golden == machine, event-skip
on and off, including one batched multi-frontend population through
``run_many``).

    PYTHONPATH=src python -m benchmarks.frontend            # writes JSON
    PYTHONPATH=src python -m benchmarks.frontend --smoke    # CI: no JSON

Slowdown convention: a late tenant is judged from its *arrival* —
``slowdown = (shared makespan - arrival) / solo makespan`` — so 1.0
means "as fast as running alone from the moment its CPU showed up".
The JSON lands in ``BENCH_frontend.json``; docs/BENCHMARKS.md documents
the schema with executable assertions.  Cycle metrics are deterministic;
``wall_us`` entries are medians of 3 runs (idle machine, per the PR 4
noise note).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import statistics
import time

from repro.core import hts
from repro.core.hts.builder import Program

from benchmarks.priority import _max_rs_occupancy, rs_admission_study

HI_PID = 1
FUNC = "dct"                        # all tenants contend for one class
DEFAULT_OUT = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_frontend.json"


def hi_stream(chain: int = 8) -> Program:
    """The latency-sensitive app as its own stream: a RAW chain (pid 1).

    No nop prelude — under per-tenant frontends the late arrival is a
    *stream arrival offset*, not instructions queued behind the floods.
    """
    p = Program("hi", region_base=0x100)
    frame = p.input(0x10, 4, "frame")
    with p.process(HI_PID):
        prev = frame
        for i in range(chain):
            prev = p.task(FUNC, in_=prev, out=4, in_size=4, tid=i)
    return p


def greedy_stream(pid: int, tasks: int = 10) -> Program:
    """A best-effort flood: ``tasks`` independent same-class tasks.

    Same shape as ``benchmarks.priority.greedy_tenant`` but with compact
    region bases — every tenant's outputs stay inside the 1024-word
    image even at 4 greedy tenants, so the scenario is runnable on the
    golden oracle (which the differential verification here requires).
    """
    p = Program(f"greedy{pid}", region_base=0x200 + 0x80 * (pid - 2))
    frame = p.input(0x10, 4, "frame")
    with p.process(pid):
        for i in range(tasks):
            p.task(FUNC, in_=frame, out=4, tid=i & 0xF)
    return p


def contended_streams(n_greedy: int, *, chain: int = 8,
                      greedy_tasks: int = 10, arrive: int = 40,
                      weight: int = 8, cap: int | None = None):
    """The rs_admission tenant mix as a MultiProgram: the hi tenant's
    stream arrives at cycle ``arrive`` (after the floods have filled the
    shared window), greedy pids optionally RS-admission-capped."""
    greedy_pids = tuple(range(2, 2 + n_greedy))
    tenants = [hi_stream(chain)] + [greedy_stream(pid, greedy_tasks)
                                    for pid in greedy_pids]
    return Program.merge(
        tenants, f"fe_{n_greedy}g_w{weight}_cap{cap or 0}",
        require_distinct_pids=True, frontends=True,
        arrivals=[arrive] + [0] * n_greedy,
        priorities={HI_PID: weight} if weight else None,
        rs_caps={p: cap for p in greedy_pids} if cap else None)


def _point(prog, *, solo_mk: int, arrive: int, n_greedy: int,
           n_fu: int, scheduler: str) -> tuple[dict, "hts.Result"]:
    """Run one multi-frontend scenario and report the hi tenant's view."""
    walls = []
    for _ in range(3):
        t0 = time.perf_counter()
        r = hts.run(prog, scheduler=scheduler, n_fu=n_fu)
        walls.append((time.perf_counter() - t0) * 1e6)
    mk = r.app_makespan(HI_PID)
    greedy_pids = range(2, 2 + n_greedy)
    return {
        "hi_makespan": mk,
        "hi_slowdown_vs_solo": (mk - arrive) / solo_mk,
        "shared_cycles": r.cycles,
        "hi_dispatch_stall_cycles": r.dispatch_stall_cycles(HI_PID),
        "hi_time_to_first_issue": r.time_to_first_issue(HI_PID),
        "hi_rs_occupancy_at_dispatch": r.rs_occupancy_at_dispatch(HI_PID),
        "max_greedy_rs_occupancy":
            max(_max_rs_occupancy(r, p) for p in greedy_pids),
        "wall_us_median": statistics.median(walls),
    }, r


def trajectory(n_greedy: int = 4, n_fu: int = 2, *, chain: int = 8,
               greedy_tasks: int = 10, arrive: int = 40, weight: int = 8,
               cap: int = 4, scheduler: str = "hts_spec",
               verify: bool = True) -> dict:
    """The full study: merged-stream reference vs per-tenant frontends."""
    solo = hts.run(hi_stream(chain), scheduler=scheduler, n_fu=n_fu)
    solo_mk = solo.app_makespan(HI_PID)

    # the PR 4 merged-stream reference, recomputed live (same scenario)
    merged = rs_admission_study(n_greedy, n_fu, chain=chain,
                                greedy_tasks=greedy_tasks, cap=cap,
                                weight=weight, scheduler=scheduler)

    scenarios = {
        "rr_unweighted": contended_streams(
            n_greedy, chain=chain, greedy_tasks=greedy_tasks,
            arrive=arrive, weight=0, cap=None),
        "uncapped": contended_streams(
            n_greedy, chain=chain, greedy_tasks=greedy_tasks,
            arrive=arrive, weight=weight, cap=None),
        "capped": contended_streams(
            n_greedy, chain=chain, greedy_tasks=greedy_tasks,
            arrive=arrive, weight=weight, cap=cap),
    }
    points = {}
    for key, prog in scenarios.items():
        points[key], _ = _point(prog, solo_mk=solo_mk, arrive=arrive,
                                n_greedy=n_greedy, n_fu=n_fu,
                                scheduler=scheduler)

    # differential verification: every reported scenario, golden == machine
    # across event-skip modes — singly AND as one batched population
    verified = False
    if verify:
        for prog in scenarios.values():
            hts.compare(prog, schedulers=(scheduler,), n_fu=n_fu)
        hts.compare(list(scenarios.values()), schedulers=(scheduler,),
                    n_fu=n_fu)
        verified = True

    capped, uncapped = points["capped"], points["uncapped"]
    return {
        "bench": "frontend",
        "scheduler": scheduler,
        "scenario": {"mix": f"1hi+{n_greedy}greedy", "n_fu": n_fu,
                     "hi_chain": chain, "greedy_tasks": greedy_tasks,
                     "hi_arrival": arrive, "hi_weight": weight,
                     "rs_cap": cap, "hi_solo_cycles": solo_mk},
        "merged_reference": {
            "hi_slowdown_weighted": merged["hi_slowdown_weighted"],
            "hi_slowdown_weighted_capped":
                merged["hi_slowdown_weighted_capped"],
            "note": "the PR 4 rs_admission study, recomputed live — "
                    "caps bound occupancy but worsen the late tenant "
                    "(merged-stream head-of-line blocking)",
        },
        "multi_frontend": points,
        "headline": {
            "hi_slowdown_capped": capped["hi_slowdown_vs_solo"],
            "below_merged_capped": capped["hi_slowdown_vs_solo"]
            < merged["hi_slowdown_weighted_capped"],
            "qos_closed": capped["hi_slowdown_vs_solo"] <= 1.3,
            "throughput_vs_uncapped":
                uncapped["shared_cycles"] / capped["shared_cycles"],
            "throughput_preserved":
                uncapped["shared_cycles"] / capped["shared_cycles"] >= 0.9,
            "verified_golden_equiv": verified,
        },
    }


def population_study(n: int = 8, *, seed0: int = 0,
                     scheduler: str = "hts_spec") -> dict:
    """Generated multi-frontend scenarios (staggered arrivals) as one
    batched ``run_many`` call, every scenario golden-verified."""
    from repro.core.hts import workloads
    scs = [workloads.generate_scenario(s, kernels=workloads.CHEAP_MIX,
                                       frontends=True, arrivals=True)
           for s in range(seed0, seed0 + n)]
    progs = [sc.multi for sc in scs]
    rep = hts.compare(progs, schedulers=(scheduler,), n_fu=2)
    walls = []
    for _ in range(3):
        pr = hts.run_many(progs, scheduler=scheduler, n_fu=2)
        walls.append(pr.wall_us)
    return {
        "n_scenarios": n, "seed0": seed0,
        "cycles": [int(c) for c in rep.cycles[scheduler]],
        "all_verified": True,
        "batched_wall_us_median": statistics.median(walls),
        "scenarios_per_sec": pr.scenarios_per_second(
            statistics.median(walls)),
    }


def section():
    """``benchmarks.run`` integration: (name, us, derived) rows."""
    t0 = time.perf_counter()
    data = trajectory(2, 2, greedy_tasks=6, arrive=20, verify=False)
    us = (time.perf_counter() - t0) * 1e6
    h = data["headline"]
    return [("frontend/1hi+2greedy/fu2", us, {
        "hi_slowdown_merged_capped":
            data["merged_reference"]["hi_slowdown_weighted_capped"],
        "hi_slowdown_fe_capped": h["hi_slowdown_capped"],
        "throughput_vs_uncapped": h["throughput_vs_uncapped"],
    })]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="scaled-down run with assertions, no JSON")
    ap.add_argument("--greedy", type=int, default=4)
    ap.add_argument("--fu", type=int, default=2)
    ap.add_argument("--cap", type=int, default=4)
    ap.add_argument("--arrive", type=int, default=40)
    ap.add_argument("--scheduler", default="hts_spec")
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    args = ap.parse_args()

    if args.smoke:
        data = trajectory(2, 2, chain=6, greedy_tasks=8, arrive=24,
                          scheduler=args.scheduler)
        pop = population_study(4, scheduler=args.scheduler)
        h = data["headline"]
        assert h["verified_golden_equiv"] and pop["all_verified"]
        assert h["below_merged_capped"], data
        assert h["qos_closed"], data
        assert h["throughput_preserved"], data
        print(f"smoke OK: capped slowdown "
              f"{h['hi_slowdown_capped']:.2f} (merged was "
              f"{data['merged_reference']['hi_slowdown_weighted_capped']:.2f}),"
              f" throughput {h['throughput_vs_uncapped']:.3f}, "
              f"{pop['n_scenarios']}-scenario population verified at "
              f"{pop['scenarios_per_sec']:.1f} scen/s")
        return

    data = trajectory(args.greedy, args.fu, cap=args.cap,
                      arrive=args.arrive, scheduler=args.scheduler)
    data["population"] = population_study(8, scheduler=args.scheduler)
    out = pathlib.Path(args.out)
    out.write_text(json.dumps(data, indent=2, default=float) + "\n")
    print(f"wrote {out}")
    m = data["merged_reference"]
    print(f"  merged reference: weighted "
          f"{m['hi_slowdown_weighted']:.2f} -> capped "
          f"{m['hi_slowdown_weighted_capped']:.2f} (head-of-line bound)")
    for key, p in data["multi_frontend"].items():
        print(f"  frontends/{key:<13} hi slowdown "
              f"{p['hi_slowdown_vs_solo']:.2f}  stall "
              f"{p['hi_dispatch_stall_cycles']:>5}  greedy RS occ "
              f"{p['max_greedy_rs_occupancy']:>2}  cycles "
              f"{p['shared_cycles']}")
    h = data["headline"]
    print(f"  headline: capped slowdown {h['hi_slowdown_capped']:.2f} "
          f"(<= 1.3: {h['qos_closed']}; below merged 2.50x: "
          f"{h['below_merged_capped']}), throughput vs uncapped "
          f"{h['throughput_vs_uncapped']:.3f} (>= 0.9: "
          f"{h['throughput_preserved']}), verified "
          f"{h['verified_golden_equiv']}")


if __name__ == "__main__":
    main()
