"""Multi-tenant sharing benchmark: tenant mixes × FU counts.

For each tenant-count point a seeded scenario (``core/hts/workloads.py``) is
run shared (N-way merged, one HTS) and solo (each tenant alone on the same
pool), producing the metrics the paper's single global makespan hides:

* per-app makespan — when each tenant's last task completed under sharing;
* fairness — per-app slowdown vs its solo run, and the max across tenants;
* sharing gain — serial (sum of solos) over shared cycles;

plus the ``hts.sweep`` strong-scaling trajectory of every merged program
(one compiled machine per scheduler, FU axis ``vmap``-batched).

    PYTHONPATH=src python -m benchmarks.multitenant          # writes JSON
    PYTHONPATH=src python -m benchmarks.multitenant --tenants 2,4,8 --fu 1,2,4

The JSON lands in ``BENCH_multitenant.json`` (repo root by default).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

from repro.core import hts
from repro.core.hts import workloads

DEFAULT_TENANTS = (2, 4, 6, 8)
DEFAULT_FU = (1, 2, 4)
DEFAULT_OUT = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_multitenant.json"


def bench_point(n_tenants: int, *, seed: int = 0, fu_points=DEFAULT_FU,
                scheduler: str = "hts_spec") -> dict:
    """One tenant-count point: shared vs solo at every FU count + sweep."""
    sc = workloads.generate_scenario(seed * 100 + n_tenants,
                                     n_tenants=n_tenants)
    point: dict = {"n_tenants": n_tenants, "seed": sc.seed,
                   "scenario": sc.name, "scheduler": scheduler, "fu": {}}
    for n_fu in fu_points:
        t0 = time.perf_counter()
        shared = hts.run(sc.merged, scheduler=scheduler, n_fu=n_fu)
        solos = workloads.solo_results(sc, scheduler=scheduler, n_fu=n_fu)
        fair = shared.fairness(solos)
        serial = sum(r.cycles for r in solos.values())
        point.setdefault("n_tasks", {str(p): len(r)
                                     for p, r in shared.by_pid().items()})
        point["fu"][str(n_fu)] = {
            "shared_cycles": shared.cycles,
            "serial_cycles": serial,
            "sharing_gain": serial / shared.cycles,
            "utilization": shared.utilization,
            "per_app_makespan": {str(p): shared.app_makespan(p)
                                 for p in sc.pids},
            "solo_cycles": {str(p): solos[p].cycles for p in sc.pids},
            "slowdowns": {str(p): s for p, s in fair.slowdowns.items()},
            "max_slowdown": fair.max_slowdown,
            "mean_slowdown": fair.mean_slowdown,
            "wall_us": (time.perf_counter() - t0) * 1e6,
        }
    sw = hts.sweep(sc.merged, n_fu=fu_points,
                   schedulers=("naive", "hts_spec"), max_prog=256)
    point["sweep"] = {
        "n_fu": [list(p) for p in sw.n_fu_list],
        "cycles": {s: [int(c) for c in sw.cycles[s]] for s in sw.schedulers},
        "speedup_hts_vs_naive": [float(x)
                                 for x in sw.speedup("hts_spec", "naive")],
    }
    return point


def trajectory(tenants=DEFAULT_TENANTS, fu_points=DEFAULT_FU,
               scheduler: str = "hts_spec", seed: int = 0) -> dict:
    return {
        "bench": "multitenant",
        "scheduler": scheduler,
        "fu_points": list(fu_points),
        "points": [bench_point(n, seed=seed, fu_points=fu_points,
                               scheduler=scheduler) for n in tenants],
    }


def section():
    """``benchmarks.run`` integration: (name, us, derived) rows."""
    rows = []
    for n in (2, 4, 8):
        t0 = time.perf_counter()
        point = bench_point(n, fu_points=(2,))
        us = (time.perf_counter() - t0) * 1e6
        fu2 = point["fu"]["2"]
        rows.append((f"multitenant/tenants{n}/fu2", us, {
            "sharing_gain": fu2["sharing_gain"],
            "max_slowdown": fu2["max_slowdown"],
            "utilization": fu2["utilization"],
        }))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tenants", default=",".join(map(str, DEFAULT_TENANTS)),
                    help="comma-separated tenant counts")
    ap.add_argument("--fu", default=",".join(map(str, DEFAULT_FU)),
                    help="comma-separated FU counts per class")
    ap.add_argument("--scheduler", default="hts_spec")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    args = ap.parse_args()
    tenants = tuple(int(x) for x in args.tenants.split(","))
    fu_points = tuple(int(x) for x in args.fu.split(","))
    data = trajectory(tenants, fu_points, args.scheduler, args.seed)
    out = pathlib.Path(args.out)
    out.write_text(json.dumps(data, indent=2, default=float) + "\n")
    print(f"wrote {out}")
    for p in data["points"]:
        fu_max = p["fu"][str(fu_points[-1])]
        print(f"  tenants={p['n_tenants']:<2} gain={fu_max['sharing_gain']:.2f} "
              f"max_slowdown={fu_max['max_slowdown']:.2f} "
              f"util={fu_max['utilization']:.1%}")


if __name__ == "__main__":
    main()
