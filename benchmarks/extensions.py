"""Beyond-figure benchmark sections: multi-application accelerator sharing
(the paper's abstract motivation) and HTS design-parameter ablations (the
paper names dispatch width / window size as design-time parameters)."""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.hts import assembler, costs, machine, multiapp
from repro.core.hts.golden import HtsParams

PARAMS = HtsParams(mem_words=4096, tracker_entries=128)


def _cycles(bench, sched="hts_spec", n_fu=2, cost_obj=None, params=None):
    code = assembler.assemble(bench.asm)
    t0 = time.perf_counter()
    out = machine.simulate(code, cost_obj or costs.costs_by_name(sched),
                           params or PARAMS, n_fu=np.array([n_fu] * 10),
                           mem_init=bench.mem_init, effects=bench.effects)
    assert out["halted"], bench.name
    return int(out["cycles"]), (time.perf_counter() - t0) * 1e6


def multi_app_sharing(bands: int = 2, tiles: int = 40):
    """Two applications (audio pid=0, image pid=1) share one accelerator
    pool: HTS-shared makespan vs running the apps serially.  Mixes are
    complementary (audio: FFT units; image: DCT/vector units) and sized to
    comparable standalone makespans, so sharing should approach
    max(a, b) ≪ a + b."""
    rows = []
    audio = multiapp.audio_straightline(bands)
    image = multiapp.image_compression(tiles)
    shared = multiapp.interleave(audio, image)
    for n_fu in (1, 2, 4):
        ca, _ = _cycles(audio, n_fu=n_fu)
        ci, _ = _cycles(image, n_fu=n_fu)
        cs, us = _cycles(shared, n_fu=n_fu)
        rows.append((f"multiapp/shared_vs_serial/fu{n_fu}", us, {
            "audio_cycles": ca, "image_cycles": ci,
            "serial_cycles": ca + ci, "shared_cycles": cs,
            "sharing_gain": (ca + ci) / cs,
            "ideal_max": max(ca, ci),
        }))
    return rows


def design_ablation(bands: int = 8):
    """HTS design parameters: issue width, RS window, CDB width."""
    from repro.core.hts.programs import audio_compression
    bench = audio_compression(bands, time_domain=False)
    rows = []
    base = costs.hts_costs(True)
    for issue_w in (1, 2, 4, 8):
        c = dataclasses.replace(base, issue_width=issue_w)
        cyc, us = _cycles(bench, cost_obj=c, n_fu=4)
        rows.append((f"ablation/issue_width{issue_w}", us, {"cycles": cyc}))
    for cdb_w in (1, 2, 4):
        c = dataclasses.replace(base, cdb_width=cdb_w)
        cyc, us = _cycles(bench, cost_obj=c, n_fu=4)
        rows.append((f"ablation/cdb_width{cdb_w}", us, {"cycles": cyc}))
    for rs in (4, 8, 16, 64):
        p = dataclasses.replace(PARAMS, rs_entries=rs)
        cyc, us = _cycles(bench, n_fu=4, params=p)
        rows.append((f"ablation/rs_entries{rs}", us, {"cycles": cyc}))
    for tlb in (2, 4, 16):
        p = dataclasses.replace(PARAMS, tlb_entries=tlb,
                                tm_slots=max(tlb, 2))
        cyc, us = _cycles(bench, n_fu=4, params=p)
        rows.append((f"ablation/tlb_entries{tlb}", us, {"cycles": cyc}))
    return rows
