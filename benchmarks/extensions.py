"""Beyond-figure benchmark sections: multi-application accelerator sharing
(the paper's abstract motivation) and HTS design-parameter ablations (the
paper names dispatch width / window size as design-time parameters).

All simulation goes through the ``hts.run`` facade — no per-file ``_sim``
wrapper, and non-halting runs raise ``hts.SimulationError`` naming the
offending program/scheduler instead of a bare assert.
"""
from __future__ import annotations

import dataclasses

from repro.core import hts
from repro.core.hts import costs, programs

PARAMS = hts.HtsParams(mem_words=4096, tracker_entries=128)


def multi_app_sharing(bands: int = 2, tiles: int = 40):
    """Two applications (audio pid=0, image pid=1) share one accelerator
    pool: HTS-shared makespan vs running the apps serially.  Mixes are
    complementary (audio: FFT units; image: DCT/vector units) and sized to
    comparable standalone makespans, so sharing should approach
    max(a, b) ≪ a + b."""
    rows = []
    audio = programs.audio_straightline(bands)
    image = programs.image_compression(tiles)
    shared = programs.merge_benches([audio, image])
    for n_fu in (1, 2, 4):
        ca = hts.run(audio, n_fu=n_fu, params=PARAMS).cycles
        ci = hts.run(image, n_fu=n_fu, params=PARAMS).cycles
        rs = hts.run(shared, n_fu=n_fu, params=PARAMS)
        rows.append((f"multiapp/shared_vs_serial/fu{n_fu}", rs.wall_us, {
            "audio_cycles": ca, "image_cycles": ci,
            "serial_cycles": ca + ci, "shared_cycles": rs.cycles,
            "sharing_gain": (ca + ci) / rs.cycles,
            "ideal_max": max(ca, ci),
            "utilization": rs.utilization,
        }))
    return rows


def design_ablation(bands: int = 8):
    """HTS design parameters: issue width, RS window, CDB width."""
    from repro.core.hts.programs import audio_compression
    bench = audio_compression(bands, time_domain=False)
    rows = []
    base = costs.hts_costs(True)
    for issue_w in (1, 2, 4, 8):
        c = dataclasses.replace(base, issue_width=issue_w)
        r = hts.run(bench, scheduler=c, n_fu=4, params=PARAMS)
        rows.append((f"ablation/issue_width{issue_w}", r.wall_us,
                     {"cycles": r.cycles}))
    for cdb_w in (1, 2, 4):
        c = dataclasses.replace(base, cdb_width=cdb_w)
        r = hts.run(bench, scheduler=c, n_fu=4, params=PARAMS)
        rows.append((f"ablation/cdb_width{cdb_w}", r.wall_us,
                     {"cycles": r.cycles}))
    for rs in (4, 8, 16, 64):
        p = dataclasses.replace(PARAMS, rs_entries=rs)
        r = hts.run(bench, n_fu=4, params=p)
        rows.append((f"ablation/rs_entries{rs}", r.wall_us,
                     {"cycles": r.cycles}))
    for tlb in (2, 4, 16):
        p = dataclasses.replace(PARAMS, tlb_entries=tlb,
                                tm_slots=max(tlb, 2))
        r = hts.run(bench, n_fu=4, params=p)
        rows.append((f"ablation/tlb_entries{tlb}", r.wall_us,
                     {"cycles": r.cycles}))
    return rows
