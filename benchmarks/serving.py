"""Serving benchmark: open-arrival-stream throughput, serve vs sequential.

``benchmarks/population.py`` measures the closed-batch best case — every
scenario available at t=0, pre-packed into one call.  A serving workload
is the opposite shape: requests arrive one at a time, and the pre-serve
workflow simulates each on arrival (a ``hts.run`` per request — the
*sequential* baseline here).  This driver measures what ``hts.serve``
recovers of the batched-path economics on two request streams:

* **qos stream** (the headline): one contended application graph (the
  PR-3 shape — a latency-sensitive chain vs greedy floods) arriving with
  seeded per-request QoS policies.  Same program shape, policy-variant
  requests — the recurring-request-type regime real serving systems live
  in, and exactly the population shape where the batched machine shines
  (``BENCH_population.json``'s 5.9x grid headline).
* **generated stream**: the raw ``workloads.arrival_stream`` —
  heterogeneous seeded scenarios in arrival order.  Event-count spread
  caps *static* batching here (a batch drains at its slowest lane), so
  this point reports the honest smaller number, consistent with the
  population benchmark's 1.5x on work-sorted heterogeneous chunks.
* **compacted points** (``generated_compacted``/``qos_compacted``): the
  same streams served with ``slice_steps="auto"`` at a narrower
  ``COMPACT_MAX_BATCH`` lane width — slice-and-refill continuous
  batching, where halted lanes are harvested between bounded step slices
  and refilled from the queue.  This is the fix for the static generated
  point: batched step cost grows with lane width, so the winning shape
  on a heterogeneous stream is narrow lanes kept permanently full by
  refill — not wide lanes idling behind their slowest neighbour.

The stream is replayed *saturating* (submitted back-to-back in arrival
order): arrival seeds fix the stream's identity and order, and the
number reported is peak sustained service throughput — the regime where
batching matters; at arrival rates below the sequential baseline's
throughput both systems keep up and the comparison is vacuous.

Device counts: one measurement subprocess per point, because the host
device pool (``XLA_FLAGS=--xla_force_host_platform_device_count``) is
fixed at jax import.  The 1-device point serves through the plain
population machine; N>1 points serve with ``ServeSpec(devices=N)`` — the
``shard_map`` launch path.  Every point asserts **zero post-warmup jit
compiles** (``Server.cache_info``) and differentially verifies a prefix
of its served results against direct ``hts.run`` calls.

    PYTHONPATH=src python -m benchmarks.serving            # writes JSON
    PYTHONPATH=src python -m benchmarks.serving --smoke    # CI-sized run

JSON lands in ``BENCH_serving.json`` (repo root by default); see
docs/BENCHMARKS.md for the schema.  Headline acceptance: serve sustains
**>= 2x scenarios/sec** over the sequential baseline on the 1-device qos
stream, with zero post-warmup compiles on every point.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import time

import numpy as np

DEFAULT_REPS = 5
DEFAULT_N = 48
DEFAULT_MAX_BATCH = 16
DEFAULT_DEVICE_COUNTS = (1, 2)
DEFAULT_OUT = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_serving.json"

STREAM = dict(seed=11, rate=1000.0, dist="poisson")
GEN_SCENARIO_KW = dict(n_tenants=2)
HI_PID = 1
QOS_WEIGHTS = (0, 1, 2, 8)
QOS_QUOTAS = (None, 1)
VERIFY_PREFIX = 4
#: slice budget for the compacted points — "auto" sizes each slice from
#: the bucket's measured completed-request step-count medians
SLICE_STEPS = "auto"
STEPWIDTH_JSON = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_stepwidth.json"


def compact_width(best_width: int) -> int:
    """Clamp the step-curve's best lane width to a usable *compaction*
    width.  Slice-and-refill only pays while refills actually happen: a
    compacted batch as wide as the static one swallows the whole queue
    in a single launch and degenerates to static batching (measured —
    occupancy collapses and the heterogeneous speedup with it).  Keeping
    the compacted width at most half the static batch guarantees the
    queue stays non-empty long enough for harvested lanes to be
    refilled, which is the whole mechanism."""
    return max(1, min(int(best_width), DEFAULT_MAX_BATCH // 2))


def _compact_max_batch(default: int = 4) -> int:
    """Lane width for the compacted points, derived from the committed
    step-width curve (``BENCH_stepwidth.json``, written by
    ``benchmarks/stepwidth.py``): the width maximising lanes advanced
    per microsecond of per-trip step cost on the default ``xla`` impl,
    clamped by :func:`compact_width`.  Falls back to ``default`` when no
    curve has been committed."""
    try:
        data = json.loads(STEPWIDTH_JSON.read_text())
        return compact_width(data["derived"]["best_width_xla"])
    except (OSError, KeyError, ValueError, TypeError):
        return default


#: lane width for the compacted points.  Batched step cost grows with
#: lane width on CPU, so width only pays where lanes stay oversubscribed;
#: compaction's refill keeps *narrow* lanes permanently full, which is
#: the winning trade on a heterogeneous stream (wide static batches idle
#: behind their slowest lane instead).  The width itself is measured,
#: not hand-picked: it comes off the committed step-width curve.
COMPACT_MAX_BATCH = _compact_max_batch()
#: every stream a point measures (the ``*_compacted`` pair serve with
#: ``slice_steps=SLICE_STEPS`` at ``COMPACT_MAX_BATCH`` lanes)
STREAMS = ("qos", "generated", "generated_compacted", "qos_compacted")


# ---------------------------------------------------------------------------
# request streams
# ---------------------------------------------------------------------------
def _hi_chain(chain: int = 8, delay: int = 10):
    from repro.core.hts.builder import Program
    p = Program("hi", region_base=0x100)
    frame = p.input(0x10, 4, "frame")
    for _ in range(delay):
        p.nop()
    with p.process(HI_PID):
        prev = frame
        for i in range(chain):
            prev = p.task("dct", in_=prev, out=4, in_size=4, tid=i)
    return p


def _greedy(pid: int, tasks: int = 10):
    from repro.core.hts.builder import Program
    p = Program(f"greedy{pid}", region_base=0x180 + 0x80 * (pid - 2))
    frame = p.input(0x10, 4, "frame")
    with p.process(pid):
        for i in range(tasks):
            p.task("dct", in_=frame, out=4, tid=i & 0xF)
    return p


def qos_request_types():
    """The request-type pool: one contended app graph, each type a
    different attached QoS policy (weights × quotas)."""
    from repro.core.hts.builder import Program
    types = []
    for w in QOS_WEIGHTS:
        for q in QOS_QUOTAS:
            kw = {}
            if w:
                kw["priorities"] = {HI_PID: w}
            if q:
                kw["quotas"] = {2: q, 3: q}
            types.append(Program.merge(
                [_hi_chain(), _greedy(2), _greedy(3)], f"req_w{w}_q{q}",
                require_distinct_pids=True, **kw))
    return types


def qos_stream(n: int):
    """``n`` requests drawing seeded from the qos type pool (recurring
    request types — the serving sweet spot)."""
    rng = np.random.default_rng(STREAM["seed"])
    types = qos_request_types()
    return [types[int(rng.integers(len(types)))] for _ in range(n)]


def generated_stream(n: int):
    """``n`` heterogeneous seeded scenarios in Poisson arrival order."""
    from repro.core.hts import workloads
    arrivals = workloads.arrival_stream(
        STREAM["seed"], STREAM["rate"], n, dist=STREAM["dist"],
        **GEN_SCENARIO_KW)
    return [a.scenario.merged for a in arrivals]


# ---------------------------------------------------------------------------
# one measurement point (runs in a subprocess with a forced device pool)
# ---------------------------------------------------------------------------
def measure_stream(progs, *, devices: int, max_batch: int,
                   reps: int, slice_steps=None) -> dict:
    """Serve-vs-sequential medians for one request list on this process's
    device pool.  ``devices=1`` uses the plain launch path; ``devices>1``
    the sharded one.  ``slice_steps`` switches the server to
    slice-and-refill continuous batching (compaction) — the knob that
    rescues heterogeneous streams from slowest-lane drain."""
    from repro.core import hts

    # scenario-sized capacities for the batched path (as in
    # benchmarks/population.py), right-sized to these streams: the
    # heaviest request type retires ~28 tasks, so 64/32 keeps >2×
    # headroom while shrinking the per-step state every serve mode pays
    # for (a request that did overflow would fail loudly, not silently).
    # The sequential baseline keeps facade defaults — that is the
    # workflow being replaced
    params = hts.HtsParams(max_tasks=64, cdb_entries=32)
    # compaction turns the admission queue into the refill reservoir, so
    # sliced points size it to the in-flight stream (a starved reservoir
    # re-introduces the drain tails compaction exists to remove); static
    # points keep the bounded 4×width backpressure queue
    max_queue = len(progs) if slice_steps is not None else 4 * max_batch
    spec = hts.ServeSpec(max_batch=max_batch, max_queue=max_queue,
                         deadline=10.0, params=params,
                         slice_steps=slice_steps,
                         devices=devices if devices > 1 else None)

    def serve_once():
        with hts.serve(spec) as srv:
            futs = [srv.submit(p) for p in progs]
            srv.drain()
            return srv, [f.result(timeout=0) for f in futs]

    srv, served = serve_once()                    # warm the bucket cache
    warm = srv.cache_info()

    # verify a prefix of served results against the pre-serve workflow
    for prog, res in list(zip(progs, served))[:VERIFY_PREFIX]:
        ref = hts.run(prog, scheduler="hts_spec", n_fu=2)
        assert res.cycles == ref.cycles, (res.program, res.cycles,
                                          ref.cycles)

    serve_walls = []
    for _ in range(reps):
        t0 = time.perf_counter()
        srv, _ = serve_once()
        serve_walls.append((time.perf_counter() - t0) * 1e6)
        after = srv.cache_info()
        assert after.jit_compiles == warm.jit_compiles, \
            f"recompiled: {warm} -> {after}"

    def sequential():
        return [hts.run(p, scheduler="hts_spec", n_fu=2) for p in progs]

    sequential()                                  # warm the per-run path
    seq_walls = []
    for _ in range(reps):
        t0 = time.perf_counter()
        sequential()
        seq_walls.append((time.perf_counter() - t0) * 1e6)

    n = len(progs)
    serve_us = float(np.median(serve_walls))
    seq_us = float(np.median(seq_walls))
    rep = srv.report()
    return {
        "n_requests": n,
        "max_batch": max_batch,
        "slice_steps": slice_steps,
        "serve": {"total_us": serve_us,
                  "scenarios_per_sec": hts.scenarios_per_second(n, serve_us)},
        "sequential": {"total_us": seq_us,
                       "scenarios_per_sec":
                           hts.scenarios_per_second(n, seq_us)},
        "speedup_vs_sequential": seq_us / serve_us,
        "cache": {"entries": warm.entries, "misses": warm.misses,
                  "jit_compiles": warm.jit_compiles,
                  "post_warmup_jit_compiles": 0},   # asserted above
        "batches": rep.batches,
        "mean_occupancy": float(np.mean(
            [b.occupancy for b in rep.per_bucket.values()])),
        "verified_prefix": VERIFY_PREFIX,
    }


def measure_point(devices: int, n: int, max_batch: int, reps: int) -> dict:
    """One device count, both streams, both batching modes.  The
    ``*_compacted`` entries serve with ``slice_steps=SLICE_STEPS``
    (slice-and-refill); the heterogeneous generated stream is where
    compaction earns its keep — static batches there drain at the
    slowest lane."""
    return {
        "devices": devices,
        "reps": reps,
        "max_batch": max_batch,
        "qos": measure_stream(qos_stream(n), devices=devices,
                              max_batch=max_batch, reps=reps),
        "generated": measure_stream(generated_stream(n), devices=devices,
                                    max_batch=max_batch, reps=reps),
        "generated_compacted": measure_stream(
            generated_stream(n), devices=devices,
            max_batch=COMPACT_MAX_BATCH, reps=reps,
            slice_steps=SLICE_STEPS),
        "qos_compacted": measure_stream(
            qos_stream(n), devices=devices, max_batch=COMPACT_MAX_BATCH,
            reps=reps, slice_steps=SLICE_STEPS),
    }


def _run_point(devices: int, n: int, max_batch: int, reps: int) -> dict:
    """Spawn one measurement subprocess with a ``devices``-wide host pool
    and parse its JSON point (last stdout line)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    repo = pathlib.Path(__file__).resolve().parent.parent
    env["PYTHONPATH"] = str(repo / "src")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.serving", "--point",
         "--devices", str(devices), "--n", str(n),
         "--max-batch", str(max_batch), "--reps", str(reps)],
        capture_output=True, text=True, timeout=1200, env=env, cwd=repo)
    if out.returncode != 0:
        raise RuntimeError(f"point devices={devices} failed:\n"
                           f"{out.stderr[-3000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def trajectory(*, device_counts=DEFAULT_DEVICE_COUNTS, n: int = DEFAULT_N,
               max_batch: int = DEFAULT_MAX_BATCH,
               reps: int = DEFAULT_REPS) -> dict:
    points = [_run_point(d, n, max_batch, reps) for d in device_counts]
    one = points[0]["qos"]
    return {
        "bench": "serving",
        "stream": {**STREAM, "n": n,
                   "qos_types": len(QOS_WEIGHTS) * len(QOS_QUOTAS),
                   "generated_kw": GEN_SCENARIO_KW},
        "serve_spec": {"max_batch": max_batch,
                       "max_queue": 4 * max_batch,
                       "slice_steps_compacted": SLICE_STEPS},
        "points": points,
        "headline": {
            "n_requests": n,
            "device_counts": list(device_counts),
            "scenarios_per_sec_serve_1dev":
                one["serve"]["scenarios_per_sec"],
            "scenarios_per_sec_sequential":
                one["sequential"]["scenarios_per_sec"],
            "speedup_vs_sequential": one["speedup_vs_sequential"],
            "target_speedup": 2.0,
            "met": one["speedup_vs_sequential"] >= 2.0,
            "generated_stream_speedup":
                points[0]["generated"]["speedup_vs_sequential"],
            "generated_stream_speedup_compacted":
                points[0]["generated_compacted"]["speedup_vs_sequential"],
            "compacted_target_speedup": 1.0,
            "compacted_met":
                points[0]["generated_compacted"]["speedup_vs_sequential"]
                >= 1.0,
            "post_warmup_jit_compiles_all_points": 0,
            "verified_prefix_per_point": VERIFY_PREFIX,
        },
        "note": "medians of {} reps on an otherwise idle machine; wall "
                "times on this class of box are +/-50% noisy, so assert "
                "against conservative bounds, not the medians".format(reps),
    }


def section():
    """``benchmarks.run`` integration: one in-process 1-device qos point
    plus a compacted heterogeneous point (the slice-and-refill regime)."""
    point = measure_stream(qos_stream(16), devices=1, max_batch=8, reps=1)
    compact = measure_stream(generated_stream(16), devices=1,
                             max_batch=COMPACT_MAX_BATCH, reps=1,
                             slice_steps=SLICE_STEPS)
    return [
        ("serving/qos_stream16/batch8", point["serve"]["total_us"], {
            "speedup_vs_sequential": point["speedup_vs_sequential"],
            "scenarios_per_sec": point["serve"]["scenarios_per_sec"],
            "mean_occupancy": point["mean_occupancy"],
        }),
        (f"serving/generated16/batch{COMPACT_MAX_BATCH}/compacted",
         compact["serve"]["total_us"], {
             "speedup_vs_sequential": compact["speedup_vs_sequential"],
             "scenarios_per_sec": compact["serve"]["scenarios_per_sec"],
             "mean_occupancy": compact["mean_occupancy"],
         }),
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--reps", type=int, default=DEFAULT_REPS)
    ap.add_argument("--n", type=int, default=DEFAULT_N)
    ap.add_argument("--max-batch", type=int, default=DEFAULT_MAX_BATCH)
    ap.add_argument("--devices", type=int, default=1,
                    help="(with --point) this point's device count")
    ap.add_argument("--point", action="store_true",
                    help="measure one point in-process and print its JSON "
                         "(run by the parent with XLA_FLAGS set)")
    ap.add_argument("--device-counts", type=int, nargs="+",
                    default=list(DEFAULT_DEVICE_COUNTS))
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (16 requests, batch 8, 1 rep; no "
                         "JSON unless --out is given)")
    ap.add_argument("--out", default=None,
                    help=f"output path (default {DEFAULT_OUT}; "
                         "smoke runs write no JSON unless set)")
    args = ap.parse_args()

    if args.point:
        print(json.dumps(
            measure_point(args.devices, args.n, args.max_batch, args.reps),
            default=float))
        return

    if args.smoke:
        data = trajectory(device_counts=tuple(args.device_counts),
                          n=16, max_batch=8, reps=1)
        # smoke gates correctness, not wall-clock: differential prefixes
        # verified, zero post-warmup compiles, throughput measured
        assert data["headline"]["speedup_vs_sequential"] > 0
        for p in data["points"]:
            for stream in STREAMS:
                assert p[stream]["cache"]["post_warmup_jit_compiles"] == 0
                assert p[stream]["verified_prefix"] == VERIFY_PREFIX
    else:
        data = trajectory(device_counts=tuple(args.device_counts),
                          n=args.n, max_batch=args.max_batch,
                          reps=args.reps)

    out = None
    if args.out:
        out = pathlib.Path(args.out)
    elif not args.smoke:
        out = DEFAULT_OUT
    if out is not None:
        out.write_text(json.dumps(data, indent=2, default=float) + "\n")
        print(f"wrote {out}")

    for p in data["points"]:
        for stream in STREAMS:
            s = p[stream]
            print(f"  devices={p['devices']} {stream} "
                  f"({s['n_requests']} requests, batch {s['max_batch']}, "
                  f"{s['batches']} launches, "
                  f"occupancy {s['mean_occupancy']:.2f}):")
            print(f"    sequential {s['sequential']['total_us']:>12.0f} us "
                  f" ({s['sequential']['scenarios_per_sec']:>8.1f} scen/s)")
            print(f"    serve      {s['serve']['total_us']:>12.0f} us "
                  f" ({s['serve']['scenarios_per_sec']:>8.1f} scen/s)")
            print(f"    speedup    {s['speedup_vs_sequential']:.2f}x "
                  f"(0 post-warmup jit compiles)")
    h = data["headline"]
    print(f"  headline: {h['speedup_vs_sequential']:.2f}x serve vs "
          f"sequential on the 1-device qos stream (target >= "
          f"{h['target_speedup']}x: {'MET' if h['met'] else 'NOT MET'}); "
          f"generated stream {h['generated_stream_speedup']:.2f}x static, "
          f"{h['generated_stream_speedup_compacted']:.2f}x compacted "
          f"(target >= {h['compacted_target_speedup']}x: "
          f"{'MET' if h['compacted_met'] else 'NOT MET'})")


if __name__ == "__main__":
    main()
