"""Step-width benchmark: per-trip step cost vs lane width, per step impl.

The population machine runs one ``lax.while_loop`` whose body is the
vmapped per-cycle step, so *every* batched economics question in this
repo — static batching, slice-and-refill compaction, sharding — reduces
to one curve: **wall-clock per while-loop trip as a function of lane
width**.  The flatter that curve, the wider the profitable batch.  This
driver measures it directly for each step-body lowering
(``machine.STEP_IMPLS``):

* ``xla_base`` — the pre-restructure step body, kept verbatim as the
  measured baseline for this curve.
* ``xla`` — the restructured default: hoisted tables, collapsed
  masked-select chains, cumsum-rank CDB enqueue instead of a full
  argsort, narrow mask dtypes in the RS arbiter, and event-proportional
  *scatter* trace writes in place of the base machine's U-wide one-hot
  selects (the dominant per-lane term of the trip cost — K×U compares
  per trace write per trip, paid whether or not any event fired).
* ``pallas`` — the fused per-lane kernel step (``pallas_step.py``),
  lane-per-program grid.  On CPU this runs in **interpret mode**, so its
  numbers here are honesty checks and shape validation, not a speed
  claim; on a TPU backend the same code path compiles to Mosaic.

Method: one CHEAP_MIX scenario is packed once at the facade-default
capacities (``HtsParams()``) — the state shape every ``hts.run_many``
caller pays for unless they right-size it, and the regime where the
U-proportional trace-write term dominates the lane slope — and
replicated lane-for-lane to each width with ``batch.replicate``, so the
sweep varies *only* the width.
Each (width, impl) point re-enters its run's own compile bucket through
``PopulationResult.trip_cost_us``: a fresh carry advanced by a fixed
step budget, median of ``reps`` timed slices — interleaved round-robin
across impls, so the shared box's load drift cannot bias one impl's
median — divided by the trips actually executed.

The derived block feeds a policy knob: ``best_width_xla`` is the width
maximising lanes-per-microsecond on the default impl, and
``benchmarks/serving.py`` derives its ``COMPACT_MAX_BATCH`` (the
slice-and-refill lane width) from the committed JSON.  The driver
re-measures the serving ``qos_compacted`` point at that width to close
the loop.

    PYTHONPATH=src python -m benchmarks.stepwidth            # writes JSON
    PYTHONPATH=src python -m benchmarks.stepwidth --smoke    # CI-sized

JSON lands in ``BENCH_stepwidth.json`` (repo root); see
docs/BENCHMARKS.md for the schema.  Headline acceptance: the
restructured ``xla`` width-8/width-1 per-trip ratio is strictly below
``xla_base``'s — the restructure flattened the curve, not just shifted
it.
"""
from __future__ import annotations

import argparse
import json
import pathlib

import numpy as np

DEFAULT_WIDTHS = (1, 2, 4, 8, 16)
DEFAULT_BUDGET = 256
DEFAULT_REPS = 7
DEFAULT_OUT = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_stepwidth.json"

SEED = 11
SCENARIO_KW = dict(n_tenants=2, max_tasks=4)
#: facade-default machine capacities (``HtsParams()``): the trace tables
#: are (max_tasks+1,)-wide, so the default 1024-task capacity is exactly
#: where the base machine's one-hot trace writes dominate the per-lane
#: slope this benchmark exists to measure
PARAMS_KW: dict = {}
IMPLS = ("xla_base", "xla", "pallas")
#: the two widths whose per-trip ratio is the headline (width growth
#: factor the restructure must beat)
RATIO_WIDTHS = (1, 8)


def _population(width: int):
    """One CHEAP scenario, packed at default capacities, tiled to
    ``width`` identical lanes — width is the only swept variable."""
    from repro.core import hts
    from repro.core.hts import batch, workloads
    sc = workloads.generate_scenario(SEED, kernels=workloads.CHEAP_MIX,
                                     **SCENARIO_KW)
    pop = batch.pack_population([sc.merged],
                                params=hts.HtsParams(**PARAMS_KW))
    return batch.replicate(pop, width)


def measure_point(width: int, *, budget: int, reps: int,
                  impls=IMPLS) -> dict:
    """Per-trip medians for every impl at one lane width.  Each impl's
    run is its own compile bucket (``step_impl`` is a spec field);
    ``trip_cost_us`` times the resumable machine of that same bucket.
    Reps are **interleaved round-robin across impls** so the shared
    box's slow load drift lands on every impl alike — back-to-back
    per-impl blocks would let a noisy minute bias one impl's median."""
    from repro.core import hts
    pop = _population(width)
    runs = {}
    for impl in impls:
        r = hts.run_many(pop, scheduler="hts_spec", step_impl=impl)
        assert bool(np.asarray(r.halted).all()), (impl, width)
        runs[impl] = r
    walls = {impl: [] for impl in impls}
    for _ in range(reps):
        for impl in impls:
            walls[impl].append(runs[impl].trip_cost_us(budget=budget,
                                                       reps=1))
    return {"width": width,
            "per_trip_us": {i: float(np.median(walls[i])) for i in impls}}


def _derived(points, impls=IMPLS) -> dict:
    by_w = {p["width"]: p["per_trip_us"] for p in points}
    lo, hi = RATIO_WIDTHS
    ratios = {impl: by_w[hi][impl] / by_w[lo][impl]
              for impl in impls if lo in by_w and hi in by_w}
    # throughput proxy: lanes advanced per microsecond of trip cost —
    # the width the compacted serving path should run at
    lanes_per_us = {w: w / c["xla"] for w, c in by_w.items()}
    best = min(sorted(lanes_per_us),
               key=lambda w: (-lanes_per_us[w], w))
    return {
        "ratio_widths": list(RATIO_WIDTHS),
        "per_trip_ratio": ratios,
        "lanes_per_us_xla": lanes_per_us,
        "best_width_xla": best,
    }


def sweep(*, widths=DEFAULT_WIDTHS, budget: int = DEFAULT_BUDGET,
          reps: int = DEFAULT_REPS, impls=IMPLS,
          serving_point: bool = True) -> dict:
    from benchmarks import serving
    from repro.core.hts import pallas_step

    points = [measure_point(w, budget=budget, reps=reps, impls=impls)
              for w in widths]
    derived = _derived(points, impls=impls)

    data = {
        "bench": "stepwidth",
        "spec": {
            "seed": SEED,
            "scenario_kw": SCENARIO_KW,
            "params": PARAMS_KW,
            "budget": budget,
            "reps": reps,
            "impls": list(impls),
            "pallas_interpret": pallas_step.INTERPRET,
        },
        "points": points,
        "derived": derived,
        "note": "per-trip medians of {} reps at step budget {}; wall "
                "times on this class of box are +/-50% noisy, so assert "
                "against conservative bounds, not the medians; pallas "
                "numbers are interpret-mode on CPU (correctness path, "
                "not a speed claim)".format(reps, budget),
    }

    r = derived["per_trip_ratio"]
    if "xla" in r and "xla_base" in r:
        data["headline"] = {
            "baseline_w{}_over_w{}".format(*RATIO_WIDTHS[::-1]):
                r["xla_base"],
            "restructured_w{}_over_w{}".format(*RATIO_WIDTHS[::-1]):
                r["xla"],
            "flattened": r["xla"] < r["xla_base"],
            "best_width_xla": derived["best_width_xla"],
        }

    if serving_point:
        # close the loop: re-measure the serving qos_compacted point at
        # the width this curve says is profitable (the same width
        # benchmarks/serving.py derives its COMPACT_MAX_BATCH from —
        # clamped below the static batch so slice-and-refill can refill)
        w = serving.compact_width(derived["best_width_xla"])
        pt = serving.measure_stream(
            serving.qos_stream(16), devices=1, max_batch=w,
            reps=max(1, reps // 2), slice_steps=serving.SLICE_STEPS)
        data["serving"] = {
            "qos_compacted_width": w,
            "n_requests": pt["n_requests"],
            "speedup_vs_sequential": pt["speedup_vs_sequential"],
            "mean_occupancy": pt["mean_occupancy"],
        }
    return data


def section():
    """``benchmarks.run`` integration: a two-width mini-sweep per impl."""
    rows = []
    for w in (1, 8):
        pt = measure_point(w, budget=32, reps=1)
        for impl in IMPLS:
            rows.append((f"stepwidth/w{w}/{impl}",
                         pt["per_trip_us"][impl], {"width": w}))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--widths", type=int, nargs="+",
                    default=list(DEFAULT_WIDTHS))
    ap.add_argument("--budget", type=int, default=DEFAULT_BUDGET)
    ap.add_argument("--reps", type=int, default=DEFAULT_REPS)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (widths 1+4, budget 32, 1 rep, no "
                         "serving re-measure; no JSON unless --out)")
    ap.add_argument("--out", default=None,
                    help=f"output path (default {DEFAULT_OUT}; smoke runs "
                         "write no JSON unless set)")
    args = ap.parse_args()

    if args.smoke:
        data = sweep(widths=(1, 4), budget=32, reps=1,
                     serving_point=False)
        # smoke gates the machinery, not wall-clock: every impl produced
        # a positive per-trip figure at every width and the derived
        # block computed
        for p in data["points"]:
            for impl in IMPLS:
                assert p["per_trip_us"][impl] > 0.0, (p["width"], impl)
        assert data["derived"]["best_width_xla"] in (1, 4)
    else:
        data = sweep(widths=tuple(args.widths), budget=args.budget,
                     reps=args.reps)

    out = None
    if args.out:
        out = pathlib.Path(args.out)
    elif not args.smoke:
        out = DEFAULT_OUT
    if out is not None:
        out.write_text(json.dumps(data, indent=2, default=float) + "\n")
        print(f"wrote {out}")

    for p in data["points"]:
        cells = "  ".join(f"{impl} {p['per_trip_us'][impl]:>9.1f}"
                          for impl in data["spec"]["impls"])
        print(f"  width {p['width']:>2}: {cells}  (us/trip)")
    d = data["derived"]
    print(f"  w{RATIO_WIDTHS[1]}/w{RATIO_WIDTHS[0]} per-trip ratio: " +
          ", ".join(f"{i} {d['per_trip_ratio'][i]:.2f}x"
                    for i in d["per_trip_ratio"]))
    print(f"  best width (xla lanes/us): {d['best_width_xla']}")
    if "headline" in data:
        h = data["headline"]
        print(f"  headline: restructured ratio "
              f"{h['restructured_w8_over_w1']:.2f}x vs baseline "
              f"{h['baseline_w8_over_w1']:.2f}x — flattened: "
              f"{'YES' if h['flattened'] else 'NO'}")
    if "serving" in data:
        s = data["serving"]
        print(f"  serving qos_compacted @ width {s['qos_compacted_width']}: "
              f"{s['speedup_vs_sequential']:.2f}x vs sequential")


if __name__ == "__main__":
    main()
