"""Population-scale batched simulation benchmark: scenarios/sec.

The paper's system-level claim is many applications sharing one
accelerator pool; scenario studies — the HEFT-style dynamic-workload
sweeps and priority-mix studies the ROADMAP cites — need *populations* of
multi-tenant scenarios, and before this PR every one of them was a Python
loop of ``hts.run``.  This driver measures what the scenario vmap axis
buys on the two population shapes that matter:

* **QoS policy grid** (the headline): the PR-3 starvation shape — a
  latency-sensitive chain arriving after N greedy same-class floods —
  instantiated as a (tenant-mix × SchedPolicy) grid, 64 scenario
  instances.  Policies are runtime data and each mix is one program, so
  the population is step-count-homogeneous: the shape where one batched
  machine shines.  This is exactly the study ``benchmarks/priority.py``
  runs as a Python loop today.
* **generated scenario population**: 64 seeded ``workloads`` scenarios
  (random tenant counts, kernels, loops, branches).  Heterogeneous step
  counts cap the win (a batch runs as long as its slowest lane — see
  ``batch.plan_chunks``), so this section reports the honest smaller
  speedup alongside the headline.

Both paths are measured as medians over repetitions, warmed up (no
compile time in the numbers), and the loop baseline is the real
pre-population workflow: ``hts.run(scenario, n_fu=..., policy=...)`` with
facade defaults.  The batched path is ``batch.pack_population`` +
``hts.run_many`` over work-planned chunks — shape bucketing, capacity
right-sizing (``max_tasks``/``cdb_entries``) and chunking are part of the
feature being measured.

The run also *differentially verifies* the batched path: ``hts.compare``
on a population slice checks the vmapped machine (event-skip on and off)
against a golden-oracle loop, scenario by scenario.

    PYTHONPATH=src python -m benchmarks.population            # writes JSON
    PYTHONPATH=src python -m benchmarks.population --smoke    # CI-sized run

JSON lands in ``BENCH_population.json`` (repo root by default); see
docs/BENCHMARKS.md for the schema.  Headline acceptance: the batched path
sustains **>= 5x scenarios/sec** over the loop on a >= 64-scenario
population, with golden equivalence proven on every scenario.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from repro.core import hts
from repro.core.hts import batch, workloads
from repro.core.hts.builder import Program
from repro.core.hts.policy import SchedPolicy

DEFAULT_REPS = 5
DEFAULT_OUT = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_population.json"

#: scenario-sized capacities: every population scenario stays well under
#: 192 tasks, and the machine's trace/CDB state scales with these, so the
#: defaults (1024) would pay for capacity no scenario uses.  The batched
#: path right-sizes them; the loop baseline keeps facade defaults — that
#: is the workflow being replaced.
PARAMS = hts.HtsParams(max_tasks=192, cdb_entries=64)

HI_PID = 1


# ---------------------------------------------------------------------------
# the QoS policy grid (headline population)
# ---------------------------------------------------------------------------
def _hi_chain(chain: int = 8, delay: int = 10) -> Program:
    """Latency-sensitive tenant: RAW chain arriving after ``delay`` nops."""
    p = Program("hi", region_base=0x100)
    frame = p.input(0x10, 4, "frame")
    for _ in range(delay):
        p.nop()
    with p.process(HI_PID):
        prev = frame
        for i in range(chain):
            prev = p.task("dct", in_=prev, out=4, in_size=4, tid=i)
    return p


def _greedy(pid: int, tasks: int = 10) -> Program:
    """Best-effort flood: independent same-class tasks (compact bases so
    up to 6 tenants stay inside the default 1024-word memory)."""
    p = Program(f"greedy{pid}", region_base=0x180 + 0x80 * (pid - 2))
    frame = p.input(0x10, 4, "frame")
    with p.process(pid):
        for i in range(tasks):
            p.task("dct", in_=frame, out=4, tid=i & 0xF)
    return p


def _contended(n_greedy: int) -> Program:
    return Program.merge(
        [_hi_chain()] + [_greedy(2 + k) for k in range(n_greedy)],
        f"contended_{n_greedy}g", require_distinct_pids=True)


def build_grid(mixes=(2, 3, 4, 5), weights=(0, 1, 2, 8),
               quotas=(None, 1), rs_caps=(None, 4)):
    """(program, policy) instances of the tenant-mix × policy grid."""
    instances = []
    for g in mixes:
        built = _contended(g).build()
        greedy_pids = tuple(range(2, 2 + g))
        for w in weights:
            for q in quotas:
                for rc in rs_caps:
                    pol = SchedPolicy.of(
                        weights=({HI_PID: w} if w else None),
                        quotas=({p: q for p in greedy_pids} if q else None),
                        rs_caps=({p: rc for p in greedy_pids}
                                 if rc else None))
                    instances.append((built, pol))
    return instances


def _median_wall(fn, reps: int) -> float:
    walls = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        walls.append(time.perf_counter() - t0)
    return float(np.median(walls)) * 1e6


def measure_grid(instances, *, n_fu: int = 2, chunk: int = 32,
                 scheduler: str = "hts_spec",
                 reps: int = DEFAULT_REPS) -> dict:
    """Loop-vs-batched scenarios/sec on the policy grid (median of reps)."""
    n = len(instances)
    packs = [hts.pack_population([b for b, _ in instances[k:k + chunk]],
                                 n_fu=n_fu, params=PARAMS,
                                 policy=[p for _, p in instances[k:k + chunk]])
             for k in range(0, n, chunk)]

    def loop():
        return [hts.run(b, scheduler=scheduler, n_fu=n_fu, policy=pol)
                for b, pol in instances]

    def batched():
        return [hts.run_many(pk, scheduler=scheduler) for pk in packs]

    loop_res, batch_res = loop(), batched()       # warm both compiled paths
    batch_cycles = [int(c) for r in batch_res for c in r.cycles]
    assert batch_cycles == [r.cycles for r in loop_res], \
        "batched and looped cycle counts diverged"

    loop_us = _median_wall(loop, reps)
    batched_us = _median_wall(batched, reps)
    return {
        "population": "policy_grid",
        "n_scenarios": n,
        "n_chunks": len(packs),
        "chunk": chunk,
        "n_fu": n_fu,
        "scheduler": scheduler,
        "reps": reps,
        "loop": {"total_us": loop_us,
                 "scenarios_per_sec": hts.scenarios_per_second(n, loop_us)},
        "batched": {"total_us": batched_us,
                    "scenarios_per_sec":
                        hts.scenarios_per_second(n, batched_us)},
        "speedup": loop_us / batched_us,
        "hi_slowdown_spread": _grid_qos_spread(instances, batch_res),
    }


def _grid_qos_spread(instances, batch_res) -> dict:
    """The study the grid exists for: pid-1 makespan across the policy
    axis, straight off the batched results (per-scenario slicing)."""
    makespans = [r[i].app_makespan(HI_PID)
                 for r in batch_res for i in range(len(r))]
    return {"min": int(min(makespans)), "max": int(max(makespans))}


# ---------------------------------------------------------------------------
# generated scenario population (heterogeneous)
# ---------------------------------------------------------------------------
def build_population(n: int, *, seed0: int = 0,
                     kernels=workloads.CHEAP_MIX,
                     max_tasks: int = 4) -> workloads.Population:
    """One max-bucket population of ``n`` seeded multi-tenant scenarios."""
    (pop,) = workloads.generate_population(
        n, seed0=seed0, bucket=False, kernels=kernels, max_tasks=max_tasks)
    return pop


def measure_generated(pop: workloads.Population, *, n_fu: int = 2,
                      scheduler: str = "hts_spec",
                      reps: int = DEFAULT_REPS) -> dict:
    """Loop-vs-batched on the heterogeneous generated population."""
    programs = list(pop.programs)
    plan = batch.plan_chunks(programs)
    packs = [hts.pack_population([programs[i] for i in ch], n_fu=n_fu,
                                 max_prog=pop.max_prog, params=PARAMS)
             for ch in plan]

    def loop():
        return [hts.run(p, scheduler=scheduler, n_fu=n_fu)
                for p in programs]

    def batched():
        return [hts.run_many(pk, scheduler=scheduler) for pk in packs]

    loop_res, batch_res = loop(), batched()
    got = {}
    for r in batch_res:
        for nm, c in zip(r.names, r.cycles):
            got[nm] = int(c)
    assert [got[p.name] for p in programs] == [r.cycles for r in loop_res], \
        "batched and looped cycle counts diverged"

    loop_us = _median_wall(loop, reps)
    batched_us = _median_wall(batched, reps)
    n = len(programs)
    return {
        "population": "generated_scenarios",
        "n_scenarios": n,
        "seeds": [pop.seeds[0], pop.seeds[-1]],
        "max_prog": pop.max_prog,
        "chunk_widths": [len(c) for c in plan],
        "n_fu": n_fu,
        "scheduler": scheduler,
        "reps": reps,
        "loop": {"total_us": loop_us,
                 "scenarios_per_sec": hts.scenarios_per_second(n, loop_us)},
        "batched": {"total_us": batched_us,
                    "scenarios_per_sec":
                        hts.scenarios_per_second(n, batched_us)},
        "speedup": loop_us / batched_us,
    }


# ---------------------------------------------------------------------------
# differential verification
# ---------------------------------------------------------------------------
def verify(instances, generated: workloads.Population, *,
           n_fu: int = 2, grid_schedulers=("hts_spec",),
           gen_schedulers=("naive", "hts_spec")) -> dict:
    """Population compare: golden loop ≡ one vmapped batch per mode."""
    grid = hts.compare([b for b, _ in instances],
                       policy=[p for _, p in instances],
                       schedulers=grid_schedulers, n_fu=n_fu, params=PARAMS)
    gen = hts.compare(list(generated.programs), schedulers=gen_schedulers,
                      n_fu=n_fu, max_prog=generated.max_prog, params=PARAMS)
    return {
        "verified": True,                 # compare raises on any mismatch
        "grid": {"n_scenarios": len(grid),
                 "schedulers": list(grid.schedulers),
                 "n_modes": grid.n_modes},
        "generated": {"n_scenarios": len(gen),
                      "schedulers": list(gen.schedulers),
                      "n_modes": gen.n_modes},
    }


def trajectory(*, grid_instances=None, generated_n: int = 64,
               reps: int = DEFAULT_REPS, verify_grid_n: int = 64,
               verify_gen_n: int = 16) -> dict:
    instances = (build_grid() if grid_instances is None else grid_instances)
    pop = build_population(generated_n)
    grid_point = measure_grid(instances, reps=reps)
    gen_point = measure_generated(pop, reps=reps)
    golden_equiv = verify(instances[:verify_grid_n],
                          build_population(verify_gen_n))
    return {
        "bench": "population",
        "grid": grid_point,
        "generated": gen_point,
        "golden_equiv": golden_equiv,
        "headline": {
            "population": "policy_grid",
            "n_scenarios": grid_point["n_scenarios"],
            "scenarios_per_sec_batched":
                grid_point["batched"]["scenarios_per_sec"],
            "scenarios_per_sec_loop":
                grid_point["loop"]["scenarios_per_sec"],
            "speedup": grid_point["speedup"],
            "target_speedup": 5.0,
            "met": grid_point["speedup"] >= 5.0,
            "generated_population_speedup": gen_point["speedup"],
            "golden_equiv_all_scenarios": golden_equiv["verified"],
        },
    }


def section():
    """``benchmarks.run`` integration: (name, us, derived) rows."""
    instances = build_grid(mixes=(2, 4), weights=(0, 8),
                           quotas=(None, 1), rs_caps=(None, 4))
    point = measure_grid(instances, chunk=16, reps=1)
    return [(f"population/grid{point['n_scenarios']}/fu{point['n_fu']}",
             point["batched"]["total_us"], {
                 "speedup_vs_loop": point["speedup"],
                 "scenarios_per_sec":
                     point["batched"]["scenarios_per_sec"],
             })]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--reps", type=int, default=DEFAULT_REPS)
    ap.add_argument("--generated-n", type=int, default=64)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (16-instance grid, 8 generated, "
                         "1 rep; no JSON unless --out is given)")
    ap.add_argument("--out", default=None,
                    help=f"output path (default {DEFAULT_OUT}; "
                         "smoke runs write no JSON unless set)")
    args = ap.parse_args()

    if args.smoke:
        instances = build_grid(mixes=(2, 4), weights=(0, 8),
                               quotas=(None, 1), rs_caps=(None, 4))
        data = trajectory(grid_instances=instances, generated_n=8,
                          reps=1, verify_grid_n=4, verify_gen_n=4)
    else:
        data = trajectory(generated_n=args.generated_n, reps=args.reps)

    out = None
    if args.out:
        out = pathlib.Path(args.out)
    elif not args.smoke:
        out = DEFAULT_OUT
    if out is not None:
        out.write_text(json.dumps(data, indent=2, default=float) + "\n")
        print(f"wrote {out}")

    for point in (data["grid"], data["generated"]):
        n = point["n_scenarios"]
        print(f"  {point['population']} ({n} scenarios, "
              f"{point['scheduler']}, n_fu={point['n_fu']}):")
        print(f"    loop     {point['loop']['total_us']:>12.0f} us  "
              f"({point['loop']['scenarios_per_sec']:>8.1f} scen/s)")
        print(f"    batched  {point['batched']['total_us']:>12.0f} us  "
              f"({point['batched']['scenarios_per_sec']:>8.1f} scen/s)")
        print(f"    speedup  {point['speedup']:.2f}x")
    h = data["headline"]
    print(f"  headline: {h['speedup']:.2f}x on the {h['n_scenarios']}"
          f"-scenario policy grid (target >= {h['target_speedup']}x: "
          f"{'MET' if h['met'] else 'NOT MET'})")
    g = data["golden_equiv"]
    print(f"  golden_equiv: grid {g['grid']['n_scenarios']} scenarios x "
          f"{g['grid']['n_modes']} modes {g['grid']['schedulers']}; "
          f"generated {g['generated']['n_scenarios']} x "
          f"{g['generated']['n_modes']} {g['generated']['schedulers']} — "
          "all equal")


if __name__ == "__main__":
    main()
