"""Reproductions of the paper's evaluation (Figs 7-10, Table II).

All cycle numbers come through the unified ``hts.run`` / ``hts.sweep``
facade (compiled JAX machine, event-skip mode, schedule-equivalence-tested
against the golden simulator).  Each function returns rows of
(name, us_per_call, derived) for benchmarks/run.py.
"""
from __future__ import annotations

import time

from repro.core import hts
from repro.core.hts import costs, programs

SCHEDULERS = costs.ALL_SCHEDULERS


def fig7(n_fu_list=(1, 2, 4)):
    """Synthetic benchmarks without branches × schedulers × FU counts."""
    rows = []
    for gen in programs.SYNTHETIC_NO_BRANCH:
        bench = gen()
        for n_fu in n_fu_list:
            base = None
            for sched in SCHEDULERS:
                r = hts.run(bench, scheduler=sched, n_fu=n_fu)
                if base is None:                   # naive first
                    base = r.cycles
                rows.append((f"fig7/{bench.name}/{sched}/fu{n_fu}", r.wall_us,
                             {"cycles": r.cycles,
                              "speedup_vs_naive": base / r.cycles}))
    return rows


def fig8(n_fu: int = 2):
    """Branch benchmarks: speculation on/off, taken/not-taken."""
    rows = []
    for gen in programs.SYNTHETIC_BRANCH:
        bench = gen()
        base = None
        for sched in SCHEDULERS:
            r = hts.run(bench, scheduler=sched, n_fu=n_fu)
            if base is None:
                base = r.cycles
            rows.append((f"fig8/{bench.name}/{sched}/fu{n_fu}", r.wall_us,
                         {"cycles": r.cycles,
                          "speedup_vs_naive": base / r.cycles,
                          "spec_aborted": r.spec_aborted}))
    return rows


def fig9(bands: int = 8, n_fu: int = 2):
    """Audio compression (Algorithm 1), BT and BNT variants."""
    rows = []
    for time_domain in (False, True):
        bench = programs.audio_compression(bands, time_domain)
        base = None
        for sched in SCHEDULERS:
            r = hts.run(bench, scheduler=sched, n_fu=n_fu)
            if base is None:
                base = r.cycles
            rows.append((f"fig9/{bench.name}/{sched}", r.wall_us,
                         {"cycles": r.cycles,
                          "speedup_vs_naive": base / r.cycles}))
    return rows


def fig10(bands_list=(8, 16, 32), n_fu_list=(1, 2, 4, 8, 16)):
    """Strong scaling with FU count × number of bands — one ``hts.sweep``
    (a single vmapped machine per scheduler) per program size."""
    rows = []
    max_speedup = 0.0
    # the looped program is ~45 instructions; right-size the machine state so
    # the vmapped compile stays cheap (max 32 bands × 5 tasks + 1 = 161 tasks).
    # tracker = 256 so high-FU configs never crawl on structural stalls.
    params = hts.HtsParams(max_tasks=256, mem_words=2048, tracker_entries=256,
                           rs_entries=64)
    for bands in bands_list:
        bench = programs.audio_compression(bands, time_domain=False)
        sw = hts.sweep(bench, n_fu=n_fu_list,
                       schedulers=("naive", "hts_spec"), params=params,
                       max_prog=64)
        for i, k in enumerate(n_fu_list):
            naive_c = int(sw.cycles["naive"][i])
            hts_c = int(sw.cycles["hts_spec"][i])
            sp = naive_c / hts_c
            max_speedup = max(max_speedup, sp)
            rows.append((f"fig10/audio_bands{bands}/fu{k}",
                         sw.wall_us["hts_spec"] / len(n_fu_list),
                         {"hts_cycles": hts_c, "naive_cycles": naive_c,
                          "speedup": sp}))
    rows.append(("fig10/max_speedup_vs_naive", 0.0,
                 {"speedup": max_speedup,
                  "paper_claim": "up to 12x (paper abstract)"}))
    return rows


def table2():
    """Table II: execute each DSP accelerator function as its Pallas kernel
    and report wall time; 'derived' carries the paper's cycle cost."""
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops
    rows = []
    table = ops.dsp_dispatch_table()
    rng = np.random.default_rng(0)
    for name, (fid, frame, cyc) in costs.FUNCTIONS.items():
        x = jnp.asarray(rng.standard_normal((64, frame)).astype(np.float32))
        fn = table[name]
        fn(x).block_until_ready()          # compile
        t0 = time.perf_counter()
        reps = 5
        for _ in range(reps):
            out = fn(x)
        out.block_until_ready()
        us = (time.perf_counter() - t0) * 1e6 / reps
        rows.append((f"tableII/{name}", us,
                     {"paper_cycles": cyc, "frame": frame,
                      "batch": 64}))
    return rows
