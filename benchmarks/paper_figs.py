"""Reproductions of the paper's evaluation (Figs 7-10, Table II).

All cycle numbers come from the compiled JAX machine (event-skip mode,
schedule-equivalence-tested against the golden simulator).  Each function
returns rows of (name, us_per_call, derived) for benchmarks/run.py.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hts import assembler, costs, machine, programs
from repro.core.hts.golden import HtsParams

SCHEDULERS = costs.ALL_SCHEDULERS


def _sim(bench, sched: str, n_fu: int, params=None):
    params = params or HtsParams()
    code = assembler.assemble(bench.asm)
    t0 = time.perf_counter()
    out = machine.simulate(code, costs.costs_by_name(sched), params,
                           n_fu=np.array([n_fu] * 10),
                           mem_init=bench.mem_init, effects=bench.effects)
    dt = (time.perf_counter() - t0) * 1e6
    assert out["halted"], (bench.name, sched)
    return int(out["cycles"]), dt, out


def fig7(n_fu_list=(1, 2, 4)):
    """Synthetic benchmarks without branches × schedulers × FU counts."""
    rows = []
    for gen in programs.SYNTHETIC_NO_BRANCH:
        bench = gen()
        for n_fu in n_fu_list:
            base = None
            for sched in SCHEDULERS:
                cyc, us, _ = _sim(bench, sched, n_fu)
                base = base or cyc                 # naive first
                rows.append((f"fig7/{bench.name}/{sched}/fu{n_fu}", us,
                             {"cycles": cyc, "speedup_vs_naive": base / cyc}))
    return rows


def fig8(n_fu: int = 2):
    """Branch benchmarks: speculation on/off, taken/not-taken."""
    rows = []
    for gen in programs.SYNTHETIC_BRANCH:
        bench = gen()
        base = None
        for sched in SCHEDULERS:
            cyc, us, out = _sim(bench, sched, n_fu)
            base = base or cyc
            rows.append((f"fig8/{bench.name}/{sched}/fu{n_fu}", us,
                         {"cycles": cyc, "speedup_vs_naive": base / cyc,
                          "spec_aborted": int(out["spec_aborted"])}))
    return rows


def fig9(bands: int = 8, n_fu: int = 2):
    """Audio compression (Algorithm 1), BT and BNT variants."""
    rows = []
    for time_domain in (False, True):
        bench = programs.audio_compression(bands, time_domain)
        base = None
        for sched in SCHEDULERS:
            cyc, us, _ = _sim(bench, sched, n_fu)
            base = base or cyc
            rows.append((f"fig9/{bench.name}/{sched}", us,
                         {"cycles": cyc, "speedup_vs_naive": base / cyc}))
    return rows


import functools


@functools.lru_cache(maxsize=8)
def _vmapped_runner(sched: str, max_prog: int, params: HtsParams):
    """One compiled vmapped machine per scheduler — the program, FU configs
    and memory images are all runtime arguments, so every (bands × FU) point
    reuses it."""
    ms = machine.MachineSpec(params=params, costs=costs.costs_by_name(sched),
                             event_skip=True, max_cycles=50_000_000)
    return jax.jit(jax.vmap(machine.make_machine(ms, max_prog),
                            in_axes=(None, None, 0, None, None)))


def fig10(bands_list=(8, 16, 32), n_fu_list=(1, 2, 4, 8, 16)):
    """Strong scaling with FU count × number of bands — executed as ONE
    vmapped machine per scheduler: the FU axis is vmapped, the program
    (bands) is a runtime input."""
    rows = []
    max_speedup = 0.0
    # the looped program is ~42 instructions; right-size the machine state so
    # the vmapped compile stays cheap (max 32 bands × 5 tasks + 1 = 161 tasks).
    # tracker = 256 so high-FU configs never crawl on structural stalls.
    params = HtsParams(max_tasks=256, mem_words=2048, tracker_entries=256,
                       rs_entries=64)
    for bands in bands_list:
        bench = programs.audio_compression(bands, time_domain=False)
        code = assembler.assemble(bench.asm)
        ftab, p_len = machine.pack_program(code, 64)
        mem, eff = machine.images(params, bench.mem_init, bench.effects)
        n_fu_arr = jnp.asarray([[k] * 10 for k in n_fu_list], jnp.int32)

        results = {}
        for sched in ("naive", "hts_spec"):
            run = _vmapped_runner(sched, 64, params)
            t0 = time.perf_counter()
            out = run(jnp.asarray(ftab), p_len, n_fu_arr,
                      jnp.asarray(mem), jnp.asarray(eff))
            cycles = np.asarray(out["cycles"])
            dt = (time.perf_counter() - t0) * 1e6 / len(n_fu_list)
            assert np.asarray(out["halted"]).all()
            results[sched] = (cycles, dt)
        for i, k in enumerate(n_fu_list):
            naive_c = int(results["naive"][0][i])
            hts_c = int(results["hts_spec"][0][i])
            sp = naive_c / hts_c
            max_speedup = max(max_speedup, sp)
            rows.append((f"fig10/audio_bands{bands}/fu{k}",
                         results["hts_spec"][1],
                         {"hts_cycles": hts_c, "naive_cycles": naive_c,
                          "speedup": sp}))
    rows.append(("fig10/max_speedup_vs_naive", 0.0,
                 {"speedup": max_speedup,
                  "paper_claim": "up to 12x (paper abstract)"}))
    return rows


def table2():
    """Table II: execute each DSP accelerator function as its Pallas kernel
    and report wall time; 'derived' carries the paper's cycle cost."""
    from repro.kernels import ops
    rows = []
    table = ops.dsp_dispatch_table()
    rng = np.random.default_rng(0)
    for name, (fid, frame, cyc) in costs.FUNCTIONS.items():
        x = jnp.asarray(rng.standard_normal((64, frame)).astype(np.float32))
        fn = table[name]
        fn(x).block_until_ready()          # compile
        t0 = time.perf_counter()
        reps = 5
        for _ in range(reps):
            out = fn(x)
        out.block_until_ready()
        us = (time.perf_counter() - t0) * 1e6 / reps
        rows.append((f"tableII/{name}", us,
                     {"paper_cycles": cyc, "frame": frame,
                      "batch": 64}))
    return rows
