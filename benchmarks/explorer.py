"""Design-space explorer: FU mixes under an area budget, Pareto-ranked.

The point of heterogeneous cost tables is architectural search: with
per-(class, unit) latency multipliers as *traced runtime data*, a whole
design grid — every FU mix × both issue arbiters — evaluates as ONE
compiled ``hts.run_many`` batch (cost tables, FU counts, and the eft flag
all ride the scenario vmap axis; nothing recompiles between design
points).

The explored space
------------------
Two unit implementations of the hot class (``dct``, the only class the
workload exercises):

* **fast** — cost multiplier 1, area 3 (the paper's calibrated unit);
* **slow** — cost multiplier 3, area 1 (a cheaper, 3x-latency variant).

A *design* is a (n_slow, n_fast) mix with total area ``3*n_fast + n_slow``
within the budget.  Slow units sit at the LOW flattened indices, where the
baseline greedy arbiter looks first — so greedy genuinely pays for slow
units while the ``eft`` arbiter routes around them whenever a fast unit is
free; each design is evaluated under both arbiters.

The workload is the repo's standard contended shape (one latency-sensitive
chain + greedy same-class floods, distinct pids), so every design point
reports **makespan** (total cycles), **area**, and **fairness** (max
per-tenant slowdown vs that tenant's solo run *on the same design* — solo
baselines are one more batched run).  A point is Pareto-optimal if no
other point is <= on all three axes and < on one.

Honesty + verification:

* every reported design point is ``hts.compare``-verified — golden oracle
  ≡ compiled machine with event-skip on AND off;
* the same grid re-runs with uniform (all-ones) cost tables, where EFT
  provably degrades to greedy — the measured ``uniform_eft_delta_cycles``
  is committed (expected: exactly 0 on every design).

    PYTHONPATH=src python -m benchmarks.explorer            # writes JSON
    PYTHONPATH=src python -m benchmarks.explorer --smoke    # CI-sized run

JSON lands in ``BENCH_explorer.json`` (repo root by default); see
docs/BENCHMARKS.md for the schema.  Headline acceptance: >= 8 Pareto
points under the area budget, every point verified, and zero
uniform-cost eft-vs-greedy delta.
"""
from __future__ import annotations

import argparse
import json
import pathlib

from repro.core import hts
from repro.core.hts.builder import Program
from repro.core.hts.policy import SchedPolicy

DEFAULT_OUT = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_explorer.json"

#: the two dct-unit implementations (cost multiplier, area units)
UNIT_TYPES = {"fast": {"cost": 1, "area": 3}, "slow": {"cost": 3, "area": 1}}
AREA_BUDGET = 9
MAX_UNITS = 4          # machine pool width per class
HI_PID = 1


# ---------------------------------------------------------------------------
# workload: the contended multi-tenant shape (all-dct, so the dct mix IS
# the design)
# ---------------------------------------------------------------------------
def _hi_chain(chain: int = 6) -> Program:
    p = Program("hi", region_base=0x100)
    frame = p.input(0x10, 4, "frame")
    with p.process(HI_PID):
        prev = frame
        for i in range(chain):
            prev = p.task("dct", in_=prev, out=4, in_size=4, tid=i)
    return p


def _greedy(pid: int, tasks: int = 8) -> Program:
    p = Program(f"greedy{pid}", region_base=0x180 + 0x80 * (pid - 2))
    frame = p.input(0x10, 4, "frame")
    with p.process(pid):
        for i in range(tasks):
            p.task("dct", in_=frame, out=4, tid=i & 0xF)
    return p


def build_workload(n_greedy: int = 2):
    """(merged program, {pid: solo program}) of the contended shape."""
    tenants = [_hi_chain()] + [_greedy(2 + k) for k in range(n_greedy)]
    merged = Program.merge(tenants, "explorer_contended",
                           require_distinct_pids=True)
    pids = [HI_PID] + [2 + k for k in range(n_greedy)]
    return merged, dict(zip(pids, tenants))


# ---------------------------------------------------------------------------
# the design grid
# ---------------------------------------------------------------------------
def enumerate_designs(area_budget: int = AREA_BUDGET,
                      max_units: int = MAX_UNITS):
    """Every (n_slow, n_fast) dct mix within the area budget.

    Slow units first in the cost row — the adversarial layout for the
    greedy arbiter.  Returns dicts with the mix, its area, the per-class
    ``n_fu`` override and the ``fu_cost`` row.
    """
    fast, slow = UNIT_TYPES["fast"], UNIT_TYPES["slow"]
    designs = []
    for n_fast in range(max_units + 1):
        for n_slow in range(max_units + 1 - n_fast):
            if n_fast + n_slow == 0:
                continue
            area = n_fast * fast["area"] + n_slow * slow["area"]
            if area > area_budget:
                continue
            designs.append({
                "name": f"{n_slow}slow+{n_fast}fast",
                "n_slow": n_slow, "n_fast": n_fast,
                "area": area,
                "n_units": n_slow + n_fast,
                "cost_row": (slow["cost"],) * n_slow
                            + (fast["cost"],) * n_fast,
            })
    return designs


def _lane_plan(designs, modes=("greedy", "eft"), uniform: bool = False):
    """Per-lane (n_fu, fu_cost, policy) for one batched grid evaluation."""
    n_fu, fu_cost, pols, keys = [], [], [], []
    for d in designs:
        for mode in modes:
            n_fu.append({"dct": d["n_units"]})
            fu_cost.append(None if uniform else {"dct": d["cost_row"]})
            pols.append(SchedPolicy(issue_mode=mode))
            keys.append((d["name"], mode))
    return n_fu, fu_cost, pols, keys


def _norm_point_n_fu(spec):
    from repro.core.hts import costs
    return tuple(spec.get("dct", 1) if costs.FUNC_NAMES[c] == "dct" else 1
                 for c in range(costs.NUM_FUNCS))


def evaluate_grid(designs, *, modes=("greedy", "eft"),
                  uniform: bool = False, scheduler: str = "hts_spec"):
    """The whole design × arbiter grid as ONE run_many batch (plus one
    more for the per-tenant solo baselines).  Returns per-(design, mode)
    rows with makespan, area, and max per-tenant slowdown."""
    merged, solos = build_workload()
    n_fu, fu_cost, pols, keys = _lane_plan(designs, modes, uniform)
    n_fu = [_norm_point_n_fu(s) for s in n_fu]
    shared = hts.run_many([merged] * len(keys), scheduler=scheduler,
                          n_fu=n_fu, fu_cost=fu_cost, policy=pols)
    # solo baselines: every tenant on every (design, mode) lane
    pids = list(solos)
    solo_res = hts.run_many(
        [solos[p] for _ in keys for p in pids], scheduler=scheduler,
        n_fu=[f for f in n_fu for _ in pids],
        fu_cost=[c for c in fu_cost for _ in pids],
        policy=[p for p in pols for _ in pids])
    rows = []
    for i, (dname, mode) in enumerate(keys):
        d = next(x for x in designs if x["name"] == dname)
        solo_c = {p: int(solo_res.cycles[i * len(pids) + j])
                  for j, p in enumerate(pids)}
        r = shared[i]
        slowdowns = {p: r.app_makespan(p) / solo_c[p] for p in pids}
        rows.append({
            "design": dname, "mode": mode,
            "area": d["area"], "n_slow": d["n_slow"], "n_fast": d["n_fast"],
            "makespan": int(shared.cycles[i]),
            "max_slowdown": round(max(slowdowns.values()), 4),
        })
    return rows


def pareto(rows):
    """Non-dominated rows, minimising (makespan, area, max_slowdown)."""
    def key(r):
        return (r["makespan"], r["area"], r["max_slowdown"])

    def dominates(a, b):
        ka, kb = key(a), key(b)
        return all(x <= y for x, y in zip(ka, kb)) and ka != kb

    return [r for r in rows
            if not any(dominates(o, r) for o in rows if o is not r)]


def verify_grid(designs, *, modes=("greedy", "eft"),
                schedulers=("hts_spec",)) -> dict:
    """Every design point compare-verified: golden ≡ machine, event-skip
    on and off (compare raises on the first divergence)."""
    merged, _ = build_workload()
    n_fu, fu_cost, pols, keys = _lane_plan(designs, modes)
    rep = hts.compare([merged] * len(keys),
                      n_fu=[_norm_point_n_fu(s) for s in n_fu],
                      fu_cost=fu_cost, policy=pols, schedulers=schedulers)
    return {"verified": True, "n_points": len(rep),
            "schedulers": list(rep.schedulers), "n_modes": rep.n_modes}


def trajectory(*, area_budget: int = AREA_BUDGET,
               verify_all: bool = True, verify_n: int = 4) -> dict:
    designs = enumerate_designs(area_budget)
    rows = evaluate_grid(designs)
    frontier = pareto(rows)
    for r in rows:
        r["on_frontier"] = r in frontier

    # honesty check: uniform costs => eft degrades to greedy exactly
    uni = evaluate_grid(designs, uniform=True)
    by_design = {}
    for r in uni:
        by_design.setdefault(r["design"], {})[r["mode"]] = r["makespan"]
    uniform_delta = max(abs(m["eft"] - m["greedy"])
                        for m in by_design.values())

    verified = verify_grid(designs if verify_all else designs[:verify_n])

    het = [r for r in rows if r["n_slow"] and r["n_fast"]]
    eft_wins = sum(
        1 for r in het if r["mode"] == "eft" and r["makespan"] < next(
            o["makespan"] for o in het
            if o["design"] == r["design"] and o["mode"] == "greedy"))
    best = {m: min(r["makespan"] for r in rows if r["mode"] == m)
            for m in ("greedy", "eft")}
    return {
        "bench": "explorer",
        "workload": "contended: 1 chain (pid 1) + 2 greedy dct floods",
        "unit_types": UNIT_TYPES,
        "area_budget": area_budget,
        "n_designs": len(designs),
        "designs": [{k: d[k] for k in
                     ("name", "n_slow", "n_fast", "area", "cost_row")}
                    for d in designs],
        "points": rows,
        "pareto_frontier": frontier,
        "uniform_eft_delta_cycles": uniform_delta,
        "verified": verified,
        "headline": {
            "n_designs": len(designs),
            "n_points": len(rows),
            "n_frontier": len(frontier),
            "frontier_min_points": 8,
            "met": len(frontier) >= 8,
            "best_makespan_greedy": best["greedy"],
            "best_makespan_eft": best["eft"],
            "eft_wins_mixed_designs": eft_wins,
            "n_mixed_designs": len(het) // 2,
            "uniform_eft_delta_cycles": uniform_delta,
            "all_points_compare_verified": verified["verified"]
                and verified["n_points"] == len(rows),
        },
    }


def section():
    """``benchmarks.run`` integration: (name, us, derived) rows."""
    import time
    designs = enumerate_designs()
    t0 = time.perf_counter()
    rows = evaluate_grid(designs)
    us = (time.perf_counter() - t0) * 1e6
    frontier = pareto(rows)
    return [(f"explorer/grid{len(rows)}/budget{AREA_BUDGET}", us, {
        "n_designs": len(designs),
        "n_frontier": len(frontier),
        "best_makespan_eft": min(r["makespan"] for r in rows
                                 if r["mode"] == "eft"),
        "best_makespan_greedy": min(r["makespan"] for r in rows
                                    if r["mode"] == "greedy"),
    })]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--area-budget", type=int, default=AREA_BUDGET)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (smaller budget, 4 points verified; "
                         "no JSON unless --out is given)")
    ap.add_argument("--out", default=None,
                    help=f"output path (default {DEFAULT_OUT}; "
                         "smoke runs write no JSON unless set)")
    args = ap.parse_args()

    if args.smoke:
        data = trajectory(area_budget=min(args.area_budget, 7),
                          verify_all=False, verify_n=4)
    else:
        data = trajectory(area_budget=args.area_budget)

    out = None
    if args.out:
        out = pathlib.Path(args.out)
    elif not args.smoke:
        out = DEFAULT_OUT
    if out is not None:
        out.write_text(json.dumps(data, indent=2, default=float) + "\n")
        print(f"wrote {out}")

    h = data["headline"]
    print(f"  {data['n_designs']} designs within area {data['area_budget']}"
          f" x 2 arbiters = {h['n_points']} points, one batched machine")
    for r in data["pareto_frontier"]:
        print(f"    frontier: {r['design']:<14} {r['mode']:<6} "
              f"makespan {r['makespan']:>6}  area {r['area']:>2}  "
              f"slowdown {r['max_slowdown']:.2f}")
    print(f"  best makespan: greedy {h['best_makespan_greedy']}, "
          f"eft {h['best_makespan_eft']} "
          f"(eft wins {h['eft_wins_mixed_designs']}/{h['n_mixed_designs']} "
          "mixed designs)")
    print(f"  uniform-cost eft-vs-greedy delta: "
          f"{h['uniform_eft_delta_cycles']} cycles")
    print(f"  frontier {h['n_frontier']} points (target >= "
          f"{h['frontier_min_points']}: {'MET' if h['met'] else 'NOT MET'}); "
          f"verified {data['verified']['n_points']} points x "
          f"{data['verified']['n_modes']} modes")


if __name__ == "__main__":
    main()
