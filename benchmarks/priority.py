"""Priority-aware scheduling benchmark: QoS recovery under contention.

The starvation scenario the age-order arbiter cannot fix: one
latency-sensitive tenant (pid 1, a dependency *chain* whose tasks become
ready one at a time) arrives *after* N greedy tenants have flooded the
reservation station with independent same-class tasks (its arrival lag is
modelled by a nop prelude, so every one of its tasks is younger than the
whole backlog).  Under pure age order the chain queues behind the entire
flood at every hop; with a priority weight on pid 1 it jumps the queue
and re-acquires a unit the cycle it wakes, so its makespan approaches the
solo runtime while aggregate throughput is untouched (the weighted
arbiter is work-conserving — see ``core/hts/policy.py``).

Swept axes: priority weight x FU count x tenant mix, plus per-class FU
*quota* points: capping each greedy pid bounds its occupancy, and when
the greedy caps sum to less than the pool size a unit is effectively
reserved for the latency-sensitive tenant — QoS without any weights.

    PYTHONPATH=src python -m benchmarks.priority             # writes JSON
    PYTHONPATH=src python -m benchmarks.priority --weights 0,2,8 --fu 1,2

The JSON lands in ``BENCH_priority.json`` (repo root by default); see
docs/BENCHMARKS.md for the field-by-field schema.  Headline check (the
repo's QoS acceptance bar): at some contended point the high-priority
tenant's makespan is <= 1.15x its solo runtime while shared-run cycles
regress < 5% vs unweighted sharing.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

from repro.core import hts
from repro.core.hts.builder import Program

DEFAULT_WEIGHTS = (0, 1, 2, 8)      # 0 = unweighted age-order baseline
DEFAULT_FU = (1, 2)
DEFAULT_MIXES = (2, 4)              # number of greedy tenants
HI_PID = 1
FUNC = "dct"                        # all tenants contend for one class
DEFAULT_OUT = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_priority.json"


def hi_tenant(chain: int = 8, delay: int = 0) -> Program:
    """The latency-sensitive app: a ``chain``-deep RAW chain (pid 1).

    ``delay`` nops model a late arrival: in the round-robin merge they hold
    the chain's dispatch back until the greedy floods have filled the RS,
    so every chain task is *younger* (higher age) than the whole backlog —
    the worst case for the age-order arbiter."""
    p = Program("hi", region_base=0x100)
    frame = p.input(0x10, 4, "frame")
    for _ in range(delay):
        p.nop()
    with p.process(HI_PID):
        prev = frame
        for i in range(chain):
            prev = p.task(FUNC, in_=prev, out=4, in_size=4, tid=i)
    return p


def greedy_tenant(pid: int, tasks: int = 10) -> Program:
    """A best-effort flood: ``tasks`` independent same-class tasks."""
    p = Program(f"greedy{pid}", region_base=0x200 + 0x100 * (pid - 2))
    frame = p.input(0x10, 4, "frame")
    with p.process(pid):
        for i in range(tasks):
            p.task(FUNC, in_=frame, out=4, tid=i & 0xF)
    return p


def contended(n_greedy: int, *, chain: int = 8, greedy_tasks: int = 10,
              weight: int = 0, quota: int | None = None) -> Program:
    """The merged tenant mix, with pid 1 weighted / greedy pids quota-capped.
    The hi tenant arrives after the floods (``delay=greedy_tasks`` nops)."""
    tenants = [hi_tenant(chain, delay=greedy_tasks)] \
        + [greedy_tenant(2 + k, greedy_tasks) for k in range(n_greedy)]
    priorities = {HI_PID: weight} if weight else None
    quotas = ({2 + k: quota for k in range(n_greedy)} if quota else None)
    return Program.merge(tenants, f"contended_{n_greedy}g_w{weight}",
                         require_distinct_pids=True,
                         priorities=priorities, quotas=quotas)


def bench_point(n_greedy: int, n_fu: int, *, weights=DEFAULT_WEIGHTS,
                chain: int = 8, greedy_tasks: int = 10,
                scheduler: str = "hts_spec") -> dict:
    """One (mix, FU) point: solo baseline + every weight + a quota point."""
    solo = hts.run(hi_tenant(chain, delay=greedy_tasks),
                   scheduler=scheduler, n_fu=n_fu)
    solo_mk = solo.app_makespan(HI_PID)
    base = hts.run(contended(n_greedy, chain=chain,
                             greedy_tasks=greedy_tasks),
                   scheduler=scheduler, n_fu=n_fu)
    point = {"mix": f"1hi+{n_greedy}greedy", "n_greedy": n_greedy,
             "n_fu": n_fu, "hi_chain": chain, "greedy_tasks": greedy_tasks,
             "hi_solo_cycles": solo_mk, "unweighted_cycles": base.cycles,
             "by_weight": {}}
    for w in weights:
        t0 = time.perf_counter()
        r = (base if w == 0 else
             hts.run(contended(n_greedy, chain=chain,
                               greedy_tasks=greedy_tasks, weight=w),
                     scheduler=scheduler, n_fu=n_fu))
        mk = r.app_makespan(HI_PID)
        point["by_weight"][str(w)] = {
            "hi_makespan": mk,
            "hi_slowdown_vs_solo": mk / solo_mk,
            "shared_cycles": r.cycles,
            "throughput_vs_unweighted": base.cycles / r.cycles,
            "utilization": r.utilization,
            "wall_us": (time.perf_counter() - t0) * 1e6,
        }
    # complementary mechanism: cap every greedy pid at 1 in-flight unit
    rq = hts.run(contended(n_greedy, chain=chain, greedy_tasks=greedy_tasks,
                           quota=1),
                 scheduler=scheduler, n_fu=n_fu)
    mq = rq.app_makespan(HI_PID)
    point["greedy_quota_1"] = {
        "hi_makespan": mq, "hi_slowdown_vs_solo": mq / solo_mk,
        "shared_cycles": rq.cycles,
        "throughput_vs_unweighted": base.cycles / rq.cycles,
    }
    return point


def quota_reservation_demo(n_greedy: int = 2, *, chain: int = 8,
                           greedy_tasks: int = 12,
                           scheduler: str = "hts_spec") -> dict:
    """Quotas as capacity *reservation*: cap every greedy pid at 1 in-flight
    unit with ``n_fu = n_greedy + 1`` units in the class — the sum of greedy
    caps is below the pool size, so one unit is always left for pid 1 and
    its chain runs at solo speed without any priority weight.  (At the swept
    points, where greedy caps >= n_fu, the same quota only bounds occupancy
    — age order still hands every freed unit back to the flood.)"""
    n_fu = n_greedy + 1
    solo = hts.run(hi_tenant(chain, delay=greedy_tasks),
                   scheduler=scheduler, n_fu=n_fu)
    base = hts.run(contended(n_greedy, chain=chain,
                             greedy_tasks=greedy_tasks),
                   scheduler=scheduler, n_fu=n_fu)
    rq = hts.run(contended(n_greedy, chain=chain, greedy_tasks=greedy_tasks,
                           quota=1),
                 scheduler=scheduler, n_fu=n_fu)
    solo_mk = solo.app_makespan(HI_PID)
    return {
        "mix": f"1hi+{n_greedy}greedy", "n_fu": n_fu, "greedy_quota": 1,
        "hi_solo_cycles": solo_mk,
        "hi_slowdown_unquotaed": base.app_makespan(HI_PID) / solo_mk,
        "hi_slowdown_quotaed": rq.app_makespan(HI_PID) / solo_mk,
        "throughput_vs_unquotaed": base.cycles / rq.cycles,
    }


def _max_rs_occupancy(result, pid: int) -> int:
    """Peak dispatched-but-not-issued tasks of ``pid`` (RS residency)."""
    iv = [(r.dispatch, r.issue) for r in result.schedule
          if r.pid == pid and not r.aborted and r.dispatch >= 0
          and r.issue >= 0]
    points = sorted({t for s, e in iv for t in (s, e)})
    return max((sum(1 for s, e in iv if s <= t < e) for t in points),
               default=0)


def rs_admission_study(n_greedy: int = 4, n_fu: int = 2, *, chain: int = 8,
                       greedy_tasks: int = 10, cap: int = 4,
                       weight: int = 8,
                       scheduler: str = "hts_spec") -> dict:
    """Per-pid RS admission caps on the 4-greedy dispatch-blocking points.

    The mechanism works as specified — a capped flood's reservation-station
    residency is bounded by the cap, so it can never exhaust the shared
    window — but the measured study also records the *negative finding*:
    in the merged-stream model, dispatch order IS stream order (the N
    tenant programs round-robin through ONE frontend), so a blocking
    admission stall can only delay instructions, never reorder them, and
    the late tenant's makespan does not improve (head-of-line blocking at
    the shared frontend, not the RS, is the binding constraint).

    **Closed by PR 5**: per-tenant frontends (``core/hts/frontend.py``)
    give every tenant its own dispatch stream and the arbiter skips a
    capped stream instead of stalling behind it — ``benchmarks/frontend.py``
    re-runs this exact scenario there and the capped slowdown drops below
    solo+30% (``BENCH_frontend.json``, the ``see_multi_frontend`` pointer
    in the emitted section).
    """
    from repro.core.hts.policy import SchedPolicy
    greedy_pids = tuple(range(2, 2 + n_greedy))
    prog = contended(n_greedy, chain=chain, greedy_tasks=greedy_tasks)
    solo = hts.run(hi_tenant(chain, delay=greedy_tasks),
                   scheduler=scheduler, n_fu=n_fu)
    w_pol = SchedPolicy.of(weights={HI_PID: weight})
    c_pol = SchedPolicy.of(weights={HI_PID: weight},
                           rs_caps={p: cap for p in greedy_pids})
    base = hts.run(prog, scheduler=scheduler, n_fu=n_fu, policy=w_pol)
    capped = hts.run(prog, scheduler=scheduler, n_fu=n_fu, policy=c_pol)
    solo_mk = solo.app_makespan(HI_PID)
    return {
        "mix": f"1hi+{n_greedy}greedy", "n_fu": n_fu, "rs_cap": cap,
        "hi_weight": weight,
        "max_greedy_rs_occupancy_uncapped":
            max(_max_rs_occupancy(base, p) for p in greedy_pids),
        "max_greedy_rs_occupancy_capped":
            max(_max_rs_occupancy(capped, p) for p in greedy_pids),
        "hi_slowdown_weighted": base.app_makespan(HI_PID) / solo_mk,
        "hi_slowdown_weighted_capped": capped.app_makespan(HI_PID) / solo_mk,
        "throughput_vs_weighted": base.cycles / capped.cycles,
        "finding": ("occupancy bounded by the cap; latency unchanged or "
                    "worse — merged-stream head-of-line blocking, see "
                    "docs/BENCHMARKS.md"),
        "see_multi_frontend": ("BENCH_frontend.json — the same scenario "
                               "under per-tenant frontends "
                               "(benchmarks/frontend.py): rs_caps become "
                               "per-stream backpressure and the late "
                               "tenant's slowdown drops below the "
                               "merged-stream figure"),
    }


def trajectory(mixes=DEFAULT_MIXES, fu_points=DEFAULT_FU,
               weights=DEFAULT_WEIGHTS, scheduler: str = "hts_spec") -> dict:
    points = [bench_point(g, f, weights=weights, scheduler=scheduler)
              for g in mixes for f in fu_points]
    best = max(
        (p for p in points),
        key=lambda p: p["by_weight"][str(weights[-1])]
        ["throughput_vs_unweighted"]
        - p["by_weight"][str(weights[-1])]["hi_slowdown_vs_solo"])
    top = best["by_weight"][str(weights[-1])]
    return {
        "bench": "priority",
        "scheduler": scheduler,
        "weights": list(weights),
        "points": points,
        "quota_demo": quota_reservation_demo(mixes[0], scheduler=scheduler),
        "rs_admission": rs_admission_study(mixes[-1], fu_points[-1],
                                           scheduler=scheduler),
        # the acceptance headline: QoS recovered, throughput preserved
        "headline": {
            "mix": best["mix"], "n_fu": best["n_fu"],
            "weight": weights[-1],
            "hi_slowdown_vs_solo": top["hi_slowdown_vs_solo"],
            "throughput_vs_unweighted": top["throughput_vs_unweighted"],
            "qos_recovered": top["hi_slowdown_vs_solo"] <= 1.15,
            "throughput_preserved": top["throughput_vs_unweighted"] >= 0.95,
        },
    }


def section():
    """``benchmarks.run`` integration: (name, us, derived) rows."""
    rows = []
    for n_greedy, n_fu in ((2, 1), (4, 2)):
        t0 = time.perf_counter()
        p = bench_point(n_greedy, n_fu, weights=(0, 8))
        us = (time.perf_counter() - t0) * 1e6
        w8, w0 = p["by_weight"]["8"], p["by_weight"]["0"]
        rows.append((f"priority/{p['mix']}/fu{n_fu}", us, {
            "hi_slowdown_w0": w0["hi_slowdown_vs_solo"],
            "hi_slowdown_w8": w8["hi_slowdown_vs_solo"],
            "throughput_vs_unweighted": w8["throughput_vs_unweighted"],
        }))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--mixes", default=",".join(map(str, DEFAULT_MIXES)),
                    help="comma-separated greedy-tenant counts")
    ap.add_argument("--fu", default=",".join(map(str, DEFAULT_FU)),
                    help="comma-separated FU counts per class")
    ap.add_argument("--weights", default=",".join(map(str, DEFAULT_WEIGHTS)),
                    help="comma-separated hi-pid priority weights (0 first)")
    ap.add_argument("--scheduler", default="hts_spec")
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    args = ap.parse_args()
    data = trajectory(tuple(int(x) for x in args.mixes.split(",")),
                      tuple(int(x) for x in args.fu.split(",")),
                      tuple(int(x) for x in args.weights.split(",")),
                      args.scheduler)
    out = pathlib.Path(args.out)
    out.write_text(json.dumps(data, indent=2, default=float) + "\n")
    print(f"wrote {out}")
    q = data["quota_demo"]
    print(f"  quota demo {q['mix']} fu={q['n_fu']} cap=1: hi slowdown "
          f"{q['hi_slowdown_unquotaed']:.2f} -> {q['hi_slowdown_quotaed']:.2f}")
    ra = data["rs_admission"]
    print(f"  rs admission {ra['mix']} fu={ra['n_fu']} cap={ra['rs_cap']}: "
          f"greedy RS occupancy {ra['max_greedy_rs_occupancy_uncapped']} -> "
          f"{ra['max_greedy_rs_occupancy_capped']}, hi slowdown "
          f"{ra['hi_slowdown_weighted']:.2f} -> "
          f"{ra['hi_slowdown_weighted_capped']:.2f} (head-of-line bound)")
    h = data["headline"]
    print(f"  headline {h['mix']} fu={h['n_fu']} w={h['weight']}: "
          f"hi slowdown {h['hi_slowdown_vs_solo']:.3f} "
          f"(qos_recovered={h['qos_recovered']}), throughput "
          f"{h['throughput_vs_unweighted']:.3f} "
          f"(preserved={h['throughput_preserved']})")
    for p in data["points"]:
        w_hi = data["weights"][-1]
        print(f"  {p['mix']:<12} fu={p['n_fu']}: slowdown "
              + " ".join(f"w{w}={p['by_weight'][str(w)]['hi_slowdown_vs_solo']:.2f}"
                         for w in data["weights"])
              + f" quota1={p['greedy_quota_1']['hi_slowdown_vs_solo']:.2f}"
              + f" tput(w{w_hi})="
              f"{p['by_weight'][str(w_hi)]['throughput_vs_unweighted']:.3f}")


if __name__ == "__main__":
    main()
