"""Documentation executability: every fenced ```python example in README.md
and docs/*.md runs, and every relative markdown link resolves.

Conventions (see docs/ARCHITECTURE.md "Documentation CI"):

* blocks fenced as ```python execute, in order, in one namespace per file
  (so a later snippet can build on an earlier one, like a REPL session);
* an HTML comment line ``<!-- no-run -->`` immediately before a fence
  skips that block (reserved for illustrative fragments);
* all other fences (```bash, ```text, output blocks...) are not executed;
* relative links ``[text](path)`` must point at files that exist.

CI runs this module as its own job (the "docs" job) so documented
snippets cannot rot; it is also part of the fast tier.
"""
import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent

DOC_FILES = sorted(
    [ROOT / "README.md"] + list((ROOT / "docs").glob("*.md")),
    key=lambda p: p.name)

_FENCE = re.compile(r"^```(\w*)\s*$")
_LINK = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(?:#[^)]*)?\)")


def _python_blocks(path: pathlib.Path):
    """(start_line, source) for each runnable ```python fence in ``path``."""
    blocks, lines = [], path.read_text().splitlines()
    i, skip_next = 0, False
    while i < len(lines):
        m = _FENCE.match(lines[i])
        if m and m.group(1) == "python" and not skip_next:
            start = i + 1
            body = []
            i += 1
            while i < len(lines) and not lines[i].startswith("```"):
                body.append(lines[i])
                i += 1
            blocks.append((start + 1, "\n".join(body)))
        elif m and m.group(1) == "python":
            while i + 1 < len(lines) and not lines[i + 1].startswith("```"):
                i += 1
            i += 1          # closing fence
        skip_next = lines[i].strip() == "<!-- no-run -->" if i < len(lines) \
            else False
        i += 1
    return blocks


def _doc_ids():
    return [p.name for p in DOC_FILES]


@pytest.mark.parametrize("path", DOC_FILES, ids=_doc_ids())
def test_docs_exist_and_have_examples(path):
    assert path.exists()
    if path.name in ("README.md", "API.md"):
        assert _python_blocks(path), f"{path.name} has no runnable examples"


@pytest.mark.parametrize("path", DOC_FILES, ids=_doc_ids())
def test_fenced_python_examples_execute(path, monkeypatch):
    """Execute the file's ```python fences in one shared namespace
    (from the repo root, like the commands the docs quote)."""
    monkeypatch.chdir(ROOT)
    blocks = _python_blocks(path)
    ns: dict = {"__name__": f"docs_{path.stem}"}
    for line, src in blocks:
        try:
            exec(compile(src, f"{path.name}:{line}", "exec"), ns)
        except Exception as e:     # noqa: BLE001 - report snippet location
            pytest.fail(f"{path.name} example at line {line} failed: "
                        f"{type(e).__name__}: {e}")


@pytest.mark.parametrize("path", DOC_FILES, ids=_doc_ids())
def test_relative_links_resolve(path):
    text = path.read_text()
    # strip fenced code before scanning for links
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for target in _LINK.findall(text):
        if "://" in target or target.startswith("mailto:"):
            continue
        resolved = (path.parent / target).resolve()
        assert resolved.exists(), (
            f"{path.name}: broken relative link -> {target}")
