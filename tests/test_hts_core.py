"""HTS core: ISA round-trip, assembler, golden-vs-machine equivalence
(including hypothesis-generated random programs), scheduler cost-model
invariants."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.hts import assembler, costs, golden, isa, machine, programs

PARAMS = golden.HtsParams(n_fu=(2,) * 10)
N_FU = np.array([2] * 10)


# ---------------------------------------------------------------------------
# ISA
# ---------------------------------------------------------------------------
@given(st.integers(0, 0xEF), st.integers(0, 0xFFFF), st.integers(0, 0xFF),
       st.integers(0, 0xFFFF), st.integers(0, 0xFF), st.integers(0, 0xF),
       st.integers(0, 0xF), st.integers(0, 0xF))
def test_isa_roundtrip_task(acc, a, asz, b, bsz, tid, pid, ctl):
    ins = isa.Instr(op=isa.OP_TASK, acc=acc, a=a, asz=asz, b=b, bsz=bsz,
                    tid=tid, pid=pid, ctl=ctl)
    got = isa.decode_word(ins.encode())
    assert got == ins


@given(st.sampled_from([isa.OP_ADD, isa.OP_MUL, isa.OP_MOV, isa.OP_JUMP,
                        isa.OP_IF, isa.OP_LBEG, isa.OP_LEND]),
       st.integers(0, 0xFFFF), st.integers(0, 0xFF), st.integers(0, 0xFFFF))
def test_isa_roundtrip_ctrl(op, a, asz, b):
    ins = isa.Instr(op=op, a=a, asz=asz, b=b)
    assert isa.decode_word(ins.encode()) == ins


def test_assembler_matches_paper_example():
    """The §V-B independent-nodes example assembles and disassembles."""
    text = """\
real_fir 10 2 13 2 0 0 0 0000
complex_fir 16 2 19 2 1 0 0 0000
adaptive_fir 23 3 28 3 2 0 0 0000
vector_dot 40 4 48 4 3 0 0 0000
iir 32 3 36 3 4 0 0 0000"""
    code = assembler.assemble(text)
    assert code.shape == (5, 4)
    back = assembler.disassemble(code)
    assert back.splitlines()[0].startswith("real_fir 10 2 13 2")
    ins = isa.decode_program(code)
    assert ins[3].acc == costs.FUNC_IDS["vector_dot"]
    assert ins[3].a == 0x40 and ins[3].b == 0x48


def test_assembler_labels_and_errors():
    code = assembler.assemble("jump @end 0 0 0\n@end\nnop")
    assert isa.decode_program(code)[0].a == 1
    with pytest.raises(assembler.AsmError):
        assembler.assemble("bogus_acc 0 0 0 0")
    with pytest.raises(assembler.AsmError):
        assembler.assemble("jump @missing")


# ---------------------------------------------------------------------------
# scheduler cost-model invariants over all benchmarks
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def bench_cycles():
    out = {}
    for bench in programs.all_benches():
        code = assembler.assemble(bench.asm)
        out[bench.name] = {
            s: golden.run(code, costs.costs_by_name(s), PARAMS,
                          bench.mem_init, bench.effects)
            for s in costs.ALL_SCHEDULERS
        }
    return out


def test_hts_never_slower_than_naive(bench_cycles):
    for name, rs in bench_cycles.items():
        assert rs["hts_nospec"].cycles <= rs["naive"].cycles, name
        assert rs["hts_spec"].cycles <= rs["naive"].cycles, name


def test_naive_matches_closed_form(bench_cycles):
    """Naive = Σ(exec + interrupt) + per-task dispatch cycle (paper §VI-C)."""
    r = bench_cycles["no_dependency"]["naive"]
    total_exec = sum(costs.FUNC_CYCLES[t.func] for t in r.tasks)
    n = len(r.tasks)
    expect = total_exec + n * (costs.INTERRUPT_LATENCY + 2) + 1
    assert abs(r.cycles - expect) <= n          # ±1 cycle/task bookkeeping


def test_speculation_only_helps_or_is_free(bench_cycles):
    for name, rs in bench_cycles.items():
        # mis-speculation must be ~free (paper Fig 8 observation)
        assert rs["hts_spec"].cycles <= rs["hts_nospec"].cycles + 5, name


def test_correct_speculation_wins(bench_cycles):
    rs = bench_cycles["branch_not_taken_no_dep"]
    assert rs["hts_spec"].cycles < rs["hts_nospec"].cycles


def test_spec_aborts_only_on_taken_branches(bench_cycles):
    assert bench_cycles["branch_taken_no_dep"]["hts_spec"].spec_aborted > 0
    assert bench_cycles["branch_not_taken_no_dep"]["hts_spec"].spec_aborted == 0


# ---------------------------------------------------------------------------
# golden ≡ machine (fixed corpus, both event-skip modes)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bench_idx", range(len(programs.ALL_SYNTHETIC)))
@pytest.mark.parametrize("sched", ["naive", "hts_spec"])
def test_machine_equals_golden(bench_idx, sched):
    bench = programs.ALL_SYNTHETIC[bench_idx]()
    code = assembler.assemble(bench.asm)
    cm = costs.costs_by_name(sched)
    g = golden.run(code, cm, PARAMS, bench.mem_init, bench.effects)
    m = machine.simulate(code, cm, PARAMS, n_fu=N_FU,
                         mem_init=bench.mem_init, effects=bench.effects)
    assert m["halted"] and int(m["cycles"]) == g.cycles
    assert machine.schedule_tuple(m) == g.schedule_tuple()


# ---------------------------------------------------------------------------
# hypothesis: random straight-line task programs
# ---------------------------------------------------------------------------
@st.composite
def random_program(draw):
    n = draw(st.integers(2, 14))
    lines = []
    for i in range(n):
        func = draw(st.sampled_from(list(costs.FUNC_IDS)))
        if i and draw(st.booleans()):
            src = 0x100 + draw(st.integers(0, i - 1)) * 8       # RAW dep
        else:
            src = 0x10
        dst = 0x100 + i * 8
        # occasional WAW: write an earlier task's region
        if i and draw(st.integers(0, 4)) == 0:
            dst = 0x100 + draw(st.integers(0, i - 1)) * 8
        lines.append(f"{func} {src:x} 4 {dst:x} 4 {i & 0xF:x} 0 0 0")
    return "\n".join(lines)


@settings(max_examples=25, deadline=None)
@given(random_program(),
       st.sampled_from(["naive", "software", "hts_spec"]),
       st.sampled_from([1, 3]))
def test_machine_equals_golden_random(asm, sched, n_fu):
    code = assembler.assemble(asm)
    cm = costs.costs_by_name(sched)
    p = golden.HtsParams(n_fu=(n_fu,) * 10)
    g = golden.run(code, cm, p, None, None)
    m = machine.simulate(code, cm, p, n_fu=np.array([n_fu] * 10))
    assert m["halted"]
    assert int(m["cycles"]) == g.cycles
    assert machine.schedule_tuple(m) == g.schedule_tuple()


@settings(max_examples=10, deadline=None)
@given(random_program())
def test_event_skip_is_exact(asm):
    """Event-skip mode must produce bit-identical schedules."""
    code = assembler.assemble(asm)
    cm = costs.costs_by_name("hts_spec")
    a = machine.simulate(code, cm, PARAMS, n_fu=N_FU, event_skip=True)
    b = machine.simulate(code, cm, PARAMS, n_fu=N_FU, event_skip=False)
    assert machine.schedule_tuple(a) == machine.schedule_tuple(b)
    assert int(a["cycles"]) == int(b["cycles"])


# ---------------------------------------------------------------------------
# vmap over FU configurations (Fig-10 machinery)
# ---------------------------------------------------------------------------
def test_vmap_over_fu_configs():
    import jax
    import jax.numpy as jnp
    bench = programs.no_dependency(12)
    code = assembler.assemble(bench.asm)
    ftab, p_len = machine.pack_program(code, 64)
    mem, eff = machine.images(PARAMS, bench.mem_init, bench.effects)
    ms = machine.MachineSpec(params=PARAMS,
                             costs=costs.costs_by_name("hts_spec"))
    run = jax.jit(jax.vmap(machine.make_machine(ms, 64),
                           in_axes=(None, None, 0, None, None)))
    n_fus = jnp.asarray([[1] * 10, [2] * 10, [4] * 10], jnp.int32)
    out = run(jnp.asarray(ftab), p_len, n_fus, jnp.asarray(mem),
              jnp.asarray(eff))
    cycles = np.asarray(out["cycles"])
    assert (cycles[0] >= cycles[1]).all() and cycles[1] >= cycles[2]
    # each vmapped row equals its standalone simulation
    for i, k in enumerate((1, 2, 4)):
        solo = machine.simulate(code, costs.costs_by_name("hts_spec"),
                                PARAMS, n_fu=np.array([k] * 10),
                                max_prog=64)
        assert int(solo["cycles"]) == int(cycles[i])
