"""Population-scale batching: packing/bucketing units (fast tier) and the
one-batch >= 32-scenario differential fuzz (slow tier).

The fast tier pins the shape bookkeeping — padding must be semantics-free,
buckets and chunk plans deterministic, per-scenario slicing identical to
individual runs.  The slow tier drives a whole generated population
through ONE vmapped machine batch and requires bit-identical schedules
against a per-scenario golden loop.
"""
import numpy as np
import pytest

from repro.core import hts
from repro.core.hts import batch, workloads
from repro.core.hts.builder import Program
from repro.core.hts.policy import NO_QUOTA, SchedPolicy

#: one shared shape bucket for every compiled machine in this module —
#: population machines compile per (spec, max_prog, batch width), so the
#: tests reuse a single width/bucket to keep the fast tier fast.
MAX_PROG = 64
N_SMALL = 4


def _tiny(name, n_tasks, kernel="vector_dot", base=0x100):
    p = Program(name, region_base=base)
    frame = p.input(0x10, 4, "frame")
    prev = frame
    for i in range(n_tasks):
        prev = p.task(kernel, in_=prev, out=4, in_size=4, tid=i)
    return p


@pytest.fixture(scope="module")
def small_pop():
    return [_tiny(f"p{i}", 2 + i) for i in range(N_SMALL)]


# ---------------------------------------------------------------------------
# buckets, work estimates and chunk plans (pure shape bookkeeping)
# ---------------------------------------------------------------------------
def test_prog_bucket_ladder():
    assert batch.prog_bucket(0) == batch.MIN_BUCKET
    assert batch.prog_bucket(batch.MIN_BUCKET) == batch.MIN_BUCKET
    assert batch.prog_bucket(batch.MIN_BUCKET + 1) == 2 * batch.MIN_BUCKET
    assert batch.prog_bucket(100) == 128
    assert batch.prog_bucket(5, floor=4) == 8
    with pytest.raises(ValueError):
        batch.prog_bucket(5, floor=0)


def test_work_estimate_tracks_instruction_count(small_pop):
    ests = [batch.work_estimate(p) for p in small_pop]
    assert ests == sorted(ests) and ests[0] < ests[-1]
    # equals the decoded instruction count (the empirically best proxy)
    assert ests[0] == len(small_pop[0].build().instrs)


def test_plan_chunks_partitions_and_sorts(small_pop):
    progs = small_pop * 5                        # 20 scenarios
    plan = batch.plan_chunks(progs, max_chunk=8, min_chunk=2)
    flat = [i for ch in plan for i in ch]
    assert sorted(flat) == list(range(len(progs)))
    # ascending estimated work across the plan
    ests = [batch.work_estimate(progs[i]) for i in flat]
    assert ests == sorted(ests)
    # widths never exceed max_chunk and narrow toward the tail
    widths = [len(ch) for ch in plan]
    assert max(widths) <= 8
    assert widths[0] == 8 and widths == sorted(widths, reverse=True)
    with pytest.raises(ValueError):
        batch.plan_chunks(progs, max_chunk=4, min_chunk=8)


def test_plan_chunks_profile_overrides_static_estimate(small_pop):
    """profile= replaces the instruction-count proxy with measured step
    counts: a profile inverting the static order inverts the plan."""
    n = len(small_pop)
    profile = list(range(n, 0, -1))              # heaviest first by index
    plan = batch.plan_chunks(small_pop, max_chunk=2, min_chunk=1,
                             profile=profile)
    flat = [i for ch in plan for i in ch]
    assert flat == list(reversed(range(n)))      # sorted by profile, not len
    with pytest.raises(ValueError):
        batch.plan_chunks(small_pop, profile=profile[:-1])   # wrong length
    with pytest.raises(ValueError):
        batch.plan_chunks(small_pop, profile=[profile])      # not 1-D


def test_plan_chunks_profile_from_population_result(small_pop):
    """The intended loop: run once, re-chunk on the machine's measured
    per-lane while-loop trip counts (PopulationResult.steps)."""
    first = hts.run_many(small_pop, scheduler="hts_spec")
    steps = first.steps
    assert steps is not None and steps.shape == (len(small_pop),)
    assert (steps >= 1).all()
    # a result object is accepted directly (its .steps is the profile)
    plan = batch.plan_chunks(small_pop, max_chunk=2, min_chunk=1,
                             profile=first)
    flat = [i for ch in plan for i in ch]
    assert sorted(flat) == list(range(len(small_pop)))
    ordered = [int(steps[i]) for i in flat]
    assert ordered == sorted(ordered)            # measured-ascending plan


# ---------------------------------------------------------------------------
# packing
# ---------------------------------------------------------------------------
def test_pack_population_shapes_and_padding(small_pop):
    params = hts.HtsParams()
    pop = batch.pack_population(small_pop, params=params, n_fu=2,
                                max_prog=MAX_PROG)
    n = len(small_pop)
    assert len(pop) == n
    assert pop.ftab.shape[:2] == (n, MAX_PROG)
    assert pop.p_len.tolist() == [len(p.build().instrs) for p in small_pop]
    assert pop.mem.shape == (n, params.total_mem)
    assert pop.n_fu.shape == (n, 10) and (pop.n_fu == 2).all()
    # padding rows are zero (never fetched: pc >= p_len)
    for i in range(n):
        assert (pop.ftab[i, pop.p_len[i]:] == 0).all()
    # auto bucket picks the population's prog_bucket
    auto = batch.pack_population(small_pop)
    assert auto.max_prog == batch.prog_bucket(int(max(auto.p_len)))
    with pytest.raises(ValueError, match="max_prog"):
        batch.pack_population(small_pop, max_prog=4)


def test_pack_population_per_scenario_n_fu_and_policy(small_pop):
    fus = [1, 2, (1,) * 10, 4]
    pols = [None, SchedPolicy.of(weights={1: 3}), None,
            SchedPolicy.of(quotas={2: 1}, rs_caps={3: 2})]
    pop = batch.pack_population(small_pop, n_fu=fus, policy=pols,
                                max_prog=MAX_PROG)
    assert pop.n_fu[0].tolist() == [1] * 10
    assert pop.n_fu[3].tolist() == [4] * 10
    assert pop.prio[1][1] == 3 and pop.prio[0][1] == 0
    assert pop.quota[3][2] == 1 and pop.rs_cap[3][3] == 2
    assert pop.rs_cap[0][3] == NO_QUOTA
    assert pop.widest_fu == 4
    with pytest.raises(ValueError, match="n_fu"):
        batch.pack_population(small_pop, n_fu=[1, 2])
    with pytest.raises(ValueError, match="policies"):
        batch.pack_population(small_pop, policy=[None])


def test_padding_is_semantics_free(small_pop):
    """The same program, padded to two different buckets, schedules
    identically (padding rows are never fetched)."""
    a = hts.run(small_pop[0], n_fu=2, max_prog=32, max_fu_per_class=2)
    b = hts.run(small_pop[0], n_fu=2, max_prog=MAX_PROG, max_fu_per_class=2)
    assert a.cycles == b.cycles and a.schedule == b.schedule


# ---------------------------------------------------------------------------
# run_many and PopulationResult
# ---------------------------------------------------------------------------
def test_run_many_matches_individual_runs(small_pop):
    pr = hts.run_many(small_pop, n_fu=2, max_prog=MAX_PROG)
    assert len(pr) == N_SMALL and pr.all_halted
    for i, prog in enumerate(small_pop):
        solo = hts.run(prog, n_fu=2, max_prog=MAX_PROG,
                       max_fu_per_class=pr.max_fu_per_class)
        assert solo.cycles == int(pr.cycles[i])
        assert solo.schedule == pr[i].schedule       # per-scenario slicing
        assert pr[i].program == f"p{i}"
    # iteration yields the same Results; table renders
    assert [r.cycles for r in pr] == [int(c) for c in pr.cycles]
    assert "scenario" in pr.table()
    assert pr.scenarios_per_sec() > 0


def test_run_many_golden_backend_parity(small_pop):
    gr = hts.run_many(small_pop, n_fu=2, backend="golden")
    jr = hts.run_many(small_pop, n_fu=2, max_prog=MAX_PROG)
    assert gr.backend == "golden" and len(gr) == len(jr)
    assert [int(c) for c in gr.cycles] == [int(c) for c in jr.cycles]
    assert gr[0].schedule == jr[0].schedule
    with pytest.raises(ValueError, match="backend"):
        hts.run_many(small_pop, backend="nope")


def test_run_many_per_scenario_policies(small_pop):
    """One batched call, a different policy per lane — same results as
    per-scenario runs with those policies."""
    pols = [SchedPolicy(), SchedPolicy.of(weights={1: 8}),
            SchedPolicy.of(rs_caps={1: 1}), SchedPolicy.of(quotas={1: 1})]
    pr = hts.run_many(small_pop, n_fu=2, policy=pols, max_prog=MAX_PROG)
    for i, prog in enumerate(small_pop):
        solo = hts.run(prog, n_fu=2, policy=pols[i], max_prog=MAX_PROG,
                       max_fu_per_class=pr.max_fu_per_class)
        assert solo.schedule == pr[i].schedule, i


def test_sweep_population_mode(small_pop):
    sw = hts.sweep(small_pop[:2], n_fu=(1, 2), schedulers=("hts_spec",),
                   max_prog=MAX_PROG)
    assert sw.is_population and sw.programs == ("p0", "p1")
    assert sw.cycles["hts_spec"].shape == (2, 2)
    # more units never slows a scenario down
    assert (sw.cycles["hts_spec"][:, 0] >= sw.cycles["hts_spec"][:, 1]).all()
    assert "scenarios" in sw.table()


def test_compare_population_mode(small_pop):
    report = hts.compare(small_pop, schedulers=("hts_spec",),
                         max_prog=MAX_PROG)
    assert isinstance(report, hts.PopulationCompareReport)
    assert len(report) == N_SMALL and report.n_modes == 3
    assert report.cycles["hts_spec"].shape == (N_SMALL,)


def test_compare_population_raises_on_injected_divergence(small_pop):
    """A wrong golden row must surface as a MismatchError naming the
    scenario (guards the comparison itself, not just happy paths)."""
    import repro.core.hts.api as api
    real = api.run_many

    def crooked(programs, **kw):
        res = real(programs, **kw)
        if kw.get("backend") == "golden":
            object.__setattr__(res, "cycles", res.cycles + 1)
        return res

    api.run_many, saved = crooked, api.run_many
    try:
        with pytest.raises(hts.MismatchError, match="scenario 0"):
            api.compare_population(small_pop, schedulers=("hts_spec",),
                                   max_prog=MAX_PROG)
    finally:
        api.run_many = saved


# ---------------------------------------------------------------------------
# slow tier: one >= 32-scenario vmap batch, bit-identical to golden
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_population_differential_fuzz_one_batch():
    """>= 32 generated scenarios simulated in ONE vmap batch; schedules
    must be bit-identical to per-scenario golden runs (and to the
    no-event-skip machine), per scheduler."""
    (pop,) = workloads.generate_population(32, bucket=False,
                                           kernels=workloads.CHEAP_MIX,
                                           max_tasks=4)
    report = hts.compare(list(pop.programs), n_fu=2,
                         schedulers=("naive", "hts_spec"),
                         max_prog=pop.max_prog)
    assert len(report) == 32 and report.n_modes == 3


@pytest.mark.slow
def test_population_mixed_priority_differential_fuzz():
    """Mixed-priority population (weights/quotas/RS caps drawn per
    scenario) through the batched machine vs golden."""
    (pop,) = workloads.generate_population(16, bucket=False,
                                           kernels=workloads.CHEAP_MIX,
                                           max_tasks=4, mixed_priority=True)
    assert any(sc.policy is not None and sc.policy.rs_caps
               for sc in pop.scenarios), "no RS cap drawn in 16 scenarios"
    report = hts.compare(list(pop.programs), n_fu=2,
                         schedulers=("hts_spec",), max_prog=pop.max_prog)
    assert len(report) == 16


@pytest.mark.slow
def test_population_heterogeneous_differential_fuzz():
    """Heterogeneous population: per-scenario cost tables (mixed with
    uniform lanes and eft policies) ride the same vmap batch as the
    mixed-priority tables, golden = machine in every event-skip mode."""
    (pop,) = workloads.generate_population(16, bucket=False,
                                           kernels=workloads.CHEAP_MIX,
                                           max_tasks=4, mixed_priority=True,
                                           heterogeneous_fus=True)
    scs = pop.scenarios
    assert any(sc.fu_cost is not None for sc in scs)
    assert any(sc.policy and sc.policy.issue_mode == "eft" for sc in scs)
    report = hts.compare(list(pop.programs), n_fu=2,
                         fu_cost=[sc.fu_cost for sc in scs],
                         schedulers=("hts_spec",), max_prog=pop.max_prog)
    assert len(report) == 16
