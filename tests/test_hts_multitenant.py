"""Multi-tenant workload engine: N-way Program.merge isolation invariants,
per-pid schedule metrics + fairness, and the seeded differential fuzzer
(golden ≡ JAX machine, all scheduler cost models, event-skip on and off)."""
import numpy as np
import pytest

from repro.core import hts
from repro.core.hts import costs, golden, isa, programs, workloads
from repro.core.hts.builder import BuilderError, Program

#: acceptance floor: the differential fuzzer must clear ≥ 50 scenarios.
FUZZ_SEEDS = 50
FUZZ_SCHEDULERS = ("naive", "hts_nospec", "hts_spec")


def _chain(name, funcs, pid, base):
    p = Program(name, region_base=base)
    frame = p.input(0x10, 4, "frame")
    with p.process(pid):
        prev = frame
        for i, f in enumerate(funcs):
            prev = p.task(f, in_=prev, out=4, in_size=4, tid=i)
    return p


# ---------------------------------------------------------------------------
# N-way merge: isolation invariants
# ---------------------------------------------------------------------------
def test_merge_preserves_per_process_order_n_way():
    funcs = {1: ["fft_256", "vector_dot", "iir"],
             2: ["dct", "vector_max", "correlation", "vector_add"],
             3: ["real_fir", "complex_fir"],
             4: ["adaptive_fir", "iir", "dct"]}
    progs = [_chain(f"t{pid}", fs, pid, 0x100 + 0x100 * (pid - 1))
             for pid, fs in funcs.items()]
    merged = Program.merge(progs, require_distinct_pids=True).build()
    by_pid = {pid: [] for pid in funcs}
    for ins in merged.instrs:
        assert ins.op == isa.OP_TASK
        by_pid[ins.pid].append(costs.FUNC_NAMES[ins.acc])
    for pid, fs in funcs.items():
        assert by_pid[pid] == fs, f"pid {pid} program order torn"
    # dependencies stay within each process after OoO scheduling
    r = golden.run(merged.code, costs.costs_by_name("hts_spec"),
                   golden.HtsParams(n_fu=(2,) * 10))
    pid_of_uid = {t.uid: t.pid for t in r.tasks}
    for t in r.tasks:
        if t.dep_uid:
            assert pid_of_uid[t.dep_uid] == t.pid


def test_merge_region_disjointness():
    a = _chain("a", ["iir"], 1, 0x100)
    b = _chain("b", ["dct"], 2, 0x200)
    c_ok = _chain("c", ["vector_dot"], 3, 0x300)
    c_bad = _chain("c", ["vector_dot"], 3, 0x200)    # collides with b
    merged = Program.merge([a, b, c_ok])
    # every written reservation pair in the merge is disjoint
    spans = [(s, e) for (s, e, _, wr) in merged._reserved if wr]
    spans.sort()
    for (s1, e1), (s2, _) in zip(spans, spans[1:]):
        assert e1 <= s2
    with pytest.raises(BuilderError, match="overlaps"):
        Program.merge([a, b, c_bad])
    # the identical read-only input span is shared by all three tenants
    shared_inputs = [(s, e) for (s, e, _, wr) in merged._reserved if not wr]
    assert shared_inputs == [(0x10, 0x14)]


def test_merge_register_isolation():
    # the same Reg object spanning two programs is rejected
    a = Program("a", region_base=0x100)
    b = Program("b", region_base=0x200)
    r = a.reg("shared")
    a.mov(r, 1)
    b.mov(r, 2)
    with pytest.raises(BuilderError, match="disjoint register sets"):
        Program.merge([a, b])
    # combined register demand beyond the GPR bank fails at merge time
    progs = []
    for k in range(5):
        p = Program(f"p{k}", region_base=0x100 + 0x40 * k)
        for j in range(8):
            p.let(j, f"r{k}_{j}")
        progs.append(p)
    with pytest.raises(BuilderError, match="registers combined"):
        Program.merge(progs)                        # 40 > 31 available


def test_merge_rejects_conflicting_shared_input_images():
    def tenant(pid, base, init):
        p = Program(f"t{pid}", region_base=base)
        frame = p.input(0x10, 4, "frame").init(init)
        with p.process(pid):
            p.task("iir", in_=frame, out=4)
        return p

    # agreeing images on the shared span merge fine
    Program.merge([tenant(1, 0x100, [1, 2]), tenant(2, 0x200, [1, 2])])
    with pytest.raises(BuilderError, match="conflicting mem_init"):
        Program.merge([tenant(1, 0x100, [1, 2]), tenant(2, 0x200, [9, 9])])


def test_merge_requires_distinct_pids_when_asked():
    a = _chain("a", ["iir"], 1, 0x100)
    b = _chain("b", ["dct"], 1, 0x200)              # same pid as a
    Program.merge([a, b])                           # tolerated by default
    with pytest.raises(BuilderError, match="pid 1"):
        Program.merge([a, b], require_distinct_pids=True)


def test_interleave_is_two_way_merge():
    a = _chain("a", ["iir", "vector_dot"], 1, 0x100)
    b = _chain("b", ["dct"], 2, 0x200)
    via_merge = Program.merge([a, b]).build()
    via_interleave = _chain("a", ["iir", "vector_dot"], 1, 0x100).interleave(
        _chain("b", ["dct"], 2, 0x200)).build()
    assert np.array_equal(via_merge.code, via_interleave.code)


def test_shared_makespan_le_sum_of_solos_complementary():
    """Paper Fig-2 intuition: complementary mixes (audio FFT/FIR-heavy,
    image DCT-heavy) share the pool with shared ≤ serial makespan, and each
    tenant's in-shared makespan is no better than its solo run."""
    params = hts.HtsParams(mem_words=4096, tracker_entries=128)
    audio = programs.audio_straightline(2)           # pid 0
    image = programs.image_compression(6)            # pid 1
    third = programs.Bench.of(
        _chain("vec", ["vector_add", "vector_max", "vector_dot"] * 2, 2,
               0xC00))
    shared = programs.merge_benches([audio, image, third])
    rs = hts.run(shared, n_fu=2, params=params)
    solos = {pid: hts.run(b, n_fu=2, params=params)
             for pid, b in ((0, audio), (1, image), (2, third))}
    serial = sum(r.cycles for r in solos.values())
    assert rs.cycles <= serial
    fair = rs.fairness(solos)
    assert set(fair.slowdowns) == {0, 1, 2}
    for pid, s in fair.slowdowns.items():
        assert s >= 0.99, (pid, s)                  # sharing can't beat solo
    assert fair.max_slowdown == max(fair.slowdowns.values())


# ---------------------------------------------------------------------------
# per-pid schedule slices and fairness metrics
# ---------------------------------------------------------------------------
def test_per_pid_slices_and_makespan():
    sc = workloads.generate_scenario(7, n_tenants=4,
                                     kernels=workloads.CHEAP_MIX)
    r = hts.run(sc.merged, n_fu=2)
    assert r.pids == sc.pids
    slices = r.by_pid()
    assert sum(len(rows) for rows in slices.values()) == r.n_tasks
    for pid in sc.pids:
        assert r.schedule_for(pid) == slices[pid]
        assert all(row.pid == pid for row in slices[pid])
        mk = r.app_makespan(pid)
        assert 0 < mk <= r.cycles
    assert max(r.app_makespan(p) for p in sc.pids) <= r.cycles
    # golden backend reports identical pid tagging
    rg = hts.run(sc.merged, n_fu=2, backend="golden")
    assert rg.schedule == r.schedule


def test_fairness_against_solo_runs():
    sc = workloads.generate_scenario(11, n_tenants=3,
                                     kernels=workloads.CHEAP_MIX)
    shared = hts.run(sc.merged, n_fu=1)
    solos = workloads.solo_results(sc, n_fu=1)
    fair = shared.fairness(solos)
    assert set(fair.slowdowns) == set(sc.pids)
    for s in fair.slowdowns.values():
        assert s >= 0.99
    assert fair.max_slowdown >= fair.mean_slowdown >= 1.0 - 1e-9
    assert "slowdown" in fair.table()


# ---------------------------------------------------------------------------
# workload generator properties
# ---------------------------------------------------------------------------
def test_generator_is_seed_deterministic():
    a = workloads.generate_scenario(42)
    b = workloads.generate_scenario(42)
    assert a.n_tenants == b.n_tenants
    assert a.merged.asm == b.merged.asm
    assert a.merged.mem_init == b.merged.mem_init
    c = workloads.generate_scenario(43)
    assert (a.merged.asm != c.merged.asm or a.n_tenants != c.n_tenants)


def test_generator_respects_tenant_count_and_pids():
    for n in (2, 5, 8):
        sc = workloads.generate_scenario(3, n_tenants=n)
        assert sc.n_tenants == n
        assert sc.pids == tuple(range(1, n + 1))
        built = sc.merged.program.build()            # lowers within 31 GPRs
        task_pids = {i.pid for i in built.instrs if i.op == isa.OP_TASK}
        assert task_pids == set(sc.pids)             # every tenant emits work
    with pytest.raises(ValueError):
        workloads.generate_scenario(0, n_tenants=9)


# ---------------------------------------------------------------------------
# the differential fuzzer (acceptance: ≥ 50 scenarios, 3 schedulers,
# golden + jax event-skip on/off all schedule-identical)
# ---------------------------------------------------------------------------
def test_fuzz_differential_scenarios():
    passed = 0
    for seed in range(FUZZ_SEEDS):
        het = seed % 4 == 3     # quarter of seeds: cost tables + maybe eft
        sc = workloads.generate_scenario(seed, n_tenants=2 + seed % 3,
                                         kernels=workloads.CHEAP_MIX,
                                         max_tasks=4, heterogeneous_fus=het)
        report = hts.compare(sc.merged, schedulers=FUZZ_SCHEDULERS,
                             fu_cost=sc.fu_cost)
        assert report.schedulers == FUZZ_SCHEDULERS
        # scheduling sanity on every agreed result: OoO never loses to
        # naive — on UNIFORM units only.  With heterogeneous costs the
        # dominance can legitimately invert: naive serialises onto unit 0
        # while an overlapping schedule may place work on a slower unit.
        if sc.fu_cost is None:
            assert report.cycles("hts_nospec") <= report.cycles("naive")
            assert report.cycles("hts_spec") <= report.cycles("naive")
        passed += 1
    assert passed >= 50


@pytest.mark.slow
def test_fuzz_differential_heavy_mixes():
    """Slow tier: full Table-II mix (incl. 18k-cycle FFTs) and up to 8
    tenants, software scheduler included; a third of the seeds draw
    heterogeneous cost tables (and sometimes the eft arbiter)."""
    for seed in range(12):
        sc = workloads.generate_scenario(1000 + seed,
                                         kernels=workloads.FULL_MIX,
                                         heterogeneous_fus=seed % 3 == 0)
        hts.compare(sc.merged, fu_cost=sc.fu_cost,
                    schedulers=("naive", "software", "hts_nospec",
                                "hts_spec"))
