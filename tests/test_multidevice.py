"""Multi-device behaviours, each in a subprocess with a forced host-device
pool (the main test process must keep the default single device).

Every test here spawns a fresh interpreter that recompiles from scratch, so
the whole module lives in the CI slow tier (``pytest -m slow``)."""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(src: str, devices: int = 8, timeout: int = 560,
           env_extra: dict | None = None) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.update(env_extra or {})
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(src)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env, cwd=REPO)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_pipeline_executor_matches_sequential():
    """HTS-scheduled shard_map pipeline ≡ sequential layer application."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.sched.pipeline import run_pipeline

        mesh = jax.make_mesh((4,), ("stage",))
        D = 16
        def stage_fn(p, x):
            return jnp.tanh(x @ p["w"]) + p["b"]
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        params = {"w": jax.random.normal(k1, (4, D, D)) * 0.3,
                  "b": jax.random.normal(k2, (4, 1, D)) * 0.1}
        x = jax.random.normal(jax.random.PRNGKey(1), (6, 8, D))  # 6 microbatches

        got = run_pipeline(stage_fn, params, x, mesh=mesh, n_micro=6)
        want = x
        for s in range(4):
            want = stage_fn(jax.tree.map(lambda a: a[s:s+1], params)
                            if False else {"w": params["w"][s],
                                           "b": params["b"][s]}, want)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
        print("PIPELINE_OK")
    """)
    assert "PIPELINE_OK" in out


def test_pipeline_executor_differentiable():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.sched.pipeline import run_pipeline
        mesh = jax.make_mesh((4,), ("stage",))
        D = 8
        params = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, D, D)) * .3}
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 4, D))
        def stage_fn(p, h):
            return jnp.tanh(h @ p["w"])
        def loss_pipe(params):
            return jnp.sum(run_pipeline(stage_fn, params, x, mesh=mesh,
                                        n_micro=4) ** 2)
        def loss_seq(params):
            h = x
            for s in range(4):
                h = jnp.tanh(h @ params["w"][s])
            return jnp.sum(h ** 2)
        g1 = jax.grad(loss_pipe)(params)["w"]
        g2 = jax.grad(loss_seq)(params)["w"]
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                                   rtol=1e-4, atol=1e-4)
        print("GRAD_OK")
    """)
    assert "GRAD_OK" in out


def test_sharded_train_step_matches_single_device():
    """pjit'd train step on a (2,2,2) pod mesh ≡ single-device step."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models import registry
        from repro.runtime import train as train_rt
        from repro.sharding import rules as rules_lib
        from repro.data import pipeline as data_lib

        model = registry.build_smoke("qwen2-1.5b")
        dcfg = data_lib.DataConfig(vocab=model.cfg.vocab, seq_len=16,
                                   global_batch=4, seed=1)
        src = data_lib.make_source(dcfg)
        tcfg = train_rt.TrainConfig(warmup_steps=1, total_steps=4)
        state = train_rt.init_state(model, jax.random.PRNGKey(0))
        batch = src.batch(0)

        plain = jax.jit(train_rt.make_train_step(model, tcfg))
        s1, m1 = plain(state, batch)

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        rules = rules_lib.make_rules(mesh)
        step = train_rt.jit_train_step(model, mesh, rules, tcfg,
                                       jax.eval_shape(lambda: batch))
        s2, m2 = step(train_rt.init_state(model, jax.random.PRNGKey(0)),
                      batch)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-2, \\
            (float(m1["loss"]), float(m2["loss"]))
        d = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(s1["params"]),
                                jax.tree.leaves(s2["params"])))
        assert d < 2e-2, d
        print("SHARDED_OK", float(m1["loss"]), float(m2["loss"]))
    """)
    assert "SHARDED_OK" in out


def test_elastic_restore_across_meshes():
    """Save under a (4,) mesh, restore under (2,2) with different shardings."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import ckpt
        import tempfile

        d = tempfile.mkdtemp()
        mesh_a = jax.make_mesh((8,), ("data",))
        x = jnp.arange(64.0).reshape(8, 8)
        xs = jax.device_put(x, NamedSharding(mesh_a, P("data", None)))
        ckpt.save(d, 1, {"w": xs})

        mesh_b = jax.make_mesh((2, 4), ("data", "model"))
        target = NamedSharding(mesh_b, P("data", "model"))
        got, step = ckpt.restore(
            d, {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)},
            shardings={"w": target})
        assert got["w"].sharding == target
        np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(x))
        print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in out


def test_grad_compression_psum():
    """int8 compressed all-reduce ≈ exact mean; error feedback carries the
    residual."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.optim.grad_compress import compressed_psum
        if hasattr(jax, "shard_map"):
            shard_map = jax.shard_map
        else:
            from jax.experimental.shard_map import shard_map

        mesh = jax.make_mesh((8,), ("pod",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 64))

        def body(gl):
            mean, err = compressed_psum(gl[0], "pod")
            return mean[None], err[None]

        mean, err = shard_map(body, mesh=mesh, in_specs=P("pod"),
                              out_specs=P("pod"))(g)
        want = jnp.mean(g, axis=0)
        got = np.asarray(mean)[0]
        scale = float(jnp.max(jnp.abs(g))) / 127
        assert np.max(np.abs(got - np.asarray(want))) < 2 * scale
        np.testing.assert_allclose(np.asarray(err),
                                   np.asarray(g) - (np.asarray(g) - np.asarray(err)),
                                   rtol=1e-6)
        print("COMPRESS_OK")
    """)
    assert "COMPRESS_OK" in out


def test_sharded_run_many_matches_single_device():
    """run_many(devices=4) with lane padding (6 lanes over 4 devices) is
    lane-for-lane identical — cycles AND schedule tuples — to the
    single-device population machine, and compare_population(devices=2)
    verifies the sharded path against the golden oracle."""
    out = run_py("""
        import numpy as np
        from repro.core import hts
        from repro.core.hts import shard, workloads

        assert shard.device_count() == 4
        progs = [workloads.generate_scenario(s, n_tenants=2,
                                             kernels=workloads.CHEAP_MIX
                                             ).merged for s in range(6)]
        r0 = hts.run_many(progs, scheduler="hts_spec")
        r4 = hts.run_many(progs, scheduler="hts_spec", devices=4)
        assert len(r4) == 6                      # pad lanes dropped
        assert np.array_equal(r0.cycles, r4.cycles)
        for i in range(6):
            assert r0[i].schedule_tuple() == r4[i].schedule_tuple(), i
        hts.compare_population(progs[:4], schedulers=("hts_spec",),
                               devices=2)
        print("SHARD_OK", list(map(int, r4.cycles)))
    """, devices=4)
    assert "SHARD_OK" in out


def test_serve_sharded_matches_unsharded():
    """A ServeSpec(devices=2) server: same served results as devices=None,
    and zero recompiles after its buckets warm up."""
    out = run_py("""
        from repro.core import hts
        from repro.core.hts import workloads

        progs = [workloads.generate_scenario(s, n_tenants=2,
                                             kernels=workloads.CHEAP_MIX
                                             ).merged for s in range(8)]
        results = {}
        for devices in (None, 2):
            with hts.serve(max_batch=4, max_queue=32, deadline=99.0,
                           devices=devices,
                           clock=hts.ManualClock()) as srv:
                futs = [srv.submit(p) for p in progs]
                srv.drain()
                results[devices] = [f.result(timeout=0).cycles
                                    for f in futs]
                if devices == 2:
                    warm = srv.cache_info()
                    fs = [srv.submit(p) for p in progs[:4]]
                    fs += [srv.submit(p) for p in progs[4:]]
                    assert all(f.done() for f in fs)
                    after = srv.cache_info()
                    assert after.jit_compiles == warm.jit_compiles, \\
                        (warm, after)
        assert results[None] == results[2], results
        print("SERVE_SHARD_OK", results[2])
    """, devices=2)
    assert "SERVE_SHARD_OK" in out


def test_serve_sliced_sharded_matches_run():
    """Slice-and-refill compaction under devices=2: sharded lanes are
    harvested and refilled mid-flight, every served result matches a
    direct hts.run, and the sliced runner pair (carry init + slice) adds
    zero compiles after its first launch."""
    out = run_py("""
        from repro.core import hts
        from repro.core.hts import workloads

        progs = [workloads.generate_scenario(60 + s, n_tenants=2,
                                             kernels=workloads.CHEAP_MIX
                                             ).merged for s in range(10)]
        ref = [hts.run(p, scheduler="hts_spec", n_fu=2).cycles
               for p in progs]
        with hts.serve(max_batch=4, max_queue=32, deadline=99.0,
                       devices=2, slice_steps=64,
                       clock=hts.ManualClock()) as srv:
            futs = [srv.submit(p) for p in progs]
            srv.drain()                 # one sliced launch, 10 reqs thru 4
            got = [f.result(timeout=0).cycles for f in futs]
            warm = srv.cache_info()
            fs = [srv.submit(p) for p in progs[:5]]
            srv.drain()
            assert all(f.done() for f in fs)
            after = srv.cache_info()
            assert after.jit_compiles == warm.jit_compiles, (warm, after)
            occ = srv.report().per_bucket
            assert all(b.occupancy > 0.5 for b in occ.values()), occ
        assert got == ref, (got, ref)
        print("SERVE_SLICED_SHARD_OK")
    """, devices=2)
    assert "SERVE_SLICED_SHARD_OK" in out


@pytest.mark.slow
def test_mini_dryrun_multipod():
    """The dry-run path end-to-end on a shrunken (2,2,2) multi-pod mesh with
    smoke-size archs — proves the pod axis shards for every family."""
    out = run_py("""
        import os
        import jax
        import repro.launch.mesh as mesh_mod
        mesh_mod.make_production_mesh = lambda multi_pod=False: (
            jax.make_mesh((2, 2, 2), ("pod", "data", "model")) if multi_pod
            else jax.make_mesh((4, 2), ("data", "model")))
        from repro.launch import dryrun
        dryrun.make_production_mesh = mesh_mod.make_production_mesh
        import dataclasses
        from repro.configs import registry as creg
        from repro.configs.base import SHAPES, ShapeConfig
        # shrink shapes for speed
        SHAPES["train_4k"] = ShapeConfig("train_4k", 64, 8, "train")
        SHAPES["decode_32k"] = ShapeConfig("decode_32k", 64, 8, "decode")
        orig_get = creg.get_config
        creg.get_config = lambda a: orig_get(a).smoke()
        import repro.launch.dryrun as dr
        dr.get_config = creg.get_config
        for arch in ("qwen2-1.5b", "olmoe-1b-7b", "rwkv6-3b", "zamba2-7b",
                     "whisper-base", "paligemma-3b"):
            for shape in ("train_4k", "decode_32k"):
                rec = dr.run_cell(arch, shape, "multi", "", probe=False)
                assert rec["status"] == "OK", (arch, shape, rec.get("error"),
                                               rec.get("traceback"))
                print("OK", arch, shape)
        print("MINI_DRYRUN_OK")
    """, devices=8, timeout=560)
    assert "MINI_DRYRUN_OK" in out
