"""Multi-application sharing + design-parameter ablation behaviours."""
import numpy as np

from repro.core.hts import assembler, costs, machine, programs
from repro.core.hts.golden import HtsParams

PARAMS = HtsParams(mem_words=4096, tracker_entries=128)


def _cycles(bench, n_fu=2, params=None, cost_obj=None):
    code = assembler.assemble(bench.asm)
    out = machine.simulate(code, cost_obj or costs.costs_by_name("hts_spec"),
                           params or PARAMS, n_fu=np.array([n_fu] * 10),
                           mem_init=bench.mem_init, effects=bench.effects)
    assert out["halted"], bench.name
    return int(out["cycles"]), out


def test_multiapp_sharing_beats_serial():
    """The paper's abstract claim: multiple applications share one
    accelerator pool.  Shared makespan must beat serial execution and sit
    near max(app_a, app_b) for complementary mixes."""
    audio = programs.audio_straightline(2)
    image = programs.image_compression(40)
    shared = programs.merge_benches([audio, image])
    ca, _ = _cycles(audio)
    ci, _ = _cycles(image)
    cs, out = _cycles(shared)
    assert cs < ca + ci                     # sharing beats serial
    assert cs < 1.25 * max(ca, ci)          # near-perfect overlap
    # both apps' tasks actually ran (pid-tagged interleaved stream)
    n_tasks = int(out["n_tasks"])
    la = len(audio.asm.splitlines())
    li = len(image.asm.splitlines())
    assert n_tasks == la + li


def test_multiapp_isolation():
    """Disjoint region spaces ⇒ no cross-app dependencies: every image task's
    dependency (if any) is another image task."""
    audio = programs.audio_straightline(2)
    image = programs.image_compression(8)
    shared = programs.merge_benches([audio, image])
    code = assembler.assemble(shared.asm)
    from repro.core.hts import golden
    r = golden.run(code, costs.costs_by_name("hts_spec"), PARAMS)
    from repro.core.hts import isa
    instrs = isa.decode_program(code)
    pid_of_uid = {}
    uid = 1
    for ins in instrs:
        if ins.op == isa.OP_TASK:
            pid_of_uid[uid] = ins.pid
            uid += 1
    for t in r.tasks:
        if t.dep_uid:
            assert pid_of_uid[t.dep_uid] == pid_of_uid[t.uid], \
                "cross-application dependency leaked"


def test_rs_window_size_sensitivity():
    """Shrinking the reservation-station window (instruction window) costs
    cycles; the paper calls it a design-time parameter."""
    import dataclasses
    from repro.core.hts.programs import audio_compression
    bench = audio_compression(8, time_domain=False)
    small, _ = _cycles(bench, n_fu=4,
                       params=dataclasses.replace(PARAMS, rs_entries=4))
    large, _ = _cycles(bench, n_fu=4,
                       params=dataclasses.replace(PARAMS, rs_entries=64))
    assert small > large * 1.5


def test_issue_width_insensitive_at_task_granularity():
    """Finding: issue width 1 suffices — task latencies (10³ cycles) dwarf
    scheduler cycles, which is exactly the paper's feasibility argument for
    hardware task scheduling."""
    import dataclasses
    from repro.core.hts.programs import audio_compression
    bench = audio_compression(8, time_domain=False)
    base = costs.hts_costs(True)
    w1, _ = _cycles(bench, n_fu=4,
                    cost_obj=dataclasses.replace(base, issue_width=1))
    w8, _ = _cycles(bench, n_fu=4,
                    cost_obj=dataclasses.replace(base, issue_width=8))
    assert abs(w1 - w8) / w8 < 0.01
