"""HTS-as-runtime tests: task-graph scheduling, pipeline schedules, serving,
speculative decoding (TM-rollback analog)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.sched import pipeline, serving, specdecode, taskgraph
from repro.models import registry


# ---------------------------------------------------------------------------
# taskgraph
# ---------------------------------------------------------------------------
def test_ooo_beats_inorder_on_independent_tasks():
    tasks = [taskgraph.Task(i, "fu", 10.0) for i in range(8)]
    ooo = taskgraph.schedule(tasks, {"fu": 4}, "ooo")
    naive = taskgraph.schedule(tasks, {"fu": 4}, "inorder")
    assert ooo.makespan == 20.0          # 8 tasks / 4 units × 10
    assert naive.makespan == 80.0        # one at a time
    assert naive.makespan / ooo.makespan == 4.0


def test_dependency_chain_respected():
    tasks = [taskgraph.Task(0, "a", 5.0),
             taskgraph.Task(1, "b", 3.0, deps=(0,)),
             taskgraph.Task(2, "a", 2.0)]
    s = taskgraph.schedule(tasks, {"a": 1, "b": 1}, "ooo")
    by = {p.uid: p for p in s.placements}
    assert by[1].start >= by[0].end      # RAW respected
    assert by[2].start == by[0].end      # OoO: unit reused immediately
    assert s.makespan == 8.0


def test_deadlock_detection():
    tasks = [taskgraph.Task(0, "a", 1.0, deps=(1,)),
             taskgraph.Task(1, "a", 1.0, deps=(0,))]
    with pytest.raises(ValueError, match="deadlock"):
        taskgraph.schedule(tasks, {"a": 1})


# ---------------------------------------------------------------------------
# pipeline schedules
# ---------------------------------------------------------------------------
def test_pipeline_schedule_is_dense_wavefront():
    n_micro, n_stages = 8, 4
    s = pipeline.pipeline_schedule(n_micro, n_stages, "ooo")
    assert s.makespan == n_micro + n_stages - 1       # perfect fill
    naive = pipeline.pipeline_schedule(n_micro, n_stages, "inorder")
    assert naive.makespan == n_micro * n_stages       # full serialization
    assert pipeline.bubble_ratio(s, n_stages) < pipeline.bubble_ratio(
        naive, n_stages)


def test_pipeline_schedule_matches_wavefront_issue_order():
    """HTS-OoO must place task (m, s) at start time m + s (the wavefront
    executed by run_pipeline)."""
    s = pipeline.pipeline_schedule(6, 3, "ooo")
    for p in s.placements:
        _, m, stage = p.tag
        assert p.start == m + stage


def test_run_pipeline_matches_sequential():
    devs = jax.devices()
    if len(devs) < 2:
        pytest.skip("needs >1 device (covered by test_multidevice.py "
                    "subprocess run)")


def test_pipeline_backward_schedule_valid():
    s = pipeline.pipeline_schedule(4, 3, "ooo", backward=True)
    by = {p.tag: p for p in s.placements}
    for m in range(4):
        for st in range(3):
            assert by[("B", m, st)].start >= by[("F", m, st)].end
            if st < 2:
                assert by[("B", m, st)].start >= by[("B", m, st + 1)].end


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------
def _serve_model():
    model = registry.build_smoke("qwen2-1.5b")
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def test_server_completes_all_requests():
    model, params = _serve_model()
    srv = serving.Server(model, params, n_slots=4, max_len=64)
    rng = np.random.default_rng(0)
    for r in range(10):
        prompt = rng.integers(0, model.cfg.vocab, rng.integers(2, 6)).tolist()
        srv.submit(serving.Request(r, prompt, max_new=5))
    stats = srv.run()
    assert stats.completed == 10
    assert all(r is None for r in srv.slot_req)


def test_continuous_beats_naive_batching():
    """OoO slot admission (ASR-style) sustains higher utilization than
    drain-everything naive batching — the paper's claim at serving level."""
    rng = np.random.default_rng(1)
    reqs = [(rng.integers(0, 100, 3).tolist(), int(rng.integers(2, 12)))
            for _ in range(12)]

    def run(policy):
        model, params = _serve_model()
        srv = serving.Server(model, params, n_slots=4, max_len=64,
                             policy=policy)
        for i, (p, m) in enumerate(reqs):
            srv.submit(serving.Request(i, list(p), m))
        return srv.run()

    ooo = run("ooo")
    naive = run("naive")
    assert ooo.completed == naive.completed == 12
    assert ooo.steps < naive.steps
    assert ooo.utilization(4) > naive.utilization(4)


def test_server_output_matches_unbatched_decode():
    """A slot's output must equal standalone greedy decoding even when lanes
    are at different depths (per-lane positions make continuous batching
    exact)."""
    model, params = _serve_model()
    prompt = [5, 17, 42]
    want = specdecode.greedy_decode(model, params,
                                    np.asarray([prompt]), 6, 64)[0]
    srv = serving.Server(model, params, n_slots=3, max_len=64)
    # stagger with another request so lanes sit at different positions
    srv.submit(serving.Request(0, [9, 3], 3))
    srv.step()
    r1 = serving.Request(1, prompt, 6)
    srv.submit(r1)
    srv.run()
    np.testing.assert_array_equal(np.asarray(r1.out), want)


# ---------------------------------------------------------------------------
# speculative decoding
# ---------------------------------------------------------------------------
def test_speculative_equals_greedy():
    """Spec-decode output must equal plain greedy decoding of the target —
    speculation changes the schedule, never the result (paper §IV-C3:
    functional correctness of the TM mechanism)."""
    target = registry.build_smoke("qwen2-1.5b")
    t_params = target.init(jax.random.PRNGKey(0))
    # draft = same weights, fewer layers (self-speculation style)
    draft = registry.build_smoke("qwen2-1.5b")
    d_params = jax.tree.map(lambda x: x, t_params)
    d_params["layers"] = jax.tree.map(lambda x: x[:1], t_params["layers"])
    import dataclasses
    d_cfg = dataclasses.replace(draft.cfg, n_layers=1)
    draft = registry.build(d_cfg)

    prompt = np.asarray([[3, 1, 4, 1, 5]])
    n_new = 12
    want = specdecode.greedy_decode(target, t_params, prompt, n_new, 64)
    got, stats = specdecode.speculative_decode(
        target, t_params, draft, d_params, prompt, n_new, k=4, max_len=64)
    np.testing.assert_array_equal(got, want)
    assert stats.chunks > 0
    assert 0.0 <= stats.acceptance <= 1.0


def test_speculative_perfect_draft_accepts_all():
    """Draft == target ⇒ every proposal accepted (correct-speculation path)."""
    target = registry.build_smoke("qwen2-1.5b")
    t_params = target.init(jax.random.PRNGKey(0))
    prompt = np.asarray([[7, 7, 7]])
    want = specdecode.greedy_decode(target, t_params, prompt, 8, 64)
    got, stats = specdecode.speculative_decode(
        target, t_params, target, t_params, prompt, 8, k=4, max_len=64)
    np.testing.assert_array_equal(got, want)
    assert stats.acceptance == 1.0
