"""Per-kernel allclose sweeps: DSP Pallas kernels vs ref.py oracles."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def r(*shape, dtype=np.float32):
    return jnp.asarray(RNG.standard_normal(shape).astype(dtype))


BATCHES = [1, 7, 256, 300]


@pytest.mark.parametrize("b", BATCHES)
@pytest.mark.parametrize("n,k", [(40, 8), (64, 16), (128, 5)])
def test_real_fir(b, n, k):
    x, h = r(b, n), r(k)
    np.testing.assert_allclose(ops.real_fir(x, h), ref.real_fir(x, h),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b", [1, 64])
@pytest.mark.parametrize("n,k", [(40, 8), (96, 12)])
def test_complex_fir(b, n, k):
    x, h = r(b, n, 2), r(k, 2)
    np.testing.assert_allclose(ops.complex_fir(x, h), ref.complex_fir(x, h),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b", [1, 32])
@pytest.mark.parametrize("n,k", [(40, 8), (64, 4)])
def test_adaptive_fir(b, n, k):
    x, d = r(b, n), r(b, n)
    got = ops.adaptive_fir(x, d, 0.01, k)
    want = ref.adaptive_fir(x, d, 0.01, k)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b", [1, 33])
@pytest.mark.parametrize("n", [40, 80])
def test_iir(b, n):
    x = r(b, n)
    bc = jnp.asarray([0.2, 0.3, 0.1], jnp.float32)
    ac = jnp.asarray([1.0, -0.4, 0.05], jnp.float32)
    np.testing.assert_allclose(ops.iir(x, bc, ac), ref.iir(x, bc, ac),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b", BATCHES)
@pytest.mark.parametrize("n", [40, 128])
def test_vector_ops(b, n):
    x, y = r(b, n), r(b, n)
    np.testing.assert_allclose(ops.vector_dot(x, y), ref.vector_dot(x, y),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(ops.vector_add(x, y), ref.vector_add(x, y))
    np.testing.assert_allclose(ops.vector_max(x), ref.vector_max(x))


@pytest.mark.parametrize("b", [1, 17])
@pytest.mark.parametrize("lag", [4, 10])
def test_correlation(b, lag):
    x, y = r(b, 40), r(b, 40)
    np.testing.assert_allclose(ops.correlation(x, y, lag),
                               ref.correlation(x, y, lag),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b", [1, 9, 128])
def test_fft_256(b):
    x = r(b, 256, 2)
    np.testing.assert_allclose(ops.fft_256(x), ref.fft_256(x),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("b", [1, 65])
@pytest.mark.parametrize("n", [64, 128])
def test_dct(b, n):
    x = r(b, n)
    np.testing.assert_allclose(ops.dct(x), ref.dct(x), rtol=1e-4, atol=1e-4)


def test_fft_matches_numpy():
    x = r(4, 256, 2)
    z = np.asarray(x[..., 0]) + 1j * np.asarray(x[..., 1])
    want = np.fft.fft(z, axis=-1)
    got = np.asarray(ops.fft_256(x))
    np.testing.assert_allclose(got[..., 0], want.real, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(got[..., 1], want.imag, rtol=1e-3, atol=1e-3)
