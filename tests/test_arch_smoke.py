"""Per-architecture smoke tests: reduced configs, one train step + one decode
step on CPU, asserting output shapes and finiteness (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, applicable_shapes
from repro.configs.registry import all_archs, get_config
from repro.models import registry

B, S = 2, 32


def _batch(model, key):
    cfg = model.cfg
    if cfg.family == "audio":
        return {
            "frames": jax.random.normal(key, (B, S, cfg.d_model)),
            "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
            "labels": jax.random.randint(key, (B, S), 0, cfg.vocab),
        }
    if cfg.family == "vlm":
        text = S - cfg.prefix_len
        return {
            "prefix_embeds": jax.random.normal(key, (B, cfg.prefix_len,
                                                     cfg.d_model)),
            "tokens": jax.random.randint(key, (B, text), 0, cfg.vocab),
            "labels": jax.random.randint(key, (B, text), 0, cfg.vocab),
        }
    return {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab),
    }


@pytest.mark.parametrize("arch", all_archs())
def test_train_step_smoke(arch):
    model = registry.build_smoke(arch)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(model, jax.random.PRNGKey(1))

    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: model.train_loss(p, batch)))(params)
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)), f"{arch}: grad norm not finite"
    assert float(gnorm) > 0, f"{arch}: zero gradients"


@pytest.mark.parametrize("arch", all_archs())
def test_decode_step_smoke(arch):
    model = registry.build_smoke(arch)
    cfg = model.cfg
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(B, S)
    tokens = jnp.zeros((B, 1), jnp.int32)
    if cfg.family == "audio":
        # populate cross K/V as prefill would (zeros suffice for smoke)
        pass
    logits, cache2 = jax.jit(model.decode_step)(params, cache, tokens,
                                                jnp.int32(3))
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch
    # cache must be structurally unchanged
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", all_archs())
def test_decode_matches_forward_tail(arch, monkeypatch):
    """Greedy next-token logits from decode_step must match the sequence
    forward pass at the same position (cache correctness).

    Runs in fp32 compute: this test checks *logic* equivalence; bf16
    accumulation-order noise between the chunked kernels and the stepwise
    decode path is expected and not what is under test.
    """
    from repro.models import layers as Lmod
    monkeypatch.setattr(Lmod, "COMPUTE_DTYPE", jnp.float32)
    if arch == "whisper-base":
        pytest.skip("enc-dec decode requires populated cross-KV (covered in "
                    "test_runtime_serving)")
    cfg = get_config(arch).smoke()
    if cfg.moe:
        # capacity dropping is a train-time approximation; decode never drops,
        # so compare at a no-drop capacity factor
        import dataclasses
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    model = registry.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    T_ = 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, T_), 0, cfg.vocab)

    # sequence forward logits
    from repro.models import transformer, rwkv6, zamba2
    if cfg.family in ("dense", "moe"):
        seq_logits, _ = transformer.forward(params, cfg, toks)
    elif cfg.family == "vlm":
        pe = jnp.zeros((B, cfg.prefix_len, cfg.d_model))
        seq_logits, _ = transformer.forward(params, cfg, toks, pe)
        seq_logits = seq_logits[:, cfg.prefix_len:]
    elif cfg.family == "ssm":
        seq_logits, _ = rwkv6.forward(params, cfg, toks)
    else:
        seq_logits, _ = zamba2.forward(params, cfg, toks)

    if cfg.family == "vlm":
        pytest.skip("vlm decode over prefix exercised separately")

    # token-by-token decode
    cache = model.init_cache(B, T_)
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(T_):
        lg, cache = step(params, toks[:, t:t + 1], cache, jnp.int32(t)) \
            if False else step(params, cache, toks[:, t:t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(seq_logits, np.float32),
                               rtol=1e-3, atol=1e-3)


def test_applicable_shapes_cells():
    """40-cell bookkeeping: every arch × shape is either runnable or a
    documented skip; long_500k only runs for sub-quadratic archs."""
    cells = 0
    runs = 0
    for arch in all_archs():
        cfg = get_config(arch)
        app = applicable_shapes(cfg)
        assert set(app) == set(SHAPES)
        cells += len(app)
        runs += sum(1 for ok, _ in app.values() if ok)
        if cfg.family in ("ssm", "hybrid"):
            assert app["long_500k"][0]
        else:
            assert not app["long_500k"][0] and app["long_500k"][1]
    assert cells == 40
    assert runs == 32


def test_full_configs_exact():
    """Exact published dims (assignment block)."""
    c = get_config("yi-34b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (60, 7168, 56, 8, 20480, 64000)
    c = get_config("command-r-plus-104b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (64, 12288, 96, 8, 33792, 256000)
    c = get_config("phi3-mini-3.8b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (32, 3072, 32, 32, 8192, 32064)
    c = get_config("qwen2-1.5b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (28, 1536, 12, 2, 8960, 151936)
    assert c.qkv_bias
    c = get_config("rwkv6-3b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == (32, 2560, 8960, 65536)
    c = get_config("olmoe-1b-7b")
    assert (c.moe.num_experts, c.moe.top_k) == (64, 8)
    c = get_config("qwen2-moe-a2.7b")
    assert (c.moe.num_experts, c.moe.top_k, c.moe.num_shared) == (60, 4, 4)
    c = get_config("paligemma-3b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (18, 2048, 8, 1, 16384, 257216)
    c = get_config("zamba2-7b")
    assert (c.n_layers, c.d_model, c.ssm.d_state) == (81, 3584, 64)
    c = get_config("whisper-base")
    assert (c.n_layers, c.enc_layers, c.d_model, c.vocab) == (6, 6, 512, 51865)
