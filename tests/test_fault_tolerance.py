"""Fault tolerance: checkpoint/restart exactness, async save, retention,
restart-exact data pipeline, failure-injected training, straggler watchdog."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.data import pipeline as data_lib
from repro.models import registry
from repro.optim.adamw import AdamWConfig
from repro.runtime import train as train_rt


def _setup(tmp_path, steps=12, ckpt_every=4):
    model = registry.build_smoke("qwen2-1.5b")
    dcfg = data_lib.DataConfig(vocab=model.cfg.vocab, seq_len=16,
                               global_batch=2, seed=7)
    source = data_lib.make_source(dcfg)
    tcfg = train_rt.TrainConfig(optimizer=AdamWConfig(lr=1e-3),
                                warmup_steps=2, total_steps=steps,
                                ckpt_every=ckpt_every, max_restarts=5)
    step_fn = jax.jit(train_rt.make_train_step(model, tcfg))
    init_fn = lambda: train_rt.init_state(model, jax.random.PRNGKey(0))
    return model, source, step_fn, tcfg, init_fn


def _losses(loop):
    return [h["loss"] for h in loop.history]


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nest": {"b": jnp.ones((4,), jnp.bfloat16),
                     "step": jnp.int32(7)}}
    ckpt.save(str(tmp_path), 3, tree)
    assert ckpt.latest_step(str(tmp_path)) == 3
    template = jax.eval_shape(lambda: tree)
    got, step = ckpt.restore(str(tmp_path), template)
    assert step == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_latest(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, tree, keep=2)
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000004", "step_00000005"]
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 0, {"x": jnp.zeros((2, 2))})
    with pytest.raises(ValueError, match="shape mismatch"):
        ckpt.restore(str(tmp_path),
                     {"x": jax.ShapeDtypeStruct((3, 2), jnp.float32)})


def test_data_pipeline_restart_exact():
    dcfg = data_lib.DataConfig(vocab=100, seq_len=8, global_batch=4, seed=3)
    src = data_lib.make_source(dcfg)
    b1 = src.batch(17)
    b2 = data_lib.make_source(dcfg).batch(17)      # fresh instance, same step
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    hs = src.batch(17, host_slice=slice(2, 4))
    np.testing.assert_array_equal(hs["tokens"], b1["tokens"][2:4])


def test_training_resumes_exactly_after_failure(tmp_path):
    """Kill training mid-run; the restarted run's loss trajectory must be
    bit-identical to an uninterrupted run (checkpoint + deterministic data)."""
    steps = 12
    # uninterrupted reference
    model, source, step_fn, tcfg, init_fn = _setup(tmp_path / "ref", steps)
    ref_loop = train_rt.TrainLoop(model, source, step_fn, tcfg,
                                  str(tmp_path / "ref"), init_fn)
    ref_loop.run(steps)
    ref = _losses(ref_loop)

    # failure-injected run: RuntimeError at step 6, once
    fired = {"done": False}

    def injector(step):
        if step == 6 and not fired["done"]:
            fired["done"] = True
            raise RuntimeError("injected node failure")

    model, source, step_fn, tcfg, init_fn = _setup(tmp_path / "ft", steps)
    loop = train_rt.TrainLoop(model, source, step_fn, tcfg,
                              str(tmp_path / "ft"), init_fn,
                              failure_injector=injector)
    loop.run(steps)
    assert loop.restarts == 1
    got = {h["step"]: h["loss"] for h in loop.history}
    for i, loss in enumerate(ref):
        assert got[i] == pytest.approx(loss, abs=0.0), f"step {i} diverged"


def test_too_many_failures_raises(tmp_path):
    model, source, step_fn, tcfg, init_fn = _setup(tmp_path, steps=8)

    def injector(step):
        raise RuntimeError("permanently broken")

    loop = train_rt.TrainLoop(model, source, step_fn, tcfg, str(tmp_path),
                              init_fn, failure_injector=injector)
    with pytest.raises(RuntimeError, match="permanently broken"):
        loop.run(8)


def test_async_checkpointer_equivalent(tmp_path):
    tree = {"w": jnp.arange(10.0)}
    saver = ckpt.AsyncCheckpointer(str(tmp_path))
    saver.save(5, tree)
    saver.wait()
    got, step = ckpt.restore(str(tmp_path), jax.eval_shape(lambda: tree))
    assert step == 5
    np.testing.assert_array_equal(np.asarray(got["w"]), np.arange(10.0))


def test_straggler_watchdog(tmp_path, monkeypatch):
    model, source, step_fn, tcfg, init_fn = _setup(tmp_path, steps=12)
    loop = train_rt.TrainLoop(model, source, step_fn, tcfg, str(tmp_path),
                              init_fn)
    times = iter([0.1] * 10 + [5.0] + [0.1] * 10)   # one slow step
    fake = {"t": 0.0}

    def fake_mono():
        return fake["t"]

    orig_watch = loop._watch

    def patched_watch(step, dt):
        dt = next(times, 0.1)
        orig_watch(step, dt)

    loop._watch = patched_watch
    loop.run(12)
    assert loop.stragglers == [10]
