"""Roofline machinery: HLO collective parsing, term formulas, and a
hand-countable compiled example."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline import analysis, hlo_collectives


def test_collective_parsing_synthetic_text():
    hlo = """
  %ag = bf16[16,128]{1,0} all-gather(bf16[1,128] %x), dimensions={0}
  %ar = f32[512]{0} all-reduce(f32[512] %y), to_apply=%add
  %rs = f32[32]{0} reduce-scatter(f32[256] %z), dimensions={0}
  %cp = bf16[8,8]{1,0} collective-permute(bf16[8,8] %w)
  %a2a = f32[4,64]{1,0} all-to-all(f32[4,64] %v), dimensions={0}
  %tup = (f32[128]{0}, f32[64]{0}) all-reduce(f32[128] %p, f32[64] %q)
  %notacoll = f32[9]{0} add(f32[9] %a, f32[9] %b)
"""
    got = hlo_collectives.collective_bytes_per_device(hlo)
    assert got["per_op"]["all-gather"] == 16 * 128 * 2
    assert got["per_op"]["all-reduce"] == 512 * 4 + 128 * 4 + 64 * 4
    assert got["per_op"]["reduce-scatter"] == 32 * 4
    assert got["per_op"]["collective-permute"] == 8 * 8 * 2
    assert got["per_op"]["all-to-all"] == 4 * 64 * 4
    assert got["counts"]["all-reduce"] == 2


def test_async_start_done_counted_once():
    hlo = """
  %ags = bf16[64]{0} all-gather-start(bf16[8] %x)
  %agd = bf16[64]{0} all-gather-done(bf16[64] %ags)
"""
    got = hlo_collectives.collective_bytes_per_device(hlo)
    assert got["counts"]["all-gather"] == 1
    assert got["per_op"]["all-gather"] == 64 * 2


def test_roofline_terms_and_bottleneck():
    r = analysis.Roofline(
        arch="x", shape="train_4k", mesh="single", chips=256,
        flops_global=256 * analysis.PEAK_FLOPS,          # exactly 1s compute
        bytes_global=256 * analysis.HBM_BW * 0.5,        # 0.5s memory
        collective_global=256 * analysis.LINK_BW * 0.25,  # 0.25s collective
        collective_per_op={}, model_flops=128 * analysis.PEAK_FLOPS)
    assert r.t_compute == 1.0
    assert r.t_memory == 0.5
    assert r.t_collective == 0.25
    assert r.bottleneck == "compute"
    assert r.useful_flops_ratio == 0.5
    assert 0.5 < r.roofline_fraction < 0.6


def test_compiled_flops_match_hand_count():
    """cost_analysis on a plain matmul: flops must equal 2·M·N·K (per device
    scaled by chips reproduces the global count)."""
    M = K = N = 256
    fn = jax.jit(lambda a, b: a @ b)
    c = fn.lower(jax.ShapeDtypeStruct((M, K), jnp.float32),
                 jax.ShapeDtypeStruct((K, N), jnp.float32)).compile()
    cost = c.cost_analysis()
    if isinstance(cost, (list, tuple)):     # older jax: one dict per program
        cost = cost[0]
    cost = dict(cost)
    assert abs(cost["flops"] - 2 * M * N * K) / (2 * M * N * K) < 0.01


def test_model_flops_formula():
    assert analysis.model_flops(1e9, 1000, "train") == 6e12
    assert analysis.model_flops(1e9, 1000, "serve") == 2e12
    assert analysis.model_flops(1e9, 1000, "train", active_ratio=0.25) == 1.5e12
