"""MoE dispatch invariants (hypothesis), sharding-rules behaviour, and the
whisper serving path with populated cross-KV."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import ArchConfig, MoEConfig
from repro.models import registry, transformer, whisper
from repro.sharding import rules as rules_lib


def _moe_cfg(E=8, k=2, cf=1.25, shared=0):
    return ArchConfig(
        name="moe-test", family="moe", n_layers=1, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=64, vocab=64, d_head=16,
        moe=MoEConfig(num_experts=E, top_k=k, d_expert=64,
                      num_shared=shared, d_shared=64 if shared else 0,
                      capacity_factor=cf))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([2, 4, 8]),
       st.sampled_from([1, 2]))
def test_moe_outputs_finite_and_capacity_bounded(seed, E, k):
    cfg = _moe_cfg(E=E, k=min(k, E))
    tmpl = transformer.moe_template(cfg)
    params = registry.L.init_params(jax.random.PRNGKey(seed % 2**31), tmpl)
    x = jax.random.normal(jax.random.PRNGKey(seed % 1000), (2, 8, 32),
                          jnp.float32)
    y, aux = transformer.moe_apply(params, cfg, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y, np.float32)).all()
    assert float(aux) >= 0.99   # balance loss ≥ 1 at optimum (≈E·(1/E)·... )


def test_moe_no_drop_equals_dense_mixture():
    """With capacity ≥ tokens, MoE output = Σ gate_e · expert_e(x) exactly."""
    cfg = _moe_cfg(E=4, k=2, cf=100.0)
    tmpl = transformer.moe_template(cfg)
    params = registry.L.init_params(jax.random.PRNGKey(0), tmpl)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 6, 32), jnp.float32)
    y, _ = transformer.moe_apply(params, cfg, x)

    # dense reference
    import repro.models.layers as L
    xf = x.reshape(-1, 32)
    E = transformer.padded_experts(4)
    scores = (xf @ L.cast(params["router"])).astype(jnp.float32)
    scores = jnp.where(jnp.arange(E)[None] >= 4, -1e30, scores)
    probs = jax.nn.softmax(scores, -1)
    gates, topi = jax.lax.top_k(probs, 2)
    gates = gates / gates.sum(-1, keepdims=True)
    want = jnp.zeros_like(xf)
    for e in range(4):
        h = jax.nn.silu(xf @ L.cast(params["w_gate"][e])) * \
            (xf @ L.cast(params["w_up"][e]))
        ye = h @ L.cast(params["w_down"][e])
        w = ((topi == e) * gates).sum(-1)[:, None].astype(ye.dtype)
        want = want + w * ye
    np.testing.assert_allclose(np.asarray(y.reshape(-1, 32), np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_moe_padded_experts_receive_no_tokens():
    cfg = _moe_cfg(E=6, k=2, cf=2.0)     # pads 6 → 16
    assert transformer.padded_experts(6) == 16
    tmpl = transformer.moe_template(cfg)
    params = registry.L.init_params(jax.random.PRNGKey(0), tmpl)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    y, _ = transformer.moe_apply(params, cfg, x)
    assert np.isfinite(np.asarray(y, np.float32)).all()


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------
def test_rules_divisibility_fallback():
    mesh = jax.make_mesh((1,), ("model",))
    r = rules_lib.make_rules(mesh)
    # kv_heads=8 divisible by model=1 → sharded spec with axis present
    spec = r.spec_for((8, 128), ("kv_heads", None))
    assert spec == jax.sharding.PartitionSpec("model", None)


def test_rules_drop_records():
    mesh = jax.make_mesh((1,), ("data",))

    class FakeMesh:
        axis_names = ("model",)
        shape = {"model": 16}

    r = rules_lib.Rules(dict(rules_lib.DEFAULT_RULES), FakeMesh())
    spec = r.spec_for((8, 4), ("kv_heads", None))   # 8 % 16 != 0 → dropped
    assert spec == jax.sharding.PartitionSpec(None, None)
    assert any(d[0] == "kv_heads" for d in r.dropped)
    del mesh


def test_constraint_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = rules_lib.constraint(x, ("batch", None))
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# whisper decode with populated cross-KV
# ---------------------------------------------------------------------------
def test_whisper_decode_with_cross_kv():
    model = registry.build_smoke("whisper-base")
    cfg = model.cfg
    params = model.init(jax.random.PRNGKey(0))
    B, Tenc, Tdec = 2, 12, 8
    frames = jax.random.normal(jax.random.PRNGKey(1), (B, Tenc, cfg.d_model))
    enc = whisper.encode(params, cfg, frames)

    # populate cross K/V from encoder states (prefill-side computation)
    cache = model.init_cache(B, max(Tenc, Tdec))
    import repro.models.layers as L
    xks, xvs = [], []
    for layer in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[layer], params["dec_layers"])
        k = L.linear(enc, lp["xattn"]["wk"]).reshape(
            B, Tenc, cfg.n_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
        v = L.linear(enc, lp["xattn"]["wv"]).reshape(
            B, Tenc, cfg.n_kv_heads, cfg.head_dim).transpose(0, 2, 1, 3)
        pad = cache["xk"].shape[3] - Tenc
        xks.append(jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))))
        xvs.append(jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))))
    cache["xk"] = jnp.stack(xks).astype(cache["xk"].dtype)
    cache["xv"] = jnp.stack(xvs).astype(cache["xv"].dtype)

    toks = jax.random.randint(jax.random.PRNGKey(2), (B, Tdec), 0, cfg.vocab)
    # NOTE: decode attends to the full (padded) cross K/V; the reference
    # sequence pass attends to Tenc only — pad rows contribute ~0 via V=0 but
    # softmax mass differs, so compare decode against itself for stability and
    # the seq pass for argmax agreement.
    seq_logits = whisper.decode_seq(params, cfg, toks, enc)
    cache2 = cache
    outs = []
    step = jax.jit(model.decode_step)
    for t in range(Tdec):
        lg, cache2 = step(params, cache2, toks[:, t:t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    assert np.isfinite(np.asarray(dec_logits, np.float32)).all()
    agree = (jnp.argmax(dec_logits, -1) == jnp.argmax(seq_logits, -1))
    assert float(agree.mean()) > 0.7


def test_chunk_step_matches_decode_steps():
    """chunk_step(k tokens) ≡ k sequential decode_steps (spec-decode verify)."""
    model = registry.build_smoke("qwen2-1.5b")
    cfg = model.cfg
    params = model.init(jax.random.PRNGKey(0))
    B, T = 1, 6
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, T), 0, cfg.vocab)
    c1 = model.init_cache(B, 16)
    lg_chunk, c1 = transformer.chunk_step(params, cfg, c1, toks, jnp.int32(0))
    c2 = model.init_cache(B, 16)
    outs = []
    for t in range(T):
        lg, c2 = model.decode_step(params, c2, toks[:, t:t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    np.testing.assert_allclose(
        np.asarray(lg_chunk[0], np.float32),
        np.asarray(jnp.stack(outs, 0)[:, 0], np.float32) if False
        else np.asarray(jnp.stack(outs, axis=0)[:, 0, :], np.float32),
        rtol=3e-2, atol=3e-2)


# ---------------------------------------------------------------------------
# §Perf optimized paths ≡ baseline (flags)
# ---------------------------------------------------------------------------
def test_moe_grouped_equals_baseline_fp32(monkeypatch):
    import repro.models.layers as L
    monkeypatch.setattr(L, "COMPUTE_DTYPE", jnp.float32)
    from repro.runtime import flags as fl
    cfg = _moe_cfg(E=8, k=2, cf=16.0)
    tmpl = transformer.moe_template(cfg)
    params = registry.L.init_params(jax.random.PRNGKey(0), tmpl)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 8, 32), jnp.float32)
    base, a1 = transformer.moe_apply(params, cfg, x)
    with fl.use_flags(moe_grouped=True):
        opt, a2 = transformer.moe_apply(params, cfg, x)
    np.testing.assert_allclose(np.asarray(base), np.asarray(opt),
                               rtol=1e-5, atol=1e-5)
    assert float(a1) == pytest.approx(float(a2), rel=1e-5)


def test_decode_gqa_packed_equals_baseline():
    from repro.runtime import flags as fl
    model = registry.build_smoke("qwen2-1.5b")
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(2, 16)
    tok = jnp.ones((2, 1), jnp.int32)
    for pos in (jnp.int32(3), jnp.asarray([3, 7], jnp.int32)):
        lg1, _ = model.decode_step(params, cache, tok, pos)
        with fl.use_flags(decode_gqa_packed=True):
            lg2, _ = model.decode_step(params, cache, tok, pos)
        np.testing.assert_array_equal(np.asarray(lg1), np.asarray(lg2))


def test_decode_kv_int8_close_to_baseline():
    """int8 KV cache: greedy decode logits within quantization tolerance of
    the bf16 cache; cache leaves actually int8."""
    from repro.runtime import flags as fl
    model = registry.build_smoke("qwen2-1.5b")
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 6), 0,
                              model.cfg.vocab)
    # baseline rollout
    cache = model.init_cache(2, 16)
    base = []
    for t in range(6):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1],
                                      jnp.int32(t))
        base.append(lg[:, 0])
    with fl.use_flags(decode_kv_int8=True, decode_gqa_packed=True):
        qmodel = registry.build(model.cfg)
        qcache = qmodel.init_cache(2, 16)
        assert qcache["k"].dtype == jnp.int8
        assert set(qcache) == {"k", "v", "k_s", "v_s"}
        got = []
        for t in range(6):
            lg, qcache = qmodel.decode_step(params, qcache,
                                            toks[:, t:t + 1], jnp.int32(t))
            got.append(lg[:, 0])
    b = np.asarray(jnp.stack(base), np.float32)
    g = np.asarray(jnp.stack(got), np.float32)
    # int8 quantization error bound: relative error ≲ 1/127 per contraction
    np.testing.assert_allclose(g, b, rtol=0.15, atol=0.25)
    # argmax agreement (greedy behavior preserved)
    assert (b.argmax(-1) == g.argmax(-1)).mean() > 0.9
