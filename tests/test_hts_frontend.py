"""Per-tenant frontends (core/hts/frontend.py): stream building + jump
relocation, arbitration fairness (round-robin and weighted), rs_caps as
per-stream backpressure (the head-of-line fix the rs_admission study
motivated), single-stream degradation (bit-identical to the merged
model), arrival offsets, per-stream frontend metrics, and the
multi-frontend differential fuzz (golden ≡ JAX machine, event-skip on
and off, singly and as one batched population)."""
import numpy as np
import pytest

from repro.core import hts
from repro.core.hts import frontend, golden, machine, workloads
from repro.core.hts.builder import BuilderError, Program
from repro.core.hts.costs import costs_by_name
from repro.core.hts.policy import SchedPolicy

#: acceptance floor for the multi-frontend differential fuzz (fast tier).
FRONTEND_FUZZ_SEEDS = 25


# ---------------------------------------------------------------------------
# builders
# ---------------------------------------------------------------------------
def _chain(pid, base, n=4, func="dct"):
    p = Program(f"t{pid}", region_base=base)
    frame = p.input(0x10, 4, "frame")
    with p.process(pid):
        prev = frame
        for i in range(n):
            prev = p.task(func, in_=prev, out=4, in_size=4, tid=i)
    return p


def _flood(pid, base, n=8, func="dct"):
    p = Program(f"g{pid}", region_base=base)
    frame = p.input(0x10, 4, "frame")
    with p.process(pid):
        for i in range(n):
            p.task(func, in_=frame, out=4, tid=i & 0xF)
    return p


def _loopy(pid, base, taken):
    """Loop + mem-branch tenant: exercises lend/jump relocation + spec."""
    p = Program(f"l{pid}", region_base=base)
    frame = p.input(0x10, 4, "frame")
    with p.process(pid):
        w = p.walker(stride=8, count=2, name=f"w{pid}")
        with p.loop(2):
            p.task("vector_add", in_=frame, out=w, out_size=4, tid=1)
            w.advance()
        cond = p.region(1, name=f"c{pid}")
        cond.init(9 if taken else 1)
        br = p.branch(on=cond, cond=">=", thr=5, kind="mem")
        with br.not_taken():
            p.task("vector_dot", in_=frame, out=4, tid=2)
        with br.taken():
            p.task("vector_max", in_=frame, out=4, tid=3)
    return p


# ---------------------------------------------------------------------------
# stream building
# ---------------------------------------------------------------------------
def test_build_frontends_boundaries_and_relocation():
    mp = hts.build_frontends([_loopy(1, 0x100, True), _loopy(2, 0x200, False)])
    (s1, s2) = mp.streams
    assert (s1.start, s1.pid) == (0, 1) and s2.pid == 2
    assert s1.end == s2.start and len(mp.code) == s2.end
    # the two streams are the same shape; absolute jump targets must be
    # relocated into stream 2's range
    from repro.core.hts import isa
    ops = isa.decode_program(mp.code)
    jumps = [(i, o.a) for i, o in enumerate(ops) if o.op == isa.OP_JUMP]
    assert len(jumps) == 2
    (i1, a1), (i2, a2) = jumps
    assert s1.start <= a1 <= s1.end and s2.start <= a2 <= s2.end
    assert a2 - a1 == s2.start - s1.start


def test_merge_frontends_keyword_and_validation():
    ts = [_chain(1, 0x100), _chain(2, 0x200)]
    mp = Program.merge(ts, require_distinct_pids=True, frontends=True,
                       arrivals=[0, 9], priorities={1: 4}, fe_mode="weighted")
    assert isinstance(mp, frontend.MultiProgram)
    assert mp.streams.arrivals == (0, 9)
    assert mp.policy.fe_mode == "weighted"
    # weighted mode lowers pid weights into the stream table
    assert list(mp.streams.table(mp.policy)[:, 3]) == [4, 0]
    # rr mode (default) zeroes the weight column even with weights set
    assert list(mp.streams.table(SchedPolicy.of(weights={1: 4}))[:, 3]) == [0, 0]
    with pytest.raises(BuilderError):
        Program.merge(ts, arrivals=[0, 9])          # needs frontends=True
    with pytest.raises(BuilderError):
        Program.merge(ts, frontends=True, arrivals=[0])   # length mismatch
    # isolation checks still run (same region base = overlap)
    with pytest.raises(BuilderError):
        Program.merge([_chain(1, 0x100), _chain(2, 0x100)], frontends=True)


# ---------------------------------------------------------------------------
# arbitration fairness
# ---------------------------------------------------------------------------
def test_round_robin_alternates_streams():
    mp = hts.build_frontends([_flood(1, 0x100, 4), _flood(2, 0x200, 4),
                              _flood(3, 0x300, 4)])
    r = hts.run(mp, n_fu=1)
    # with three always-eligible streams, dispatch cycles interleave
    # 1,2,3,1,2,3,... — every consecutive dispatch is a different stream
    order = [row.pid for row in sorted(r.schedule, key=lambda t: t.dispatch)]
    assert order[:9] == [1, 2, 3, 1, 2, 3, 1, 2, 3]


def test_weighted_frontend_prefers_high_weight_stream():
    ts = [_flood(1, 0x100, 6), _flood(2, 0x200, 6)]
    pol_rr = SchedPolicy.of(weights={2: 8})
    pol_w = SchedPolicy.of(weights={2: 8}, fe_mode="weighted")
    mp = hts.build_frontends(ts)
    rr = hts.run(mp, n_fu=1, policy=pol_rr)
    w = hts.run(mp, n_fu=1, policy=pol_w)
    # round-robin alternates regardless of weights...
    assert [t.pid for t in sorted(rr.schedule,
                                  key=lambda t: t.dispatch)][:4] == [1, 2, 1, 2]
    # ...weighted mode dispatches ALL of pid 2 before pid 1 is granted
    # once (pid 2's stream is always eligible and heavier)
    worder = [t.pid for t in sorted(w.schedule, key=lambda t: t.dispatch)]
    assert worder[:7] == [2] * 6 + [1]
    # weighted frontends cut the heavy tenant's dispatch-stall cycles
    assert w.dispatch_stall_cycles(2) < rr.dispatch_stall_cycles(2)


def test_fe_mode_validation():
    with pytest.raises(ValueError):
        SchedPolicy.of(fe_mode="fifo")
    with pytest.raises(ValueError):
        SchedPolicy.of(fe_mode="weighted").merge_with(SchedPolicy.of())


# ---------------------------------------------------------------------------
# rs_caps become per-stream backpressure (the head-of-line fix)
# ---------------------------------------------------------------------------
from benchmarks.priority import _max_rs_occupancy as _rs_occupancy  # noqa: E402
# (the shared RS-residency metric — same definition the benchmarks report)


def test_rs_cap_backpressure_bounds_flood_and_spares_late_tenant():
    """The invariant the rs_admission study measured as impossible in the
    merged model: the capped flood's RS occupancy is bounded by the cap
    AND the late tenant is unharmed (its makespan does not regress)."""
    arrive = 24
    hi = _chain(1, 0x100, 6)
    floods = [_flood(p, 0x200 + 0x80 * (p - 2), 10) for p in (2, 3)]
    cap = 3

    def build(rs_caps):
        return Program.merge([hi] + floods, require_distinct_pids=True,
                             frontends=True, arrivals=[arrive, 0, 0],
                             priorities={1: 8}, rs_caps=rs_caps)

    uncapped = hts.run(build(None), n_fu=2)
    capped = hts.run(build({2: cap, 3: cap}), n_fu=2)
    # flood occupancy provably bounded
    assert max(_rs_occupancy(capped, p) for p in (2, 3)) <= cap
    assert max(_rs_occupancy(uncapped, p) for p in (2, 3)) > cap
    # the late tenant is NOT harmed by the caps (merged model: 1.5 -> 2.5x)
    assert capped.app_makespan(1) <= uncapped.app_makespan(1)
    # and the caps stall only the flood streams, never the hi stream
    assert capped.dispatch_stall_cycles(1) <= uncapped.dispatch_stall_cycles(1)
    # aggregate throughput is preserved (work-conserving arbiter)
    assert capped.cycles <= uncapped.cycles * 1.1


# ---------------------------------------------------------------------------
# single-stream degradation: bit-identical to the merged model
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("scheduler", ("naive", "hts_spec"))
def test_single_stream_degrades_to_merged_model(scheduler):
    """A one-stream MultiProgram and the plain program must produce
    bit-identical schedules and cycle counts on BOTH backends (and the
    machine's default no-streams path equals the explicit one-stream
    table)."""
    prog = _loopy(1, 0x100, True)
    mp = hts.build_frontends([prog], "one")
    for backend in ("golden", "jax"):
        a = hts.run(prog, scheduler=scheduler, backend=backend, n_fu=2)
        b = hts.run(mp, scheduler=scheduler, backend=backend, n_fu=2)
        assert a.cycles == b.cycles
        assert a.schedule_tuple() == b.schedule_tuple()
        assert a.stall_cycles == b.stall_cycles
        assert tuple(a.fe_stall) == tuple(b.fe_stall)


def test_merged_multitenant_unchanged_by_frontend_machinery():
    """The historical merged (round-robin spliced) model is untouched:
    a generated scenario's merged program still verifies golden == machine
    and its Result carries the single-stream fe_stall."""
    sc = workloads.generate_scenario(7, kernels=workloads.CHEAP_MIX)
    hts.compare(sc.merged, schedulers=("hts_spec",))
    r = hts.run(sc.merged, n_fu=2)
    assert r.streams is None and len(r.fe_stall) == 1


# ---------------------------------------------------------------------------
# arrivals
# ---------------------------------------------------------------------------
def test_arrival_offset_delays_dispatch():
    mp = hts.build_frontends([_chain(1, 0x100), _chain(2, 0x200)],
                             arrivals=[0, 77])
    r = hts.run(mp, n_fu=2)
    first = {pid: min(t.dispatch for t in rows)
             for pid, rows in r.by_pid().items()}
    assert first[1] == 0
    assert first[2] == 77          # granted the cycle its CPU arrives
    assert r.streams.arrival_of(2) == 77


def test_generated_arrivals_leave_programs_unchanged():
    """arrivals=True draws offsets AFTER program generation: same-seed
    tenant programs and the merged stream are unchanged."""
    for seed in (3, 19):
        plain = workloads.generate_scenario(seed)
        with_fe = workloads.generate_scenario(seed, frontends=True,
                                              arrivals=True)
        assert plain.merged.asm == with_fe.merged.asm
        assert [t.asm for t in plain.tenants] == \
            [t.asm for t in with_fe.tenants]
        assert with_fe.multi is not None
        assert len(with_fe.arrivals) == len(with_fe.pids)
        assert with_fe.multi.streams.arrivals == with_fe.arrivals
        # and the draws are seed-deterministic
        again = workloads.generate_scenario(seed, frontends=True,
                                            arrivals=True)
        assert again.arrivals == with_fe.arrivals


# ---------------------------------------------------------------------------
# per-stream frontend metrics
# ---------------------------------------------------------------------------
def test_fe_stall_exact_golden_vs_machine_both_modes():
    mp = hts.build_frontends(
        [_loopy(1, 0x100, True), _flood(2, 0x200, 6), _chain(3, 0x300)],
        arrivals=[0, 5, 13])
    tab = mp.streams.table()
    p = golden.HtsParams()
    for sched in ("naive", "hts_spec"):
        g = golden.run(mp.code, costs_by_name(sched), p, mp.mem_init,
                       mp.effects, streams=tab)
        for skip in (True, False):
            m = machine.simulate(mp.code, costs_by_name(sched), p,
                                 mem_init=mp.mem_init, effects=mp.effects,
                                 event_skip=skip, streams=tab)
            assert list(g.fe_stall) == list(np.asarray(m["fe_stall"])), \
                (sched, skip)
            assert g.schedule_tuple() == machine.schedule_tuple(m)


def test_frontend_metrics_and_fairness_report():
    mp = hts.build_frontends([_chain(1, 0x100), _flood(2, 0x200, 8)],
                             arrivals=[40, 0], priorities={1: 8})
    shared = hts.run(mp, n_fu=2)
    # time-to-first-issue is measured from the stream's arrival
    assert shared.time_to_first_issue(1) == \
        min(t.issue for t in shared.schedule_for(1)) - 40
    assert shared.rs_occupancy_at_dispatch(2) > \
        shared.rs_occupancy_at_dispatch(1)   # flood queues behind itself
    stalls = shared.dispatch_stall_cycles()
    assert set(stalls) == {1, 2} and all(v >= 0 for v in stalls.values())
    solo = {1: hts.run(_chain(1, 0x100), n_fu=2),
            2: hts.run(_flood(2, 0x200, 8), n_fu=2)}
    rep = shared.fairness(solo)
    assert set(rep.frontend) == {1, 2}
    for pid in (1, 2):
        m = rep.frontend[pid]
        assert m["dispatch_stall_cycles"] == shared.dispatch_stall_cycles(pid)
        assert m["time_to_first_issue"] == shared.time_to_first_issue(pid)


# ---------------------------------------------------------------------------
# packing: multi-frontend populations ride the same buckets
# ---------------------------------------------------------------------------
def test_population_packs_mixed_single_and_multi():
    mp = hts.build_frontends([_chain(1, 0x100), _chain(2, 0x200)],
                             arrivals=[0, 30])
    single = _chain(1, 0x100)
    pop = hts.pack_population([mp, single, mp.with_arrivals([0, 99])])
    assert pop.streams.shape[1] == 2         # padded to the widest set
    assert pop.stream_table(1).shape[0] == 1  # the merged scenario
    res = hts.run_many(pop)
    assert res.all_halted
    # per-scenario results slice their own stream sets back out
    assert res[0].streams is not None and res[1].streams is None
    assert len(res[0].fe_stall) == 2 and len(res[1].fe_stall) == 1
    # and per-scenario runs agree with standalone execution
    for i, prog in enumerate([mp, single]):
        assert res[i].cycles == hts.run(prog).cycles


# ---------------------------------------------------------------------------
# differential fuzz: the multi-frontend dispatch model, both backends
# ---------------------------------------------------------------------------
def _fuzz(seeds, kernels):
    for seed in seeds:
        sc = workloads.generate_scenario(
            seed, kernels=kernels, frontends=True,
            arrivals=(seed % 2 == 0), mixed_priority=(seed % 3 == 0))
        hts.compare(sc.multi, schedulers=("hts_spec",))


def test_multifrontend_differential_fuzz():
    """FRONTEND_FUZZ_SEEDS seeded multi-frontend scenarios (staggered
    arrivals on even seeds, drawn policies on every third) verify
    golden == machine across event-skip modes."""
    _fuzz(range(FRONTEND_FUZZ_SEEDS), workloads.CHEAP_MIX)


@pytest.mark.slow
def test_multifrontend_differential_fuzz_full_mix():
    """Slow tier: the same fuzz over the FULL_MIX kernel pool (adds the
    long-latency FFT/FIR heavyweights — deeper event-skip windows)."""
    _fuzz(range(100, 100 + FRONTEND_FUZZ_SEEDS), workloads.FULL_MIX)


def test_multifrontend_population_differential():
    """A whole multi-frontend population through run_many, one batched
    machine call per mode, checked scenario-by-scenario against golden."""
    scs = [workloads.generate_scenario(s, kernels=workloads.CHEAP_MIX,
                                       frontends=True, arrivals=True)
           for s in range(4)]
    rep = hts.compare([sc.multi for sc in scs], schedulers=("hts_spec",))
    assert len(rep) == 4
