"""Priority/quota-aware scheduling: SchedPolicy semantics, starvation
recovery (weighted arbiter provably reorders issue), quota-mask invariants
(per-pid per-class in-flight cap never exceeded), policy threading through
builder/api, and the mixed-priority differential fuzzer (golden ≡ JAX
machine, event-skip on and off)."""
import numpy as np
import pytest

from repro.core import hts
from repro.core.hts import workloads
from repro.core.hts.builder import BuilderError, Program
from repro.core.hts.policy import NO_QUOTA, NUM_PIDS, PRIO_CAP, SchedPolicy

#: acceptance floor for the mixed-priority differential fuzz (fast tier).
PRIORITY_FUZZ_SEEDS = 25
FUZZ_SCHEDULERS = ("naive", "hts_nospec", "hts_spec")


# ---------------------------------------------------------------------------
# scenario builders (the benchmark's starvation shape, sized for tests)
# ---------------------------------------------------------------------------
def _hi_chain(chain=8, delay=8, func="dct"):
    """Latency-sensitive tenant: RAW chain, arriving after `delay` nops."""
    p = Program("hi", region_base=0x100)
    frame = p.input(0x10, 4, "frame")
    for _ in range(delay):
        p.nop()
    with p.process(1):
        prev = frame
        for i in range(chain):
            prev = p.task(func, in_=prev, out=4, in_size=4, tid=i)
    return p


def _greedy(pid, tasks=8, func="dct"):
    """Best-effort flood: independent same-class tasks."""
    p = Program(f"greedy{pid}", region_base=0x200 + 0x100 * (pid - 2))
    frame = p.input(0x10, 4, "frame")
    with p.process(pid):
        for i in range(tasks):
            p.task(func, in_=frame, out=4, tid=i & 0xF)
    return p


def _contended(n_greedy=2, *, priorities=None, quotas=None, **hi_kw):
    return Program.merge(
        [_hi_chain(**hi_kw)] + [_greedy(2 + k) for k in range(n_greedy)],
        "contended", require_distinct_pids=True,
        priorities=priorities, quotas=quotas)


def _max_inflight(result, pid, func):
    """Peak concurrently-executing tasks of (pid, func) in a schedule."""
    iv = [(r.issue, r.complete) for r in result.schedule
          if r.pid == pid and r.func == func
          and not r.aborted and r.issue >= 0 and r.complete >= 0]
    points = sorted({t for s, e in iv for t in (s, e)})
    return max((sum(1 for s, e in iv if s <= t < e) for t in points),
               default=0)


# ---------------------------------------------------------------------------
# SchedPolicy semantics
# ---------------------------------------------------------------------------
def test_policy_tables_and_defaults():
    pol = SchedPolicy.of(weights={1: 8, 3: 2}, quotas={2: 1})
    assert pol.weight_of(1) == 8 and pol.weight_of(2) == 0
    assert pol.quota_of(2) == 1 and pol.quota_of(1) == NO_QUOTA
    w = pol.weight_array()
    q = pol.quota_array()
    assert w.shape == (NUM_PIDS,) and q.shape == (NUM_PIDS,)
    assert w[1] == 8 and w[0] == 0 and q[2] == 1 and q[0] == NO_QUOTA
    assert not pol.is_default and SchedPolicy().is_default
    # hashable + content-equal (usable inside frozen HtsParams)
    assert pol == SchedPolicy.of(weights={3: 2, 1: 8}, quotas={2: 1})
    assert hash(pol) == hash(SchedPolicy.of(weights={3: 2, 1: 8},
                                            quotas={2: 1}))
    with pytest.raises(ValueError):
        SchedPolicy.of(weights={16: 1})          # pid outside 4-bit field
    with pytest.raises(ValueError):
        SchedPolicy.of(quotas={1: 0})            # quota must be >= 1
    with pytest.raises(ValueError):
        SchedPolicy.of(weights={1: PRIO_CAP + 1})  # beyond arbiter precision


def test_policy_issue_key_orders_priority_then_age():
    pol = SchedPolicy.of(weights={1: 4, 2: 1})
    # higher weight beats lower weight regardless of age
    assert pol.issue_key(1, age=100) < pol.issue_key(2, age=0)
    # age breaks ties within a priority class
    assert pol.issue_key(2, age=3) < pol.issue_key(2, age=4)
    assert pol.issue_key(2, age=3) < pol.issue_key(0, age=0)  # w=1 > w=0


def test_policy_merge_with_unions_and_rejects_conflicts():
    a = SchedPolicy.of(weights={1: 8})
    b = SchedPolicy.of(weights={2: 2}, quotas={3: 1})
    u = a.merge_with(b)
    assert u.weight_of(1) == 8 and u.weight_of(2) == 2 and u.quota_of(3) == 1
    with pytest.raises(ValueError, match="conflicting weight"):
        a.merge_with(SchedPolicy.of(weights={1: 2}))


# ---------------------------------------------------------------------------
# starvation recovery: weighted arbiter provably reorders issue
# ---------------------------------------------------------------------------
def test_priority_weighting_recovers_starved_tenant():
    """The late-arriving chain is starved by age order; a priority weight
    strictly drops its makespan to within 15% of its solo run, while the
    shared run's total cycles regress < 5% (here: don't regress at all)."""
    solo = hts.run(_hi_chain(), n_fu=1)
    base = hts.run(_contended(2), n_fu=1)
    prio = hts.run(_contended(2, priorities={1: 8}), n_fu=1)
    solo_mk = solo.app_makespan(1)
    assert base.app_makespan(1) > 2 * solo_mk          # provably starved
    assert prio.app_makespan(1) < base.app_makespan(1)  # strictly reordered
    assert prio.app_makespan(1) <= 1.15 * solo_mk       # QoS recovered
    assert prio.cycles <= 1.05 * base.cycles            # work-conserving

    # the high-priority pid's tasks overtake older greedy tasks in issue
    # order — impossible under pure age arbitration
    hi_rows = prio.schedule_for(1)
    greedy_uid_after = [r for r in prio.schedule
                        if r.pid != 1 and r.uid < hi_rows[-1].uid
                        and r.issue > hi_rows[-1].issue]
    assert greedy_uid_after, "no older greedy task issued after the chain"


def test_priority_is_runtime_data_same_compiled_machine():
    """Distinct policies reuse one compiled machine (weights are traced)."""
    from repro.core.hts import machine
    machine._compiled.cache_clear()
    prog = _contended(2)
    hts.run(prog, n_fu=1, policy=SchedPolicy.of(weights={1: 1}))
    misses_after_first = machine._compiled.cache_info().misses
    hts.run(prog, n_fu=1, policy=SchedPolicy.of(weights={1: 7}, quotas={2: 1}))
    assert machine._compiled.cache_info().misses == misses_after_first


# ---------------------------------------------------------------------------
# quota-mask invariants
# ---------------------------------------------------------------------------
DCT = 8     # costs.FUNC_IDS["dct"]


@pytest.mark.parametrize("cap", [1, 2])
def test_quota_never_exceeded(cap):
    """Per-pid per-class in-flight units never exceed the cap, on both
    backends, while uncapped pids are free to exceed it."""
    prog = _contended(2, quotas={2: cap, 3: cap})
    for backend in ("jax", "golden"):
        r = hts.run(prog, n_fu=4, backend=backend)
        for pid in (2, 3):
            assert _max_inflight(r, pid, DCT) <= cap, (backend, pid)
    # sanity: the cap binds — without it the flood takes > cap units
    r0 = hts.run(_contended(2), n_fu=4)
    assert max(_max_inflight(r0, pid, DCT) for pid in (2, 3)) > 1


def test_quota_reserves_capacity_when_caps_below_pool():
    """Sum of greedy caps < n_fu leaves a unit for the uncapped tenant:
    its chain runs at (near-)solo speed with no priority weight at all."""
    solo = hts.run(_hi_chain(), n_fu=3)
    base = hts.run(_contended(2), n_fu=3)
    quot = hts.run(_contended(2, quotas={2: 1, 3: 1}), n_fu=3)
    assert quot.app_makespan(1) < base.app_makespan(1)
    assert quot.app_makespan(1) <= 1.15 * solo.app_makespan(1)


# ---------------------------------------------------------------------------
# RS admission control (per-pid reservation-station entry caps)
# ---------------------------------------------------------------------------
# the RS-residency metric is shared with the benchmark that commits the
# rs_admission numbers — one definition of "the cap binds" for both
from benchmarks.priority import _max_rs_occupancy  # noqa: E402


def test_rs_cap_policy_semantics():
    pol = SchedPolicy.of(rs_caps={2: 3})
    assert pol.rs_cap_of(2) == 3 and pol.rs_cap_of(1) == NO_QUOTA
    arr = pol.rs_cap_array()
    assert arr.shape == (NUM_PIDS,) and arr[2] == 3 and arr[0] == NO_QUOTA
    assert not pol.is_default and "rs_caps" in pol.describe()
    with pytest.raises(ValueError):
        SchedPolicy.of(rs_caps={1: 0})           # cap must be >= 1
    u = SchedPolicy.of(weights={1: 8}).merge_with(pol)
    assert u.rs_cap_of(2) == 3 and u.weight_of(1) == 8
    with pytest.raises(ValueError, match="conflicting rs_cap"):
        pol.merge_with(SchedPolicy.of(rs_caps={2: 1}))


@pytest.mark.parametrize("cap", [1, 3])
def test_rs_cap_never_exceeded(cap):
    """Per-pid RS residency never exceeds the admission cap, on both
    backends; uncapped pids are free to exceed it."""
    prog = _contended(2)
    pol = SchedPolicy.of(rs_caps={2: cap, 3: cap})
    for backend in ("jax", "golden"):
        r = hts.run(prog, n_fu=1, backend=backend, policy=pol)
        for pid in (2, 3):
            assert _max_rs_occupancy(r, pid) <= cap, (backend, pid)
    # sanity: the cap binds — without it the flood holds > cap entries
    r0 = hts.run(_contended(2), n_fu=1)
    assert max(_max_rs_occupancy(r0, pid) for pid in (2, 3)) > 3


def test_rs_cap_differential_and_merge_attach():
    """RS-capped arbitration is verified by the same golden ≡ machine
    machinery (event-skip on and off), and ``merge(rs_caps=...)``
    attaches the policy to the program."""
    prog = Program.merge(
        [_hi_chain(chain=4, delay=4)] + [_greedy(2 + k, 6) for k in range(2)],
        "capped", require_distinct_pids=True,
        priorities={1: 8}, rs_caps={2: 2, 3: 2})
    assert prog.policy == SchedPolicy.of(weights={1: 8},
                                         rs_caps={2: 2, 3: 2})
    report = hts.compare(prog, schedulers=("naive", "hts_spec"), n_fu=1)
    assert report.schedulers == ("naive", "hts_spec")


def test_rs_cap_bounds_flood_occupancy_but_not_stream_position():
    """The measured finding behind BENCH_priority.json's rs_admission
    section: caps bound the flood's window residency (the admission
    mechanism works) but cannot improve the late tenant's makespan in the
    merged-stream model — dispatch order IS stream order, so a blocking
    cap can only delay instructions, never reorder them.  The honest
    comparison: occupancy drops, hi makespan does not improve."""
    base = hts.run(_contended(2), n_fu=1, policy=SchedPolicy.of(
        weights={1: 8}))
    capped = hts.run(_contended(2), n_fu=1, policy=SchedPolicy.of(
        weights={1: 8}, rs_caps={2: 2, 3: 2}))
    assert max(_max_rs_occupancy(capped, pid) for pid in (2, 3)) <= 2
    assert max(_max_rs_occupancy(base, pid) for pid in (2, 3)) > 2
    assert capped.app_makespan(1) >= base.app_makespan(1)


# ---------------------------------------------------------------------------
# policy threading: builder → api → Result/FairnessReport
# ---------------------------------------------------------------------------
def test_merge_attaches_policy_and_run_applies_it():
    prog = _contended(2, priorities={1: 8}, quotas={2: 1})
    assert prog.policy == SchedPolicy.of(weights={1: 8}, quotas={2: 1})
    r = hts.run(prog, n_fu=1)                    # picked up automatically
    assert r.policy is prog.policy
    # explicit policy= argument overrides the attached one
    r2 = hts.run(prog, n_fu=1, policy=SchedPolicy())
    assert r2.policy.is_default
    assert r2.schedule == hts.run(_contended(2), n_fu=1).schedule


def test_merge_unions_tenant_policies_and_rejects_conflicts():
    a = _hi_chain()
    a.policy = SchedPolicy.of(weights={1: 8})
    b = _greedy(2)
    b.policy = SchedPolicy.of(quotas={2: 1})
    merged = Program.merge([a, b], require_distinct_pids=True)
    assert merged.policy == SchedPolicy.of(weights={1: 8}, quotas={2: 1})
    b.policy = SchedPolicy.of(weights={1: 2})    # conflicts with a
    with pytest.raises(BuilderError, match="conflicting weight"):
        Program.merge([a, b], require_distinct_pids=True)


def test_fairness_report_carries_weights():
    sc = workloads.generate_scenario(17, n_tenants=3,
                                     kernels=workloads.CHEAP_MIX,
                                     mixed_priority=True)
    assert sc.policy is not None and not sc.policy.is_default
    shared = hts.run(sc.merged, n_fu=1)
    fair = shared.fairness(workloads.solo_results(sc, n_fu=1))
    assert fair.weights == {pid: sc.policy.weight_of(pid) for pid in sc.pids}
    by_w = fair.by_weight()
    assert list(by_w) == sorted(by_w, reverse=True)
    assert "weight" in fair.table()
    # same seed without mixed_priority generates identical tenant programs
    plain = workloads.generate_scenario(17, n_tenants=3,
                                        kernels=workloads.CHEAP_MIX)
    assert plain.merged.asm == sc.merged.asm and plain.policy is None


# ---------------------------------------------------------------------------
# the mixed-priority differential fuzzer (fast tier: >= 25 seeds)
# ---------------------------------------------------------------------------
def test_fuzz_differential_mixed_priority():
    passed = 0
    for seed in range(PRIORITY_FUZZ_SEEDS):
        sc = workloads.generate_scenario(seed, n_tenants=2 + seed % 3,
                                         kernels=workloads.CHEAP_MIX,
                                         max_tasks=4, mixed_priority=True)
        assert sc.policy is not None
        report = hts.compare(sc.merged, schedulers=FUZZ_SCHEDULERS)
        assert report.schedulers == FUZZ_SCHEDULERS
        passed += 1
    assert passed >= 25


@pytest.mark.slow
def test_fuzz_differential_mixed_priority_heavy():
    """Slow tier: full Table-II kernel mix, up to 8 tenants, software
    scheduler included, wider FU pools."""
    for seed in range(10):
        sc = workloads.generate_scenario(2000 + seed,
                                         kernels=workloads.FULL_MIX,
                                         mixed_priority=True)
        hts.compare(sc.merged, n_fu=3,
                    schedulers=("naive", "software", "hts_nospec",
                                "hts_spec"))
