"""Test-suite bootstrap.

The container this repo targets does not always ship ``hypothesis``; the
tier-1 suite previously died at *collection* because two test modules import
it.  When the real package is available we use it untouched.  Otherwise we
install a tiny deterministic stand-in that covers exactly the API surface
these tests use (``given``, ``settings``, ``strategies.integers /
sampled_from / booleans / composite``): each ``@given`` test runs a fixed
number of seeded pseudo-random examples.  Less thorough than real
hypothesis shrinking, but deterministic, dependency-free, and infinitely
better than not running the property tests at all.
"""
from __future__ import annotations

import functools
import random
import sys
import types

try:                                    # real hypothesis wins when present
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _MAX_FALLBACK_EXAMPLES = 10         # keep the fallback suite fast

    class _Strategy:
        def __init__(self, draw_fn):
            self.draw_with = draw_fn    # rng -> value

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))

    def _booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def _composite(fn):
        def make_strategy(*args, **kwargs):
            def draw_fn(rng):
                return fn(lambda strat: strat.draw_with(rng), *args, **kwargs)
            return _Strategy(draw_fn)
        return make_strategy

    def _given(*strategies):
        def decorate(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = min(getattr(wrapper, "_max_examples", 20),
                        _MAX_FALLBACK_EXAMPLES)
                rng = random.Random(fn.__qualname__)   # per-test, stable
                for _ in range(n):
                    fn(*args, *(s.draw_with(rng) for s in strategies),
                       **kwargs)
            # pytest must not see the original signature, or it would try to
            # resolve the strategy parameters as fixtures
            del wrapper.__wrapped__
            wrapper.hypothesis_fallback = True
            return wrapper
        return decorate

    def _settings(max_examples=20, deadline=None, **_ignored):
        def decorate(fn):
            fn._max_examples = max_examples
            return fn
        return decorate

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.sampled_from = _sampled_from
    _st.booleans = _booleans
    _st.composite = _composite

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.HealthCheck = types.SimpleNamespace(too_slow=None)

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
