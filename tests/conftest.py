"""Test-suite bootstrap: hypothesis fallback + fast-tier wall-clock guard.

**Hypothesis fallback.** The container this repo targets does not always
ship ``hypothesis``; the tier-1 suite previously died at *collection*
because two test modules import it.  When the real package is available we
use it untouched.  Otherwise we install a tiny deterministic stand-in that
covers exactly the API surface these tests use (``given``, ``settings``,
``strategies.integers / sampled_from / booleans / composite``): each
``@given`` test runs a fixed number of seeded pseudo-random examples.
Less thorough than real hypothesis shrinking, but deterministic,
dependency-free, and infinitely better than not running the property
tests at all.

**Fast-tier wall-clock guard.** The fast tier is the edit loop; letting it
creep is how suites rot.  A *full* fast-tier session (the bare
``testpaths`` run with the default ``-m 'not slow'`` selection) that
passes but exceeds the wall budget is turned into a hard failure, so a
newly-unmarked fuzz mix that doubles the tier fails CI instead of slipping
by.  The budget comes from ``HTS_FAST_BUDGET_S`` (CI pins its own number);
the default is calibrated to the measured suite on a contended 2-core dev
box (~24 min incl. docs) plus headroom — not an aspiration.  Subset runs
(explicit paths, ``-k``, ``-m slow``) are never guarded: the guard polices
the tier, not your debugging loop.
"""
from __future__ import annotations

import functools
import os
import random
import sys
import time
import types

#: wall budget for a *full* fast-tier session, seconds (override via env).
FAST_TIER_BUDGET_S = float(os.environ.get("HTS_FAST_BUDGET_S", 1800))

_SESSION_T0 = time.monotonic()


def _is_full_fast_tier(config) -> bool:
    """Bare `pytest` run over the ini testpaths with the default
    `-m 'not slow'` selection — the invocation the budget is for."""
    if list(config.args) != list(config.getini("testpaths")):
        return False                      # explicit file/dir subset
    if "not slow" not in (config.getoption("markexpr") or ""):
        return False                      # slow tier / custom -m selection
    if config.getoption("keyword"):
        return False                      # -k subset
    return True


def pytest_sessionfinish(session, exitstatus):
    if exitstatus != 0 or not _is_full_fast_tier(session.config):
        return
    elapsed = time.monotonic() - _SESSION_T0
    if elapsed <= FAST_TIER_BUDGET_S:
        return
    reporter = session.config.pluginmanager.get_plugin("terminalreporter")
    msg = (f"fast tier took {elapsed:.0f}s > budget "
           f"{FAST_TIER_BUDGET_S:.0f}s (HTS_FAST_BUDGET_S) — move new "
           f"slow mixes behind the `slow` marker (see --durations output)")
    if reporter is not None:
        reporter.write_sep("=", "FAST-TIER WALL BUDGET EXCEEDED", red=True)
        reporter.write_line(msg, red=True)
    else:                                 # pragma: no cover - no terminal
        print(msg, file=sys.stderr)
    session.exitstatus = 1

try:                                    # real hypothesis wins when present
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _MAX_FALLBACK_EXAMPLES = 10         # keep the fallback suite fast

    class _Strategy:
        def __init__(self, draw_fn):
            self.draw_with = draw_fn    # rng -> value

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))

    def _booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def _composite(fn):
        def make_strategy(*args, **kwargs):
            def draw_fn(rng):
                return fn(lambda strat: strat.draw_with(rng), *args, **kwargs)
            return _Strategy(draw_fn)
        return make_strategy

    def _given(*strategies):
        def decorate(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = min(getattr(wrapper, "_max_examples", 20),
                        _MAX_FALLBACK_EXAMPLES)
                rng = random.Random(fn.__qualname__)   # per-test, stable
                for _ in range(n):
                    fn(*args, *(s.draw_with(rng) for s in strategies),
                       **kwargs)
            # pytest must not see the original signature, or it would try to
            # resolve the strategy parameters as fixtures
            del wrapper.__wrapped__
            wrapper.hypothesis_fallback = True
            return wrapper
        return decorate

    def _settings(max_examples=20, deadline=None, **_ignored):
        def decorate(fn):
            fn._max_examples = max_examples
            return fn
        return decorate

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.sampled_from = _sampled_from
    _st.booleans = _booleans
    _st.composite = _composite

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.HealthCheck = types.SimpleNamespace(too_slow=None)

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
