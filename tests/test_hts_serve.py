"""The serving subsystem (fast tier): bucket routing, launch-on-full /
launch-on-deadline under a manual clock, bounded-queue backpressure, the
no-recompile-after-warmup cache guarantee, seeded arrival streams, and the
serve ≡ run ≡ run_many differential.  Sharding rides along on its
single-device-legal surface (``devices=1`` equivalence, ``pad_lanes``
bookkeeping, error paths); true multi-device runs live in
tests/test_multidevice.py (slow tier, forced host device pool).
"""
import dataclasses

import numpy as np
import pytest

from repro.core import hts
from repro.core.hts import api, batch, shard, workloads
from repro.core.hts.builder import Program

#: distinct max_cycles => distinct MachineSpec => this module's cache tests
#: read a jit runner no other test module has touched.
CACHE_CYCLES = 4_999_999


def _tiny(name, n_tasks, kernel="vector_dot", base=0x100):
    p = Program(name, region_base=base)
    frame = p.input(0x10, 4, "frame")
    prev = frame
    for i in range(n_tasks):
        prev = p.task(kernel, in_=prev, out=4, in_size=4, tid=i)
    return p


@pytest.fixture(scope="module")
def progs():
    return [workloads.generate_scenario(s, n_tenants=2,
                                        kernels=workloads.CHEAP_MIX).merged
            for s in range(6)]


# ---------------------------------------------------------------------------
# scenarios_per_second (the deduped throughput formula)
# ---------------------------------------------------------------------------
def test_scenarios_per_second_formula():
    assert hts.scenarios_per_second(10, 2e6) == 5.0
    assert hts.scenarios_per_second(10, 0.0) == 0.0      # unmeasured
    assert hts.scenarios_per_second(0, 1e6) == 0.0


def test_population_result_scenarios_per_second(progs):
    r = hts.run_many(progs[:3], scheduler="hts_spec")
    assert r.scenarios_per_second() == pytest.approx(
        hts.scenarios_per_second(3, r.wall_us))
    # benchmarks pass their own measured median wall
    assert r.scenarios_per_second(1e6) == 3.0
    assert r.scenarios_per_sec() == r.scenarios_per_second()


# ---------------------------------------------------------------------------
# seeded arrival streams
# ---------------------------------------------------------------------------
def test_arrival_stream_seeded_and_monotonic():
    s1 = workloads.arrival_stream(7, rate=100.0, n=20, n_tenants=2)
    s2 = workloads.arrival_stream(7, rate=100.0, n=20, n_tenants=2)
    assert len(s1) == 20
    times = [a.t for a in s1]
    assert times == sorted(times) and times[0] > 0
    assert [a.t for a in s2] == times                    # reproducible
    # mean inter-arrival gap tracks 1/rate (loose: 20 exponential draws)
    assert 0.2 / 100 < times[-1] / 20 < 5.0 / 100


def test_arrival_stream_programs_independent_of_stream_params():
    """Changing seed/rate/dist re-times the stream but never changes the
    scenario programs — scenario i IS generate_scenario(seed0 + i)."""
    a = workloads.arrival_stream(1, rate=10.0, n=4, seed0=3, n_tenants=2)
    b = workloads.arrival_stream(99, rate=500.0, n=4, seed0=3,
                                 dist="uniform", n_tenants=2)
    for i in range(4):
        ref = batch.prepare(
            workloads.generate_scenario(3 + i, n_tenants=2).merged)
        assert np.array_equal(batch.prepare(a[i].scenario.merged).code,
                              ref.code)
        assert np.array_equal(batch.prepare(b[i].scenario.merged).code,
                              ref.code)
    assert [x.t for x in a] != [x.t for x in b]


def test_arrival_stream_validation():
    with pytest.raises(ValueError):
        workloads.arrival_stream(0, rate=0.0, n=3)
    with pytest.raises(ValueError):
        workloads.arrival_stream(0, rate=1.0, n=-1)
    with pytest.raises(ValueError):
        workloads.arrival_stream(0, rate=1.0, n=3, dist="bursty")
    assert workloads.arrival_stream(0, rate=1.0, n=0) == ()


# ---------------------------------------------------------------------------
# shard bookkeeping (single-device-legal surface)
# ---------------------------------------------------------------------------
def test_pad_lanes_shape_and_markers(progs):
    pop = batch.pack_population(progs[:3], n_fu=2)
    padded = shard.pad_lanes(pop, 4)
    assert len(padded) == 4 and len(pop) == 3
    assert padded.names[:3] == pop.names
    assert padded.names[3].startswith("<pad:")
    src = int(np.argmin(pop.p_len))                     # lightest lane
    assert int(padded.p_len[3]) == int(pop.p_len[src])
    for a, b in zip(padded.machine_args(), pop.machine_args()):
        assert np.array_equal(a[:3], b)                 # real lanes intact
        assert np.array_equal(a[3], b[src])             # pad replicates src
    assert shard.pad_lanes(pop, 3) is pop               # already divisible
    with pytest.raises(ValueError):
        shard.pad_lanes(pop, 0)


def test_run_many_devices1_matches_default(progs):
    r0 = hts.run_many(progs[:3], scheduler="hts_spec")
    r1 = hts.run_many(progs[:3], scheduler="hts_spec", devices=1)
    assert np.array_equal(r0.cycles, r1.cycles)
    for i in range(3):
        assert r0[i].schedule_tuple() == r1[i].schedule_tuple()


def test_devices_error_paths(progs):
    with pytest.raises(ValueError, match="backend"):
        hts.run_many(progs[:2], backend="golden", devices=1)
    too_many = shard.device_count() + 1
    with pytest.raises(ValueError, match="device"):
        hts.run_many(progs[:2], scheduler="hts_spec", devices=too_many)


# ---------------------------------------------------------------------------
# the serving engine
# ---------------------------------------------------------------------------
def test_serve_differential_vs_run_many(progs):
    """The headline semantics: results served out of shape-bucket batches
    are identical to running the programs directly."""
    clock = hts.ManualClock()
    with hts.serve(max_batch=4, max_queue=32, deadline=1.0,
                   clock=clock) as srv:
        futs = [srv.submit(p, tenant=f"t{i % 2}")
                for i, p in enumerate(progs)]
        srv.drain()
        got = [f.result(timeout=0) for f in futs]
    ref = hts.run_many(progs, scheduler="hts_spec")
    assert [r.cycles for r in got] == [int(c) for c in ref.cycles]
    for r, i in zip(got, range(len(progs))):
        assert r.schedule_tuple() == ref[i].schedule_tuple()
    rep = srv.report()
    assert rep.requests == len(progs)
    assert set(rep.per_tenant) == {"t0", "t1"}
    assert rep.per_tenant["t0"].requests == 3
    assert "served" in rep.table()


def test_serve_arrival_stream_differential():
    """Open-loop serving: a seeded arrival stream replayed on the manual
    clock, every result checked against a direct hts.run."""
    stream = workloads.arrival_stream(11, rate=1000.0, n=8, n_tenants=2,
                                      kernels=workloads.CHEAP_MIX)
    clock = hts.ManualClock()
    srv = hts.serve(max_batch=4, max_queue=16, deadline=0.005, clock=clock)
    futs = []
    for arr in stream:
        clock.t = arr.t
        futs.append(srv.submit(arr.scenario.merged))
    clock.advance(1.0)
    srv.poll()                                   # deadline-flush the tail
    assert srv.pending == 0
    for arr, f in zip(stream, futs):
        ref = hts.run(arr.scenario.merged, scheduler="hts_spec", n_fu=2)
        assert f.result(timeout=0).cycles == ref.cycles


def test_serve_launch_on_full_is_inline(progs):
    srv = hts.serve(max_batch=3, deadline=99.0, clock=hts.ManualClock())
    f1 = srv.submit(progs[0])
    f2 = srv.submit(progs[1])
    assert not f1.done() and srv.pending == 2
    f3 = srv.submit(progs[2])                    # fills the batch
    assert f1.done() and f2.done() and f3.done()
    assert srv.pending == 0


def test_serve_deadline_launch_manual_clock(progs):
    clock = hts.ManualClock()
    srv = hts.serve(max_batch=8, deadline=0.050, clock=clock)
    f = srv.submit(progs[0])
    assert srv.poll() == 0 and not f.done()      # too young
    clock.advance(0.049)
    assert srv.poll() == 0                       # still under deadline
    clock.advance(0.002)
    assert srv.poll() == 1                       # aged past 50 ms
    assert f.done()
    # a partial launch pads to max_batch: occupancy shows 1 real lane of 8
    b = srv.report().per_bucket
    (stats,) = b.values()
    assert stats.pad_lanes == 7 and stats.occupancy == pytest.approx(1 / 8)


def test_serve_submit_flushes_expired_batches(progs):
    """submit() itself runs the deadline check, so an open-loop producer
    that never calls poll() still gets deadline launches."""
    clock = hts.ManualClock()
    srv = hts.serve(max_batch=8, deadline=0.010, clock=clock)
    f = srv.submit(progs[0])
    clock.advance(0.020)
    srv.submit(progs[1])                         # flushes the aged batch
    assert f.done() and srv.pending == 1


def test_serve_bucket_routing():
    """Requests route by (program bucket, stream bucket): a long program
    and a multi-frontend scenario land in different open batches than a
    short merged one."""
    short = _tiny("short", 2)
    long = _tiny("long", 40)                     # > MIN_BUCKET instructions
    multi = workloads.generate_scenario(0, n_tenants=2, frontends=True,
                                        kernels=workloads.CHEAP_MIX).multi
    srv = hts.serve(max_batch=8, max_queue=32, deadline=99.0,
                    clock=hts.ManualClock())
    k_short, k_long, k_multi = (srv.bucket_of(p)
                                for p in (short, long, multi))
    assert k_short == (batch.MIN_BUCKET, 1)
    assert k_long[0] > batch.MIN_BUCKET          # longer program ladder
    assert k_multi[1] == 2                       # 2 frontend streams
    futs = [srv.submit(p) for p in (short, long, multi, short)]
    assert len(srv._open) == 3                   # three open batches
    srv.drain()
    for f in futs:
        assert f.result(timeout=0).halted
    rep = srv.report()
    assert set(rep.per_bucket) == {k_short, k_long, k_multi}
    assert rep.per_bucket[k_short].requests == 2


def test_serve_backpressure_queue_full(progs):
    clock = hts.ManualClock()
    srv = hts.serve(max_batch=4, max_queue=4, deadline=0.050, clock=clock)
    long = _tiny("long", 40)                     # second bucket
    for p in (progs[0], progs[1], progs[2], long):
        srv.submit(p)                            # neither bucket fills
    assert srv.pending == 4
    # a request that would NOT complete its batch is refused at the bound
    with pytest.raises(hts.QueueFullError):
        srv.submit(long)                         # 2nd-bucket batch: 2/4
    # but one that COMPLETES a batch is admitted — it launches inline and
    # frees max_batch slots (refusing it would deadlock a full queue)
    f4 = srv.submit(progs[3])                    # 1st bucket fills: 4/4
    assert f4.done() and f4.result(timeout=0).halted
    assert srv.pending == 1                      # only `long` still queued
    # deadline expiry frees the queue: submit() flushes before admitting
    clock.advance(0.060)
    f = srv.submit(progs[4])
    assert srv.pending == 1 and not f.done()
    srv.drain()
    assert f.result(timeout=0).halted


def test_serve_never_recompiles_after_warmup(progs):
    """The acceptance-criteria guarantee: once a bucket has launched, a
    further >= 3 batches through it add ZERO jit compilations — every
    launch is padded to the bucket's one compiled signature."""
    spec = hts.ServeSpec(max_batch=3, max_queue=32, deadline=99.0,
                         max_cycles=CACHE_CYCLES)
    srv = hts.serve(spec, clock=hts.ManualClock())
    assert srv.cache_info() == hts.CacheInfo(0, 0, 0, 0)
    [srv.submit(p) for p in progs[:3]]           # warm the bucket
    warm = srv.cache_info()
    assert warm.misses == 1 and warm.entries == 1
    assert warm.jit_compiles >= 1
    for wave in range(3):                        # full batches
        fs = [srv.submit(p) for p in progs[3:6]]
        assert all(f.done() for f in fs)
    srv.submit(progs[0])                         # plus a padded partial
    srv.drain()
    after = srv.cache_info()
    assert after.jit_compiles == warm.jit_compiles   # zero recompiles
    assert after.hits == 4 and after.misses == 1


def test_serve_nonhalting_request_fails_its_future_only(progs):
    srv = hts.serve(max_batch=2, deadline=99.0, max_cycles=50,
                    clock=hts.ManualClock())
    f1 = srv.submit(progs[0])                    # needs >> 50 cycles
    f2 = srv.submit(progs[1])
    assert f1.done() and f2.done()
    with pytest.raises(hts.SimulationError):
        f1.result(timeout=0)
    with pytest.raises(hts.SimulationError):
        f2.result(timeout=0)


def test_serve_close_and_validation(progs):
    srv = hts.serve(max_batch=4, deadline=99.0, clock=hts.ManualClock())
    f = srv.submit(progs[0])
    srv.close()                                  # flushes
    assert f.done()
    with pytest.raises(RuntimeError):
        srv.submit(progs[0])
    with pytest.raises(ValueError):
        hts.serve(max_batch=0)
    with pytest.raises(ValueError):
        hts.serve(max_batch=8, max_queue=4)      # queue < one batch
    with pytest.raises(ValueError):
        hts.serve(n_fu=8, max_fu_per_class=4)


def test_serve_devices1_matches_unsharded(progs):
    """The sharded launch path on one device (always legal) serves the
    same results as the plain server."""
    with hts.serve(max_batch=3, deadline=99.0, devices=1,
                   clock=hts.ManualClock()) as srv:
        futs = [srv.submit(p) for p in progs[:3]]
        got = [f.result(timeout=0).cycles for f in futs]
    ref = hts.run_many(progs[:3], scheduler="hts_spec")
    assert got == [int(c) for c in ref.cycles]


def test_serve_spec_overrides():
    spec = hts.ServeSpec(max_batch=2)
    srv = hts.serve(spec, deadline=0.5)
    assert srv.spec.max_batch == 2 and srv.spec.deadline == 0.5
    assert dataclasses.is_dataclass(srv.spec)
    assert isinstance(api._norm_costs(srv.spec.scheduler).name, str)
    with pytest.raises(ValueError):
        hts.serve(slice_steps=0)
    with pytest.raises(ValueError):
        hts.serve(slice_steps="adaptive")


# ---------------------------------------------------------------------------
# engine bugfix pins (admission cost, launch exception-safety, lifecycle)
# ---------------------------------------------------------------------------
def test_serve_submit_prepares_once_and_never_decodes(progs, monkeypatch):
    """Admission is the hot path: one prepare() per submit and ZERO
    program decodes — the bucket key reads lengths off the Prepared
    request instead of running the decoder just to count rows."""
    from repro.core.hts import isa

    calls = {"prepare": 0, "decode": 0}
    real_prepare, real_decode = batch.prepare, isa.decode_table
    monkeypatch.setattr(batch, "prepare", lambda p: (
        calls.__setitem__("prepare", calls["prepare"] + 1),
        real_prepare(p))[1])
    monkeypatch.setattr(isa, "decode_table", lambda code: (
        calls.__setitem__("decode", calls["decode"] + 1),
        real_decode(code))[1])
    srv = hts.serve(max_batch=8, deadline=99.0, clock=hts.ManualClock())
    srv.submit(progs[0])                         # queued, no launch
    assert calls == {"prepare": 1, "decode": 0}
    srv.submit(progs[1])
    assert calls == {"prepare": 2, "decode": 0}


@pytest.mark.parametrize("slice_steps", [None, 32])
def test_serve_launch_failure_fails_futures_and_restores_queue(
        progs, monkeypatch, slice_steps):
    """A launch that raises must fail its own futures and give their slots
    back — not leak hung futures and permanently shrink the queue."""
    srv = hts.serve(max_batch=4, max_queue=8, deadline=99.0,
                    slice_steps=slice_steps, clock=hts.ManualClock())
    f1 = srv.submit(progs[0])
    f2 = srv.submit(progs[1])

    def boom(*a, **k):
        raise RuntimeError("injected pack failure")

    monkeypatch.setattr(batch, "pack_population", boom)
    with pytest.raises(RuntimeError, match="injected pack failure"):
        srv.drain()
    assert srv.pending == 0                      # accounting restored
    for f in (f1, f2):
        with pytest.raises(RuntimeError, match="injected pack failure"):
            f.result(timeout=0)
    monkeypatch.undo()
    # the server is still fully serviceable: no leaked pending counts
    fs = [srv.submit(p) for p in progs]
    srv.drain()
    assert srv.pending == 0
    assert all(f.result(timeout=0).halted for f in fs)


def test_serve_post_close_raises_everywhere(progs):
    srv = hts.serve(max_batch=4, deadline=99.0, clock=hts.ManualClock())
    f = srv.submit(progs[0])
    srv.close()                                  # flushes
    assert f.done()
    srv.close()                                  # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        srv.submit(progs[0])
    with pytest.raises(RuntimeError, match="closed"):
        srv.poll()
    with pytest.raises(RuntimeError, match="closed"):
        srv.drain()


def test_serve_exit_on_exception_aborts_queued_work(progs):
    """Leaving the with-block on an exception cancels queued futures
    instead of burning simulation time on results nobody will read."""
    with pytest.raises(KeyError):
        with hts.serve(max_batch=4, deadline=99.0,
                       clock=hts.ManualClock()) as srv:
            f = srv.submit(progs[0])
            raise KeyError("caller bug")
    assert f.cancelled() and srv.pending == 0
    with pytest.raises(RuntimeError, match="closed"):
        srv.submit(progs[0])
    # normal exit still flushes
    with hts.serve(max_batch=4, deadline=99.0,
                   clock=hts.ManualClock()) as srv:
        f = srv.submit(progs[0])
    assert f.result(timeout=0).halted


# ---------------------------------------------------------------------------
# slice-and-refill continuous batching
# ---------------------------------------------------------------------------
#: distinct max_cycles => this module's sliced cache tests own their runner
SLICED_CACHE_CYCLES = 4_999_998


@pytest.mark.parametrize("event_skip", [True, False])
def test_serve_sliced_refill_differential_fuzz(event_skip):
    """The tentpole differential: slice-and-refill serving returns the
    same per-request results as a direct hts.run — seeded merged and
    multi-frontend scenarios, a queue always deeper than the lane width
    so every batch actually refills mid-flight."""
    seeds = list(range(25)) if event_skip else list(range(40, 53))
    progs = []
    for s in seeds:
        multi = s % 5 == 0
        sc = workloads.generate_scenario(s, n_tenants=2, frontends=multi,
                                         kernels=workloads.CHEAP_MIX)
        progs.append(sc.multi if multi else sc.merged)
    srv = hts.serve(max_batch=4, max_queue=64, deadline=99.0,
                    event_skip=event_skip, slice_steps=24,
                    clock=hts.ManualClock())
    with srv:
        futs = [srv.submit(p) for p in progs]
        srv.drain()
        for p, f in zip(progs, futs):
            got = f.result(timeout=0)
            ref = hts.run(p, scheduler="hts_spec", n_fu=2,
                          event_skip=event_skip)
            assert got.halted and got.cycles == ref.cycles, p.name
            assert got.stall_cycles == ref.stall_cycles, p.name
            assert got.spec_aborted == ref.spec_aborted, p.name
            assert got.fe_stall == ref.fe_stall, p.name
            assert got.schedule == ref.schedule, p.name
    rep = srv.report()
    assert rep.requests == len(progs)
    # refill is the point: lanes stay busier than a padded static launch
    assert all(b.occupancy > 0.5 for b in rep.per_bucket.values())


def test_serve_sliced_heterogeneous_cost_tables():
    """Slice-and-refill serving under heterogeneous FU costs: a server
    whose ``params.fu_cost`` marks unit 0 of the hot classes slow, fed a
    queue deeper than the lane width (so batches refill mid-flight) with
    a mix of greedy and program-attached eft policies — every request's
    sliced result equals a direct hts.run with the same table."""
    from repro.core.hts.costs import fu_cost_tuple
    from repro.core.hts.programs import Bench
    params = hts.HtsParams(fu_cost=fu_cost_tuple({"dct": (4, 1),
                                                  "vector_add": (3, 1)}))
    progs = []
    for s in range(8):
        sc = workloads.generate_scenario(60 + s, n_tenants=2,
                                         kernels=workloads.CHEAP_MIX)
        prog = sc.merged.program
        if s % 2:       # half the requests run the EFT arbiter
            prog.policy = dataclasses.replace(
                prog.policy or hts.SchedPolicy(), issue_mode="eft")
        progs.append(Bench.of(prog))
    srv = hts.serve(max_batch=3, max_queue=64, deadline=99.0, params=params,
                    slice_steps=16, clock=hts.ManualClock())
    with srv:
        futs = [srv.submit(p) for p in progs]
        srv.drain()
        for p, f in zip(progs, futs):
            got = f.result(timeout=0)
            ref = hts.run(p, scheduler="hts_spec", n_fu=2, params=params)
            assert got.halted and got.cycles == ref.cycles, p.name
            assert got.schedule == ref.schedule, p.name


def test_serve_sliced_never_recompiles_across_refills(progs):
    """The cache guarantee extends to compaction: one carry-init compile
    plus one slice compile per bucket, frozen across launches, refills,
    and adaptive (auto) slice budgets."""
    spec = hts.ServeSpec(max_batch=3, max_queue=32, deadline=99.0,
                         slice_steps="auto",
                         max_cycles=SLICED_CACHE_CYCLES)
    srv = hts.serve(spec, clock=hts.ManualClock())
    [srv.submit(p) for p in progs[:3]]
    srv.drain()
    warm = srv.cache_info()
    assert warm.misses == 1 and warm.entries == 1
    assert warm.jit_compiles == 2                # carry init + slice
    for wave in (progs[3:6], progs[:4], progs[1:6]):
        fs = [srv.submit(p) for p in wave]
        srv.drain()
        assert all(f.done() for f in fs)
    after = srv.cache_info()
    assert after.jit_compiles == warm.jit_compiles   # frozen across refills
    assert after.misses == 1


def test_serve_sliced_devices1_matches_unsharded(progs):
    """The sharded resumable path on one device (always legal) serves the
    same results as the plain sliced server and the batched reference."""
    got = {}
    for devices in (None, 1):
        with hts.serve(max_batch=2, max_queue=16, deadline=99.0,
                       devices=devices, slice_steps=48,
                       clock=hts.ManualClock()) as srv:
            futs = [srv.submit(p) for p in progs]
            srv.drain()
            got[devices] = [f.result(timeout=0).cycles for f in futs]
    ref = hts.run_many(progs, scheduler="hts_spec")
    assert got[None] == got[1] == [int(c) for c in ref.cycles]
