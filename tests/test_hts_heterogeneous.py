"""Heterogeneous FU cost tables + the EFT-rank arbiter.

Covers the three hard guarantees of the heterogeneity layer:

* **bit-identity** — an all-ones cost table plus ``issue_mode="greedy"``
  degrades *exactly* to the baseline arbiter on both backends (cycles and
  full schedule tuples pinned), and the default ``SchedPolicy()`` equality/
  hash is unchanged, so no existing compilation bucket splits;
* **EFT semantics** — the arbiter grants each task the free quota-eligible
  unit with the earliest predicted finish (cost-table latency; a busy unit
  is not a candidate, so the busy-horizon term is zero by construction),
  verified as a schedule-level property on generated scenarios;
* **policy composition** — quota and RS-cap invariants hold under ``eft``
  exactly as they do under greedy.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import hts
from repro.core.hts import costs, machine, workloads
from repro.core.hts.builder import Program
from repro.core.hts.costs import (FU_COST_CAP, FU_COST_WIDTH, FUNC_CYCLES,
                                  fu_cost_tuple, norm_fu_cost)
from repro.core.hts.policy import SchedPolicy

DCT = costs.FUNC_IDS["dct"]


def _pool(n_tasks=2, func="dct", pid=1):
    """``n_tasks`` independent same-class tasks: every task is ready at
    once, so unit selection is the whole schedule."""
    p = Program(f"pool{pid}", region_base=0x100)
    frame = p.input(0x10, 4, "frame")
    with p.process(pid):
        for i in range(n_tasks):
            p.task(func, in_=frame, out=4, tid=i & 0xF)
    return p


# ---------------------------------------------------------------------------
# cost-table normalisation
# ---------------------------------------------------------------------------
def test_norm_fu_cost_forms():
    ones = norm_fu_cost(None)
    assert ones.shape == (costs.NUM_FUNCS, FU_COST_WIDTH)
    assert (ones == 1).all() and ones.dtype == np.int32
    # keyname mapping, scalar row, short row padded with 1
    t = norm_fu_cost({"dct": 3, DCT - 1: (5, 2)})
    assert (t[DCT] == 3).all()
    assert t[DCT - 1, 0] == 5 and t[DCT - 1, 1] == 2 and t[DCT - 1, 2] == 1
    assert (t[0] == 1).all()
    # full per-class table round-trips
    full = np.arange(1, costs.NUM_FUNCS * 4 + 1).reshape(costs.NUM_FUNCS, 4)
    t2 = norm_fu_cost(full)
    assert (t2[:, :4] == full).all() and (t2[:, 4:] == 1).all()


def test_norm_fu_cost_validation():
    with pytest.raises(ValueError, match=r"\[1, "):
        norm_fu_cost({"dct": 0})
    with pytest.raises(ValueError, match=r"\[1, "):
        norm_fu_cost({"dct": FU_COST_CAP + 1})
    with pytest.raises(ValueError, match="unknown function class"):
        norm_fu_cost({99: 2})
    with pytest.raises(KeyError):
        norm_fu_cost({"not_a_kernel": 2})
    with pytest.raises(ValueError, match="per-class rows"):
        norm_fu_cost([(1, 1)] * 3)


def test_fu_cost_tuple_uniform_is_none():
    """Uniform tables normalise to None so a vanilla machine keeps a
    vanilla ``HtsParams`` key (no cache-bucket split from an explicit
    all-ones table)."""
    assert fu_cost_tuple(None) is None
    assert fu_cost_tuple({"dct": 1}) is None
    assert fu_cost_tuple(np.ones((costs.NUM_FUNCS, 4))) is None
    t = fu_cost_tuple({"dct": (2, 1)})
    assert isinstance(t, tuple) and hash(t) is not None
    assert t[DCT][0] == 2


# ---------------------------------------------------------------------------
# satellite 1: bit-identity + unchanged default policy key
# ---------------------------------------------------------------------------
def test_default_policy_equality_and_hash_unchanged():
    """``issue_mode`` is a defaulted field: the default policy's equality,
    hash and ``is_default`` are untouched, so every pre-existing
    compilation bucket keyed on ``SchedPolicy()`` survives."""
    assert SchedPolicy() == SchedPolicy(issue_mode="greedy")
    assert hash(SchedPolicy()) == hash(SchedPolicy(issue_mode="greedy"))
    assert SchedPolicy(issue_mode="greedy").is_default
    eft = SchedPolicy(issue_mode="eft")
    assert not eft.is_default and "issue eft" in eft.describe()
    assert "issue" not in SchedPolicy().describe()
    with pytest.raises(ValueError, match="issue_mode"):
        SchedPolicy.of(issue_mode="fastest")
    # merge: agreeing modes pass through, conflicting modes refuse
    assert eft.merge_with(SchedPolicy.of(weights={1: 4},
                                         issue_mode="eft")).issue_mode == "eft"
    with pytest.raises(ValueError, match="different issue modes"):
        eft.merge_with(SchedPolicy())


@pytest.mark.parametrize("backend", ["jax", "golden"])
def test_all_ones_cost_table_is_bit_identical_to_baseline(backend):
    """All-ones table + explicit greedy == today's arbiter, exactly:
    cycles and the full schedule tuple pinned on both backends."""
    ones = np.ones((costs.NUM_FUNCS, 4), np.int64)
    for sc in (workloads.generate_scenario(5, kernels=workloads.CHEAP_MIX),
               workloads.generate_scenario(17, n_tenants=3,
                                           kernels=workloads.CHEAP_MIX,
                                           mixed_priority=True)):
        base = hts.run(sc.merged, n_fu=2, backend=backend)
        via = hts.run(sc.merged, n_fu=2, backend=backend, fu_cost=ones,
                      policy=dataclasses.replace(
                          sc.policy or SchedPolicy(), issue_mode="greedy"))
        assert via.cycles == base.cycles, sc.name
        assert via.schedule_tuple() == base.schedule_tuple(), sc.name


def test_cost_tables_and_eft_share_the_default_compile_bucket():
    """Cost tables and the eft flag are traced runtime data: running with
    a heterogeneous table + eft reuses the exact compilation the default
    run produced (no new ``machine._compiled`` miss)."""
    p = _pool(4)
    hts.run(p, n_fu=2)                       # warm the bucket
    before = machine._compiled.cache_info().misses
    hts.run(p, n_fu=2, fu_cost={"dct": (4, 1)},
            policy=SchedPolicy(issue_mode="eft"))
    hts.run(p, n_fu=2, fu_cost={"dct": (2, 3)})
    assert machine._compiled.cache_info().misses == before


# ---------------------------------------------------------------------------
# EFT semantics: unit selection + makespan
# ---------------------------------------------------------------------------
def test_eft_avoids_slow_units_greedy_pays_them():
    """Slow unit at index 0 where greedy looks first: two ready tasks on a
    (8x, 1x, 1x) dct pool — greedy serialises behind the 8x unit, EFT
    finishes in one fast-unit pass.  Oracle unit attribution confirms the
    grant decisions, not just the makespan."""
    p, cost = _pool(2), {"dct": (8, 1, 1)}
    greedy = hts.run(p, n_fu=3, fu_cost=cost, backend="golden")
    eft = hts.run(p, n_fu=3, fu_cost=cost, backend="golden",
                  policy=SchedPolicy(issue_mode="eft"))
    assert eft.cycles < greedy.cycles
    # flattened pool: dct units sit at [3*DCT, 3*DCT + 3)
    g_units = sorted(t.unit - 3 * DCT for t in greedy.raw.tasks)
    e_units = sorted(t.unit - 3 * DCT for t in eft.raw.tasks)
    assert g_units == [0, 1]                 # greedy takes the slow unit
    assert e_units == [1, 2]                 # eft skips it entirely
    # heterogeneous latency itself applies under BOTH issue modes
    assert greedy.cycles > 8 * FUNC_CYCLES[DCT]


@pytest.mark.parametrize("backend", ["jax", "golden"])
def test_uniform_costs_make_eft_equal_greedy(backend):
    """With uniform unit costs every free unit predicts the same finish,
    ties break to the lowest index, and eft == greedy bit-for-bit."""
    for seed in (1, 9, 23):
        sc = workloads.generate_scenario(seed, kernels=workloads.CHEAP_MIX)
        a = hts.run(sc.merged, n_fu=2, backend=backend)
        b = hts.run(sc.merged, n_fu=2, backend=backend,
                    policy=SchedPolicy(issue_mode="eft"))
        assert a.cycles == b.cycles, seed
        assert a.schedule_tuple() == b.schedule_tuple(), seed


def _busy_intervals(gold, n_per_class):
    """unit -> [(issue, complete)) busy spans from oracle attribution."""
    spans: dict[int, list] = {}
    for t in gold.tasks:
        if t.unit >= 0 and not t.aborted and t.complete_cycle >= 0:
            spans.setdefault(t.unit, []).append(
                (t.issue_cycle, t.complete_cycle))
    return spans


def test_eft_invariant_no_free_unit_finished_earlier():
    """The EFT grant property, extracted from real schedules: for every
    granted (task, unit) pair, no other unit of the class that was *free*
    at the grant instant had a strictly earlier predicted finish
    (cost-rank, ties to lower index).  Units busy at the instant —
    including same-cycle earlier grants — are not candidates, which makes
    the reconstruction conservative and the check sound."""
    n_per, checked = 3, 0
    for seed in range(12):
        sc = workloads.generate_scenario(seed, kernels=workloads.CHEAP_MIX,
                                         heterogeneous_fus=True)
        if sc.fu_cost is None:
            continue
        table = norm_fu_cost(sc.fu_cost)
        pol = dataclasses.replace(sc.policy or SchedPolicy(),
                                  issue_mode="eft")
        # hts_nospec: no speculative aborts => every busy span is exact
        gold = hts.run(sc.merged, n_fu=n_per, backend="golden",
                       scheduler="hts_nospec", fu_cost=sc.fu_cost,
                       policy=pol).raw
        spans = _busy_intervals(gold, n_per)
        for t in gold.tasks:
            if t.unit < 0:
                continue
            u_in_class = t.unit - n_per * t.func
            key = (int(table[t.func, u_in_class]), u_in_class)
            for u in range(n_per):
                if u == u_in_class:
                    continue
                flat = n_per * t.func + u
                free = all(not (s <= t.issue_cycle < e)
                           for s, e in spans.get(flat, ()))
                if free:
                    assert (int(table[t.func, u]), u) >= key, (
                        sc.seed, t.uid, t.unit, u)
                    checked += 1
    assert checked >= 50, f"only {checked} grant decisions exercised"


# ---------------------------------------------------------------------------
# policy composition under eft
# ---------------------------------------------------------------------------
def _max_inflight(result, pid, func):
    iv = [(r.issue, r.complete) for r in result.schedule
          if r.pid == pid and r.func == func
          and not r.aborted and r.issue >= 0 and r.complete >= 0]
    points = sorted({t for s, e in iv for t in (s, e)})
    return max((sum(1 for s, e in iv if s <= t < e) for t in points),
               default=0)


def _flood(pid):
    p = Program(f"flood{pid}", region_base=0x200 + 0x100 * (pid - 1))
    frame = p.input(0x10, 4, "frame")
    with p.process(pid):
        for i in range(8):
            p.task("dct", in_=frame, out=4, tid=i & 0xF)
    return p


@pytest.mark.parametrize("backend", ["jax", "golden"])
def test_quota_never_exceeded_under_eft(backend):
    """The quota mask composes with EFT ranking: per-pid per-class
    in-flight units stay at the cap even when EFT steers every grant."""
    prog = Program.merge([_flood(1), _flood(2)], "quota_eft",
                         require_distinct_pids=True, quotas={1: 1, 2: 2})
    pol = dataclasses.replace(prog.policy, issue_mode="eft")
    r = hts.run(prog, n_fu=4, backend=backend, policy=pol,
                fu_cost={"dct": (6, 1, 1, 2)})
    assert _max_inflight(r, 1, DCT) <= 1
    assert _max_inflight(r, 2, DCT) <= 2


def test_rs_cap_backpressure_under_eft():
    """RS admission caps keep binding under eft + heterogeneous costs, on
    both backends."""
    from benchmarks.priority import _max_rs_occupancy
    prog = Program.merge([_flood(1), _flood(2)], "rscap_eft",
                         require_distinct_pids=True)
    pol = SchedPolicy.of(rs_caps={1: 2, 2: 2}, issue_mode="eft")
    for backend in ("jax", "golden"):
        r = hts.run(prog, n_fu=1, backend=backend, policy=pol,
                    fu_cost={"dct": 3})
        for pid in (1, 2):
            assert _max_rs_occupancy(r, pid) <= 2, (backend, pid)


# ---------------------------------------------------------------------------
# differential: population batch with per-scenario tables
# ---------------------------------------------------------------------------
def test_population_compare_heterogeneous_tables():
    """One batched run_many population compare: per-scenario cost tables
    (some None, some eft) through golden = machine, event-skip on and
    off."""
    scs = [workloads.generate_scenario(s, n_tenants=2,
                                       kernels=workloads.CHEAP_MIX,
                                       max_tasks=4, heterogeneous_fus=True)
           for s in range(6)]
    assert any(sc.fu_cost is not None for sc in scs)
    assert any((sc.policy and sc.policy.issue_mode == "eft") for sc in scs)
    rep = hts.compare([sc.merged for sc in scs],
                      fu_cost=[sc.fu_cost for sc in scs],
                      schedulers=("hts_spec",))
    assert len(rep) == 6 and rep.n_modes == 3


def test_sweep_threads_cost_tables_without_recompiling():
    """A cost-table + eft sweep rides the FU axis machinery: same
    compiled bucket, and the uniform-table point of the sweep equals the
    no-table run exactly."""
    p = _pool(3)
    base = hts.sweep(p, n_fu=(1, 2, 3), schedulers=("hts_spec",))
    het = hts.sweep(p, n_fu=(1, 2, 3), schedulers=("hts_spec",),
                    fu_cost={"dct": (1, 1, 1)},
                    policy=SchedPolicy(issue_mode="eft"))
    assert (base.cycles["hts_spec"] == het.cycles["hts_spec"]).all()
