"""Per-kernel allclose sweeps: transformer Pallas kernels vs ref.py oracles."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels import ops, ref

RNG = np.random.default_rng(1)


def r(*shape, dtype=np.float32, scale=1.0):
    return jnp.asarray((RNG.standard_normal(shape) * scale).astype(dtype))


@pytest.mark.parametrize("rows,d", [(1, 128), (300, 256), (8, 512)])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_rmsnorm(rows, d, dtype):
    x, w = r(rows, d, dtype=dtype), r(d, dtype=dtype)
    tol = 1e-5 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(ops.rmsnorm(x, w), ref.rmsnorm(x, w),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,hq,hkv,t,d", [
    (1, 4, 4, 128, 64),
    (2, 8, 2, 256, 64),       # GQA
    (1, 2, 1, 384, 128),      # MQA, non-multiple of block
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(b, hq, hkv, t, d, causal):
    q = r(b, hq, t, d, scale=0.3)
    k = r(b, hkv, t, d, scale=0.3)
    v = r(b, hkv, t, d)
    got = ops.flash_attention(q, k, v, causal=causal)
    kr = jnp.repeat(k, hq // hkv, axis=1)
    vr = jnp.repeat(v, hq // hkv, axis=1)
    want = ref.flash_attention(q, kr, vr, causal=causal)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_flash_attention_q_offset_decode_chunk():
    """Chunked decode: q is the last 64 positions against a 256-long cache."""
    b, h, d = 2, 4, 64
    q = r(b, h, 64, d, scale=0.3)
    k = r(b, h, 256, d, scale=0.3)
    v = r(b, h, 256, d)
    got = ops.flash_attention(q, k, v, causal=True, q_offset=192)
    want = ref.flash_attention(q, k, v, causal=True, q_offset=192)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("b,t,h,kdim,vdim", [
    (1, 64, 2, 32, 32),
    (2, 128, 4, 64, 64),
    (1, 96, 1, 64, 128),      # T not a chunk multiple
])
def test_rwkv6(b, t, h, kdim, vdim):
    rr = r(b, t, h, kdim, scale=0.5)
    k = r(b, t, h, kdim, scale=0.5)
    v = r(b, t, h, vdim, scale=0.5)
    w = jnp.asarray(1.0 / (1.0 + np.exp(-RNG.standard_normal((b, t, h, kdim)))),
                    jnp.float32) * 0.5 + 0.5      # decay in (0.5, 1)
    u = r(h, kdim, scale=0.3)
    got = ops.rwkv6_scan(rr, k, v, w, u)
    want = ref.rwkv6_scan(rr, k, v, w, u)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("b,t,h,p,n", [
    (1, 64, 2, 32, 16),
    (2, 128, 4, 64, 64),
    (1, 80, 3, 16, 32),       # odd sizes
])
def test_mamba2_ssd(b, t, h, p, n):
    x = r(b, t, h, p, scale=0.5)
    a = -jnp.abs(r(b, t, h, scale=0.5))           # decay exponent ≤ 0
    bb = r(b, t, n, scale=0.5)
    c = r(b, t, n, scale=0.5)
    got = ops.mamba2_ssd(x, a, bb, c)
    want = ref.mamba2_ssd(x, a, bb, c)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
