"""End-to-end behaviour tests for the paper's system: assembly → OoO/speculative
schedule → execution of the scheduled tasks on the real Pallas accelerators."""
import jax.numpy as jnp
import numpy as np

from repro.core.hts import assembler, costs, golden, machine, programs


def test_paper_headline_claim_end_to_end():
    """Abstract: 'up to 12× improvement vs sequential scheduling' — the
    audio-compression application at high FU counts crosses 12×."""
    bench = programs.audio_compression(16, time_domain=False)
    code = assembler.assemble(bench.asm)
    params = golden.HtsParams(n_fu=(16,) * 10, tracker_entries=256,
                              rs_entries=64, max_tasks=256)
    naive = machine.simulate(code, costs.costs_by_name("naive"), params,
                             mem_init=bench.mem_init, effects=bench.effects)
    hts = machine.simulate(code, costs.costs_by_name("hts_spec"), params,
                           mem_init=bench.mem_init, effects=bench.effects)
    assert naive["halted"] and hts["halted"]
    speedup = int(naive["cycles"]) / int(hts["cycles"])
    assert speedup > 12.0, speedup


def test_schedule_executes_on_real_kernels():
    """The full loop: ISA program → HTS schedule → each scheduled task runs
    its Pallas DSP kernel over a frame batch; output finite, aborted
    speculative tasks excluded."""
    from repro.kernels import ops
    bench = programs.audio_compression(2, time_domain=True)  # mis-speculates
    code = assembler.assemble(bench.asm)
    out = machine.simulate(code, costs.costs_by_name("hts_spec"),
                           n_fu=np.array([2] * 10),
                           mem_init=bench.mem_init, effects=bench.effects)
    sched = machine.schedule_tuple(out)
    assert int(out["spec_aborted"]) > 0          # wrong-path tasks existed
    live = [r for r in sched if not r[6]]
    assert live, "committed tasks must remain"
    table = ops.dsp_dispatch_table()
    x = jnp.asarray(np.random.default_rng(0)
                    .standard_normal((8, 256)).astype(np.float32))
    for _, func, _, issue, _, _, _, _pid in sorted(live, key=lambda r: r[3]):
        x = table[costs.FUNC_NAMES[func]](x)
        x = x / jnp.maximum(jnp.max(jnp.abs(x)), 1e-6)
    assert np.isfinite(np.asarray(x)).all()


def test_speculation_functional_correctness():
    """§IV-C3: TLB/TM mechanism preserves functional correctness — the final
    architectural memory matches the non-speculative machine exactly."""
    for gen in programs.SYNTHETIC_BRANCH:
        bench = gen()
        code = assembler.assemble(bench.asm)
        p = golden.HtsParams(n_fu=(2,) * 10)
        spec = golden.run(code, costs.costs_by_name("hts_spec"), p,
                          bench.mem_init, bench.effects)
        nospec = golden.run(code, costs.costs_by_name("hts_nospec"), p,
                            bench.mem_init, bench.effects)
        np.testing.assert_array_equal(
            spec.mem[:p.mem_words], nospec.mem[:p.mem_words]), bench.name
