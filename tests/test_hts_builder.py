"""Program Builder + hts facade: region allocator safety, lowering identity
against hand-written assembly (paper §V-B), graph-level interleave ordering,
builder→encode→decode→disassemble→reassemble round-trips, and jax/golden
backend agreement through ``hts.run``."""
import dataclasses
import importlib.util
import pathlib

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import hts
from repro.core.hts import assembler, costs, golden, isa
from repro.core.hts.builder import BuilderError, Program


# ---------------------------------------------------------------------------
# region allocator
# ---------------------------------------------------------------------------
def test_region_allocator_never_overlaps():
    p = Program("alloc")
    regions = [p.input(0x10, 4)]
    regions += [p.region(sz) for sz in (4, 1, 16, 3, 8, 100, 1)]
    regions.append(p.region(4, at=0x40))          # explicit hole
    regions += [p.region(sz) for sz in (64, 2)]   # keeps allocating past it
    w = p.walker(stride=8, count=4)               # reserves 32 words
    regions += [p.region(8), p.region(1)]
    spans = sorted((r.addr, r.end) for r in regions)
    spans.append((w.start, w.start + 4 * 8))
    spans.sort()
    for (s1, e1), (s2, e2) in zip(spans, spans[1:]):
        assert e1 <= s2, f"live regions overlap: [{s1:#x},{e1:#x}) vs " \
                         f"[{s2:#x},{e2:#x})"


def test_region_explicit_overlap_raises():
    p = Program("clash")
    p.region(8, at=0x100)
    with pytest.raises(BuilderError, match="overlaps live region"):
        p.region(4, at=0x104)
    with pytest.raises(BuilderError, match="overlaps live region"):
        p.input(0xFC, 8)
    # sub-regions are views, not new reservations — and are bounds-checked
    r = p.region(8)
    assert r.sub(2, 4).addr == r.addr + 2
    with pytest.raises(BuilderError):
        r.sub(6, 4)


def test_region_images_attach():
    p = Program("img")
    r = p.region(4)
    r.init([1, 2], offset=1)
    r.effect(9)
    assert p.mem_init == {r.addr + 1: 1, r.addr + 2: 2}
    assert p.effects == {r.addr: 9}
    with pytest.raises(BuilderError):
        r.init([1, 2, 3], offset=2)               # image exceeds region


# ---------------------------------------------------------------------------
# lowering identity vs hand-written assembly
# ---------------------------------------------------------------------------
def test_builder_matches_paper_vb_example():
    """The §V-B independent-nodes listing, typed vs hand-assembled."""
    p = Program("vb")
    layout = [("real_fir", 0x10, 2, 0x13, 2), ("complex_fir", 0x16, 2, 0x19, 2),
              ("adaptive_fir", 0x23, 3, 0x28, 3), ("vector_dot", 0x40, 4, 0x48, 4),
              ("iir", 0x32, 3, 0x36, 3)]
    for tid, (func, a, asz, b, bsz) in enumerate(layout):
        p.task(func, in_=p.input(a, asz), out=p.region(bsz, at=b), tid=tid)
    hand = """\
real_fir 10 2 13 2 0 0 0 0000
complex_fir 16 2 19 2 1 0 0 0000
adaptive_fir 23 3 28 3 2 0 0 0000
vector_dot 40 4 48 4 3 0 0 0000
iir 32 3 36 3 4 0 0 0000"""
    assert np.array_equal(p.build().code, assembler.assemble(hand))


def test_loop_context_matches_hand_asm():
    """``with p.loop(n):`` + walker lowers to the exact mov/lbeg/lend idiom
    of the paper's loop example (machine-code identity)."""
    p = Program("loop")
    frame = p.input(0x10, 4)
    w = p.walker(stride=8, count=4)
    with p.loop(4):
        p.task("iir", in_=frame, out=w, out_size=4, tid=1)
        w.advance()
    hand = """\
mov 100 0 1 0 0 0 1 0    # r1 = walking out base (imm)
mov 8 0 2 0 0 0 1 0      # r2 = stride (imm)
lbeg 4 3 0 0 0 0 0 0     # r3 = 4 iterations
iir 10 4 1 4 1 0 2 0     # out indirect via r1
add 1 2 1 0 0 0 0 0      # r1 += r2
lend 0 3 2 0 0 0 0 0     # loop back over 2-instr body
"""
    assert np.array_equal(p.build().code, assembler.assemble(hand))


def test_branch_context_matches_hand_asm():
    """``p.branch`` lowers to if/fall-through/jump exactly as hand-written
    label assembly (machine-code identity, incl. offsets)."""
    p = Program("br")
    frame = p.input(0x10, 4)
    thr = p.let(5)
    corr = p.task("correlation", in_=frame, out=1, tid=0)
    br = p.branch(on=corr.out, cond=">=", thr=thr, kind="bus")
    with br.not_taken():
        p.task("real_fir", in_=frame, out=4, tid=1)
    with br.taken():
        p.task("dct", in_=frame, out=4, tid=2)
    p.task("vector_max", in_=frame, out=1, tid=3)
    hand = """\
mov 5 0 1 0 0 0 1 0
correlation 10 4 100 1 0 0 0 0
if 100 1 @taken 0 0 0 a 0      ; BR kind, GE cond -> ctl 0xa
real_fir 10 4 108 4 1 0 0 0
jump @end 0 0 0 0 0 0 0
@taken
dct 10 4 110 4 2 0 0 0
@end
vector_max 10 4 118 1 3 0 0 0
"""
    assert np.array_equal(p.build().code, assembler.assemble(hand))


def test_builder_asm_reassembles_identically():
    """BuiltProgram.asm is paper-fidelity text: assembling it reproduces the
    builder's own machine code for every library benchmark."""
    from repro.core.hts import programs
    for bench in programs.all_benches():
        built = bench.program.build()
        assert np.array_equal(assembler.assemble(built.asm), built.code), \
            bench.name


# ---------------------------------------------------------------------------
# interleave
# ---------------------------------------------------------------------------
def _chain(name, funcs, pid, base):
    p = Program(name, region_base=base)
    frame = p.input(base - 0x10, 4)
    with p.process(pid):
        prev = frame
        for i, f in enumerate(funcs):
            prev = p.task(f, in_=prev, out=4, in_size=4, tid=i)
    return p


def test_interleave_preserves_per_process_order():
    a_funcs = ["fft_256", "vector_dot", "iir", "real_fir"]
    b_funcs = ["dct", "vector_max", "correlation"]
    a = _chain("a", a_funcs, pid=1, base=0x100)
    b = _chain("b", b_funcs, pid=2, base=0x400)
    merged = a.interleave(b).build()
    by_pid = {1: [], 2: []}
    for ins in merged.instrs:
        assert ins.op == isa.OP_TASK
        by_pid[ins.pid].append(costs.FUNC_NAMES[ins.acc])
    assert by_pid[1] == a_funcs      # per-process program order intact
    assert by_pid[2] == b_funcs
    # and the *dependencies* stay within each process after scheduling
    r = golden.run(merged.code, costs.costs_by_name("hts_spec"),
                   golden.HtsParams(n_fu=(2,) * 10))
    pid_of_uid = {uid: ins.pid
                  for uid, ins in enumerate(merged.instrs, start=1)}
    for t in r.tasks:
        if t.dep_uid:
            assert pid_of_uid[t.dep_uid] == pid_of_uid[t.uid]


def test_interleave_structured_nodes_stay_atomic():
    """A whole loop interleaves as one unit — the old asm-line splice tore
    lbeg/lend apart and silently corrupted offsets."""
    a = Program("a", region_base=0x100)
    fa = a.input(0x10, 4)
    w = a.walker(stride=8, count=4)
    with a.loop(4):
        a.task("iir", in_=fa, out=w, out_size=4, tid=1)
        w.advance()
    b = Program("b", region_base=0x400)
    fb = b.input(0x20, 4)
    with b.process(1):
        for i in range(3):
            b.task("dct", in_=fb, out=4, tid=i)
    merged = a.interleave(b).build()
    ops = [ins.op for ins in merged.instrs]
    lbeg, lend = ops.index(isa.OP_LBEG), ops.index(isa.OP_LEND)
    body = merged.instrs[lbeg + 1:lend]
    assert all(i.pid == 0 for i in body if i.op == isa.OP_TASK), \
        "foreign task spliced inside the loop body"
    assert merged.instrs[lend].b == lend - (lbeg + 1)   # back-offset intact
    # and it actually runs to completion on both backends with equal schedules
    rj = hts.run(merged, n_fu=2)
    rg = hts.run(merged, n_fu=2, backend="golden")
    assert rj.schedule == rg.schedule
    assert rj.n_tasks == 4 + 3


def test_interleave_overlapping_regions_raise():
    a = _chain("a", ["iir"], pid=0, base=0x100)
    b = _chain("b", ["dct"], pid=1, base=0x100)     # same region space!
    with pytest.raises(BuilderError, match="overlaps"):
        a.interleave(b)


# ---------------------------------------------------------------------------
# round-trip property: builder → encode → decode → disassemble → reassemble
# ---------------------------------------------------------------------------
@st.composite
def built_programs(draw):
    p = Program("prop")
    frame = p.input(0x10, 4)
    sources = [frame]
    for i in range(draw(st.integers(1, 8))):
        func = draw(st.sampled_from(sorted(costs.FUNC_IDS)))
        src = sources[draw(st.integers(0, len(sources) - 1))]
        sources.append(p.task(func, in_=src, out=4, in_size=4,
                              tid=draw(st.integers(0, 15)),
                              pid=draw(st.integers(0, 3))))
    if draw(st.booleans()):
        w = p.walker(stride=8, count=4)
        with p.loop(draw(st.integers(1, 4))):
            p.task(draw(st.sampled_from(sorted(costs.FUNC_IDS))),
                   in_=frame, out=w, out_size=4, tid=1)
            w.advance()
    if draw(st.booleans()):
        cond = p.region(1, name="cond").init(draw(st.integers(0, 9)))
        br = p.branch(on=cond, cond=draw(st.sampled_from(list("== != >= <=".split()))),
                      thr=5, kind=draw(st.sampled_from(["mem", "bus"])))
        with br.not_taken():
            p.task("real_fir", in_=frame, out=4, tid=1)
        if draw(st.booleans()):
            with br.taken():
                p.task("dct", in_=frame, out=4, tid=2)
    return p.build()


@settings(max_examples=30, deadline=None)
@given(built_programs())
def test_builder_roundtrip_identity(built):
    # encode → decode is the identity on instruction records
    decoded = isa.decode_program(built.code)
    assert list(decoded) == list(built.instrs)
    assert np.array_equal(isa.encode_program(decoded), built.code)
    # disassemble → reassemble is the identity on machine code
    asm = built.asm
    assert np.array_equal(assembler.assemble(asm, built.keynames), built.code)
    # isa-level disassembly and Instr.__str__ agree line-by-line
    assert isa.disassemble(built.code).splitlines() == \
        [str(i) for i in decoded]


# ---------------------------------------------------------------------------
# the hts.run / hts.sweep facade
# ---------------------------------------------------------------------------
def _load_quickstart():
    path = pathlib.Path(__file__).parent.parent / "examples" / "quickstart.py"
    spec = importlib.util.spec_from_file_location("quickstart_example", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_quickstart_backends_agree():
    """Acceptance: backend="golden" and backend="jax" execute the quickstart
    program with identical schedules."""
    program = _load_quickstart().build_program()
    rj = hts.run(program, scheduler="hts_spec", n_fu=2, backend="jax")
    rg = hts.run(program, scheduler="hts_spec", n_fu=2, backend="golden")
    assert rj.schedule == rg.schedule
    assert rj.cycles == rg.cycles
    assert rj.schedule_tuple() == rg.schedule_tuple()
    assert 0.0 < rj.utilization <= 1.0
    assert rj.utilization == pytest.approx(rg.utilization)
    naive = hts.run(program, scheduler="naive", n_fu=2)
    assert rj.speedup_vs(naive) > 1.0
    assert "fft_256" in rj.table()


def test_run_accepts_every_program_form():
    bench = __import__("repro.core.hts.programs",
                       fromlist=["x"]).no_dependency(6)
    via_bench = hts.run(bench, n_fu=2)
    via_program = hts.run(bench.program, n_fu=2)
    via_asm = hts.run(bench.asm, n_fu=2)
    via_code = hts.run(assembler.assemble(bench.asm), n_fu=2)
    assert (via_bench.cycles == via_program.cycles == via_asm.cycles
            == via_code.cycles)
    with pytest.raises(TypeError):
        hts.run(12345)


def test_run_unhalted_raises_named_error():
    bench = __import__("repro.core.hts.programs",
                       fromlist=["x"]).no_dependency(6)
    with pytest.raises(hts.SimulationError) as ei:
        hts.run(bench, scheduler="naive", n_fu=1, max_cycles=10)
    msg = str(ei.value)
    assert "no_dependency" in msg and "naive" in msg
    partial = hts.run(bench, scheduler="naive", n_fu=1, max_cycles=10,
                      check=False)
    assert not partial.halted


def test_sweep_matches_pointwise_run():
    bench = __import__("repro.core.hts.programs",
                       fromlist=["x"]).no_dependency(12)
    sw = hts.sweep(bench, n_fu=(1, 2, 4), schedulers=("naive", "hts_spec"))
    assert sw.schedulers == ("naive", "hts_spec")
    cyc = sw.cycles["hts_spec"]
    assert (cyc[0] >= cyc[1]).all() if hasattr(cyc[0], "all") \
        else cyc[0] >= cyc[1] >= cyc[2]
    for i, k in enumerate((1, 2, 4)):
        solo = hts.run(bench, scheduler="hts_spec", n_fu=k, max_prog=64)
        assert solo.cycles == int(cyc[i])
    speedup = sw.speedup("hts_spec", "naive")
    assert (speedup >= 1.0).all()
    assert "strong scaling" in sw.table()


def test_run_with_cost_object_and_per_class_n_fu():
    bench = __import__("repro.core.hts.programs",
                       fromlist=["x"]).no_dependency(6)
    c = dataclasses.replace(costs.hts_costs(True), issue_width=1)
    r = hts.run(bench, scheduler=c, n_fu=(1,) * 10)
    assert r.scheduler == "hts_spec" and r.halted
    with pytest.raises(ValueError):
        hts.run(bench, n_fu=(1, 2))                 # wrong class count
