"""step_impl backends: xla (restructured), xla_base, pallas — bit-identity.

The PR that introduced ``step_impl`` restructured the XLA step body for
the population width-cost curve and added a fused-pallas-kernel step
(interpreted on CPU).  Every implementation must produce bit-identical
schedules: these tests pin xla ≡ xla_base ≡ pallas on generated
scenarios (cycles, full schedule tuples, fe_stall; both event-skip
modes; single-lane and population paths), pallas ≡ golden through the
standard differential machinery (slow tier — interpret mode pays per
step), and the compile-bucket invariant that the default path did not
move.
"""
import numpy as np
import pytest

import repro.core.hts as hts
from repro.core.hts import api, batch, costs, machine, workloads

FAST_SEEDS = (0, 3, 11)


def _prep(seed, **kw):
    sc = workloads.generate_scenario(seed, n_tenants=2 + seed % 3,
                                     kernels=workloads.CHEAP_MIX,
                                     max_tasks=4, **kw)
    return sc, api._prepare(sc.merged)


# ---------------------------------------------------------------------------
# cross-implementation bit-identity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("event_skip", [True, False])
def test_step_impls_bit_identical_single_lane(event_skip):
    """All three implementations agree on every output array of the
    single-lane machine — trace tables and counters included, so
    fe_stall/stall_cycles/fu_busy_cycles are pinned, not just cycles."""
    cost = costs.costs_by_name("hts_spec")
    for seed in FAST_SEEDS:
        sc, prep = _prep(seed, heterogeneous_fus=seed % 2 == 1)
        outs = {impl: machine.simulate(prep.code, cost,
                                       mem_init=prep.mem_init,
                                       effects=prep.effects,
                                       event_skip=event_skip,
                                       fu_cost=sc.fu_cost, step_impl=impl)
                for impl in machine.STEP_IMPLS}
        ref = outs["xla"]
        for impl in ("xla_base", "pallas"):
            for k in ref:
                assert np.array_equal(ref[k], outs[impl][k]), \
                    f"seed {seed}: xla vs {impl} differ on {k!r}"


def test_step_impls_bit_identical_population():
    """One packed population through run_many under each implementation:
    cycles, schedule tuples and fe_stall agree lane for lane."""
    progs = [_prep(s)[0].merged for s in range(4)]
    runs = {impl: hts.run_many(progs, scheduler="hts_spec", step_impl=impl)
            for impl in machine.STEP_IMPLS}
    ref = runs["xla"]
    for impl in ("xla_base", "pallas"):
        r = runs[impl]
        assert np.array_equal(ref.cycles, r.cycles), impl
        for i in range(len(progs)):
            assert ref[i].schedule_tuple() == r[i].schedule_tuple(), \
                (impl, i)
            assert ref[i].fe_stall == r[i].fe_stall, (impl, i)


def test_single_lane_pallas_via_api():
    """hts.run(step_impl="pallas") — the population-of-one lift — matches
    the default path on the full Result surface."""
    sc, _ = _prep(7)
    a = hts.run(sc.merged, scheduler="hts_spec")
    b = hts.run(sc.merged, scheduler="hts_spec", step_impl="pallas")
    assert a.cycles == b.cycles
    assert a.schedule_tuple() == b.schedule_tuple()
    assert a.fe_stall == b.fe_stall


def test_pallas_resumable_slices_compose():
    """The pallas step is a fixed point for paused lanes too: slicing a
    pallas population in small step budgets collects the same outcome as
    the unsliced pallas (and default xla) run."""
    import jax
    import jax.numpy as jnp
    progs = [_prep(s)[0].merged for s in range(3)]
    ref = hts.run_many(progs, scheduler="hts_spec")
    pal = hts.run_many(progs, scheduler="hts_spec", step_impl="pallas")
    rm = api._population_slicer(pal._spec, pal._max_prog)
    args = [jnp.asarray(a) for a in pal._margs]
    carry = rm.init(*args)
    for _ in range(200):
        carry = rm.run_slice(carry, *args, jnp.asarray(37, jnp.int32))
        if not np.asarray(jax.device_get(carry["halted"]) == False).any():
            break
    out = rm.collect(carry)
    assert np.array_equal(np.asarray(out["cycles"]), ref.cycles)
    assert np.asarray(out["halted"]).all()


@pytest.mark.slow
def test_pallas_differential_fuzz():
    """The standard differential harness (golden ≡ machine, event-skip on
    AND off) with the machine side running the pallas kernels — interpret
    mode pays per machine step, hence the slow tier."""
    for seed in range(6):
        sc = workloads.generate_scenario(seed, n_tenants=2 + seed % 3,
                                         kernels=workloads.CHEAP_MIX,
                                         max_tasks=4,
                                         heterogeneous_fus=seed % 3 == 0)
        hts.compare(sc.merged, schedulers=("hts_nospec", "hts_spec"),
                    fu_cost=sc.fu_cost, step_impl="pallas")


@pytest.mark.slow
def test_pallas_population_compare_fuzz():
    """Population differential: compare_population with step_impl="pallas"
    verifies the batched pallas machine against the golden loop in both
    event-skip modes."""
    progs = [workloads.generate_scenario(100 + s, n_tenants=2,
                                         kernels=workloads.CHEAP_MIX,
                                         max_tasks=4).merged
             for s in range(3)]
    hts.compare_population(progs, schedulers=("hts_spec",),
                           step_impl="pallas")


# ---------------------------------------------------------------------------
# compile-key discipline
# ---------------------------------------------------------------------------
def test_default_step_impl_compile_bucket_unchanged():
    """The default path's compile key did not move: a default-constructed
    MachineSpec equals one with explicit step_impl="xla" (same lru
    bucket), explicit "xla" runs reuse the warm default bucket, and the
    other implementations compile into buckets of their own."""
    assert machine.MachineSpec() == machine.MachineSpec(step_impl="xla")
    sc, _ = _prep(0)
    # a max_cycles value no other test uses — this test owns its buckets
    # regardless of what the rest of the suite has already warmed
    mc = 4_999_991
    hts.run(sc.merged, n_fu=2, max_cycles=mc)        # warm default bucket
    before = machine._compiled.cache_info().misses
    hts.run(sc.merged, n_fu=2, max_cycles=mc, step_impl="xla")
    assert machine._compiled.cache_info().misses == before
    hts.run(sc.merged, n_fu=2, max_cycles=mc, step_impl="xla_base")
    assert machine._compiled.cache_info().misses == before + 1


def test_invalid_step_impl_raises():
    with pytest.raises(ValueError, match="step_impl"):
        machine.make_machine(machine.MachineSpec(), step_impl="triton")


def test_trip_cost_us_probe():
    """The profiling hook returns a positive per-trip figure on the jax
    backend and refuses on golden (no compiled machine to time)."""
    progs = [_prep(s)[0].merged for s in range(2)]
    r = hts.run_many(progs, scheduler="hts_spec")
    t = r.trip_cost_us(budget=16, reps=2)
    assert t > 0.0
    g = hts.run_many(progs, scheduler="hts_spec", backend="golden")
    with pytest.raises(ValueError, match="jax"):
        g.trip_cost_us()


def test_replicate_tiles_lanes():
    """batch.replicate widens a pack lane-for-lane: replica lanes produce
    the source lanes' cycles, so width sweeps vary only the width."""
    progs = [_prep(s)[0].merged for s in range(2)]
    pop = batch.pack_population(progs)
    wide = batch.replicate(pop, 5)
    assert len(wide) == 5
    ref = hts.run_many(pop, scheduler="hts_spec")
    r = hts.run_many(wide, scheduler="hts_spec")
    for i in range(5):
        assert int(r.cycles[i]) == int(ref.cycles[i % 2])
